"""Server hot-path benchmark: flush paths and end-to-end transports.

The parameter server is the serial resource of the cluster runtime —
every microsecond it spends aggregating is stolen from the whole fleet
at once.  Two sections, one artifact (``BENCH_server.json``):

**Flush grid** — the two implementations of the fused aggregate+apply
on the CI workload (the ``mlp`` classifier the cluster smoke tests
train):

  * **pytree** — the pre-slab ``ParameterServer`` hot path, frozen here
    verbatim: one jitted per-leaf weighted fold per buffer size K,
    precompiled for every K in 1..fleet at construction (O(fleet)
    startup compiles), params re-allocated on every update;
  * **slab** — the live path (:mod:`repro.core.slab`): gradients staged
    into a preallocated (K_max, P) buffer, ONE donated flush executable
    for every K via zero-weight masking.

The grid carries an **optimizer column** ({sgd, adamw} x every
(fleet, K) cell): sgd cells diff the slab path against the frozen
pytree baseline (speedup + acceptance); adamw cells record the fused
flush+optimizer executable — aggregation, moment updates, bias
correction and the parameter step in ONE donated launch — which has no
pre-slab counterpart to diff against.

Reported per (fleet, K, optimizer) cell:

  * ``grads_per_s`` — gradients applied per second over the **full
    server lifecycle**: construction + executable compilation + serving
    ``n_flushes`` flushes of K gradients.  CI cluster runs are
    short-lived servers (seconds of wall budget), so startup compiles
    are real serving time; this is the headline number and the
    acceptance criterion (slab >= 2x pytree at K >= 4).
  * ``startup_s`` / ``serve_s`` — the split, so the trajectory records
    where the time goes;
  * ``p50_ms`` / ``p99_ms`` — steady-state per-flush apply latency
    (compiles excluded), for both paths.

**Transport grid** — the same server driven end-to-end through the
cluster runtime under each transport (``--transport``): ``inproc``
worker threads vs ``proc`` worker processes (own JAX runtimes, socket
slab frames) vs ``host`` — the multi-host path, where the leader binds
a real TCP port and every worker is a separately-launched
``repro join`` process group that rebuilds the workload from spec JSON
fetched in the leader handshake.  Each (fleet, K, transport) cell runs
a real hybrid training burst with ``const:K`` and reports
gradients/sec over the serving window (the clock starts only once the
fleet is ready, so worker-process startup is excluded and the numbers
are comparable).  This is where "does contention actually cost us"
gets a number: thread workers share one GIL/runtime, process workers
genuinely contend on the server alone, and host workers add the full
join/lease/TCP layer the multi-host deployment pays.

**Zoo sweep** — the model-zoo slab path vs parameter count P
(``zoo:transformer`` at a ladder of ``zoo_scale`` widths), in every
``{f32, bf16} x {unsharded, sharded}`` combination: per cell the
staged-flush throughput (the optimizer's saturation point — stage K
rows, one donated flush), and the wire codec throughput
(slab -> frame bytes -> slab, i.e. what the socket hubs pay per
gradient, with ``bytes_per_grad`` recording the 2x bf16 saving).

Emits ``BENCH_server.json`` with a stable schema
(``repro.bench.server/v3``) so future PRs can diff the perf trajectory:

  PYTHONPATH=src python -m benchmarks.server_throughput --quick
  PYTHONPATH=src python -m benchmarks.server_throughput \\
      --transport inproc proc host    # transport grid selection
  PYTHONPATH=src python -m benchmarks.server_throughput --zoo-only \\
      --out BENCH_zoo.json            # just the zoo sweep (make bench-zoo)
  # or: make bench-server   /   python -m repro bench
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.slab import SlabAggregator, slab_codec


# ------------------------------------------------------------- workload

def ci_workload(seed: int = 0):
    """The CI workload: the ``mlp`` classifier params (what
    ``make smoke-cluster`` trains) and a bank of gradient-sized trees."""
    from repro.models.cnn import init_mlp_clf
    params = init_mlp_clf(jax.random.PRNGKey(seed))
    return params


def gradient_bank(params, n: int):
    """n distinct gradient trees (deterministic, gradient-sized)."""
    def one(i):
        ks = jax.random.split(jax.random.PRNGKey(1000 + i),
                              len(jax.tree_util.tree_leaves(params)))
        flat, treedef = jax.tree_util.tree_flatten(params)
        leaves = [0.01 * jax.random.normal(k, x.shape)
                  for k, x in zip(ks, flat)]
        return jax.tree_util.tree_unflatten(treedef, leaves)
    bank = [one(i) for i in range(n)]
    jax.block_until_ready(bank)
    return bank


# ----------------------------------------------------------- the paths

class PytreePath:
    """The pre-slab server hot path, frozen for comparison: jitted
    per-K fold over gradient pytrees + the O(fleet) precompile loop."""

    name = "pytree"

    def __init__(self, params, fleet: int, lr: float):
        self.lr = lr
        self.params = params

        def _agg_apply(params, grads, weights, scale):
            wsum = jnp.sum(weights)

            def comb(p, *leaves):
                s = weights[0] * leaves[0]
                for w, leaf in zip(weights[1:], leaves[1:]):
                    s = s + w * leaf
                return p - scale * (s / wsum)

            return jax.tree.map(comb, params, *grads)

        self._agg_apply = jax.jit(_agg_apply)
        # the pre-PR startup rule: compile every buffer size the run can
        # reach (K in 1..fleet) before the clock starts
        for k in range(1, max(1, fleet) + 1):
            jax.block_until_ready(self._agg_apply(
                params, (params,) * k, jnp.ones((k,), jnp.float32), 0.0))

    def serve_flush(self, grad_trees: List, weights: np.ndarray,
                    scale: float) -> None:
        self.params = self._agg_apply(
            self.params, tuple(grad_trees),
            jnp.asarray(weights, jnp.float32), scale)
        jax.block_until_ready(self.params)


class SlabPath:
    """The live slab path: stage K rows, one donated flush — with the
    optimizer (sgd | momentum | adamw) fused into the same executable
    when one is named."""

    name = "slab"

    def __init__(self, params, fleet: int, lr: float, optimizer=None):
        self.lr = lr
        self.codec = slab_codec(params)
        self.agg = SlabAggregator(self.codec, params, max(1, fleet),
                                  optimizer=optimizer)
        self.agg.warmup()

    def serve_flush(self, grad_slabs: List, weights: np.ndarray,
                    scale: float) -> None:
        for slot, slab in enumerate(grad_slabs):
            self.agg.stage(slab, slot)
        jax.block_until_ready(self.agg.flush_apply(weights, scale))


# ------------------------------------------------------------ zoo sweep

def bench_zoo_cell(params, kind: str, scale: float, dtype_name: str,
                   shards: int, K: int, n_flushes: int,
                   lr: float = 0.05) -> Dict:
    """One zoo cell: the slab path on a real zoo model's params at one
    (slab dtype, shard count) point — staged-flush throughput plus the
    wire codec cost per gradient."""
    from repro.cluster.mptransport import (_slab_from_payload,
                                           _slab_to_bytes)

    codec = slab_codec(params, dtype_name)
    bank = [codec.encode(g) for g in gradient_bank(params, max(K, 2))]
    jax.block_until_ready(bank)
    rows = [bank[i % len(bank)] for i in range(K)]
    weights = np.ones((K,), np.float32)

    t0 = time.perf_counter()
    agg = SlabAggregator(codec, params, K, shards=shards)
    agg.warmup()
    startup_s = time.perf_counter() - t0
    lat = np.empty(n_flushes)
    t1 = time.perf_counter()
    for i in range(n_flushes):
        f0 = time.perf_counter()
        for slot, slab in enumerate(rows):
            agg.stage(slab, slot)
        jax.block_until_ready(agg.flush_apply(weights, lr * K))
        lat[i] = time.perf_counter() - f0
    serve_s = time.perf_counter() - t1

    # the wire codec: what a socket hub pays per gradient frame
    n_wire = 5
    t2 = time.perf_counter()
    for _ in range(n_wire):
        payload = _slab_to_bytes(np.asarray(rows[0]), dtype_name)
    encode_s = (time.perf_counter() - t2) / n_wire
    t3 = time.perf_counter()
    for _ in range(n_wire):
        _slab_from_payload(payload, 0, dtype_name)
    decode_s = (time.perf_counter() - t3) / n_wire

    n_gradients = n_flushes * K
    return {
        "workload": f"zoo:{kind}", "zoo_scale": scale,
        "P": codec.size, "P_padded": codec.padded_size,
        "dtype": dtype_name, "shards": agg.shards, "K": K,
        "n_flushes": n_flushes,
        "flush": {
            "startup_s": round(startup_s, 4),
            "serve_s": round(serve_s, 4),
            "grads_per_s": round(n_gradients / max(serve_s, 1e-9), 1),
            "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
        },
        "wire": {
            "bytes_per_grad": len(payload),
            "encode_gbps": round(len(payload) / max(encode_s, 1e-9)
                                 / 1e9, 3),
            "decode_gbps": round(len(payload) / max(decode_s, 1e-9)
                                 / 1e9, 3),
        },
    }


def run_zoo_sweep(scales, dtypes, shard_opts, K: int,
                  n_flushes: int, kind: str = "transformer") -> Dict:
    """P sweep: the zoo workload at each scale, every dtype x sharding
    combination on the same params."""
    import jax as _jax

    from repro.models import model as M
    from repro.models.zoo import num_params, zoo_config

    grid = []
    for scale in scales:
        cfg = zoo_config(kind, scale)
        params = M.init_params(_jax.random.PRNGKey(0), cfg)
        n = num_params(params)
        for dtype_name in dtypes:
            for shards in shard_opts:
                cell = bench_zoo_cell(params, kind, scale, dtype_name,
                                      shards, K, n_flushes)
                grid.append(cell)
                f = cell["flush"]
                w = cell["wire"]
                print(f"zoo:{kind} x{scale:<5g} P={cell['P']:>9d} "
                      f"{dtype_name:4s} shards={cell['shards']}: "
                      f"flush {f['grads_per_s']:8.1f} g/s "
                      f"(p50 {f['p50_ms']:.2f}ms) | wire "
                      f"{w['bytes_per_grad'] / 1e6:6.2f} MB/grad "
                      f"enc {w['encode_gbps']:.2f} GB/s", flush=True)
        del params
    return {
        "definition": ("flush.grads_per_s = K*n_flushes / serve_s over "
                       "the staged-flush cycle (stage K rows + one "
                       "donated flush); wire.* is the slab<->frame "
                       "codec alone (bytes_per_grad halves at bf16)"),
        "kind": kind, "K": K, "grid": grid,
    }


# ------------------------------------------------- transport end-to-end

def bench_transport_cell(fleet: int, K: int, transport: str,
                         max_gradients: int, budget_s: float) -> Dict:
    """One (fleet, K, transport) cell: a real cluster training burst
    (hybrid, ``const:K``) through the full runtime.  gradients/sec is
    applied gradients over the *serving* window — the fleet-ready
    barrier keeps worker-process startup out of the denominator.

    The ``host`` cell is the full multi-host path: the leader binds a
    real TCP port and each worker is a separately-launched
    ``python -m repro join`` process group that rebuilds the workload
    from spec JSON fetched in the leader handshake."""
    from repro.api import ExperimentSpec
    from repro.cluster.trainer import ClusterTrainer

    spec = ExperimentSpec(
        arch="mlp", backend="cluster", mode="hybrid",
        schedule=f"const:{K}", cluster_workers=fleet,
        wall_budget_s=budget_s, wall_sample_every_s=budget_s,
        batch=32, smoke=True, transport=transport,
        max_gradients=max_gradients, listen="127.0.0.1:0")
    trainer = ClusterTrainer()
    if transport == "host":
        from repro.cluster.hostlink import spawn_join_process
        platform = None if jax.default_backend() == "cpu" else "cpu"
        runtime = trainer.build_runtime(spec)
        # the trainer's 10-minute interactive join window is wrong for
        # a scripted bench: a join group that dies at startup should
        # fail the cell in ~2 minutes, not stall the whole grid
        runtime.proc_ready_timeout_s = 120.0
        joins = [spawn_join_process(runtime.listen_address, workers=1,
                                    platform=platform)
                 for _ in range(fleet)]
        try:
            res = trainer.finish(runtime, spec)
        finally:
            codes = []
            for p in joins:
                try:
                    codes.append(p.wait(timeout=60))
                except Exception:
                    p.kill()
                    codes.append(p.wait())
        if any(codes):
            # a dead join group means the cell was measured with a
            # smaller fleet than its label claims — refuse to record it
            raise RuntimeError(
                f"host bench cell fleet={fleet} K={K}: join process "
                f"exit codes {codes} — the measured fleet was degraded")
    else:
        res = trainer.run(spec)
    a = res.extra["accounting"]
    serve_s = res.extra["serve_wall_s"]
    return {"transport": transport, "fleet": fleet, "K": K,
            "applied": a["applied"], "updates": a["updates"],
            "computed": a["computed"],
            "serve_wall_s": round(serve_s, 3),
            "total_wall_s": round(res.wall_s, 3),
            "grads_per_s": round(a["applied"] / max(serve_s, 1e-9), 1)}


def run_transport_grid(fleets, ks, transports, max_gradients: int,
                       budget_s: float):
    rows = []
    for fleet in fleets:
        for K in ks:
            if K > fleet:
                continue
            for transport in transports:
                row = bench_transport_cell(fleet, K, transport,
                                           max_gradients, budget_s)
                rows.append(row)
                print(f"fleet={fleet:3d} K={K:3d} "
                      f"{transport:7s}: {row['grads_per_s']:9.1f} g/s "
                      f"({row['applied']} grads in "
                      f"{row['serve_wall_s']:.2f}s serving)", flush=True)
    return rows


# ----------------------------------------------------------- measuring

def bench_cell(params, fleet: int, K: int, n_flushes: int,
               lr: float = 0.05, optimizer: str = "sgd") -> Dict:
    """One (fleet, K, optimizer) cell, same gradients and flush
    sequence for every path.  ``optimizer="sgd"`` runs both the frozen
    pytree baseline and the slab path (the historical comparison, with
    the speedup acceptance); momentum/adamw cells run the slab path
    alone — they measure the *fused flush+update* executable, which has
    no pre-slab counterpart to diff against."""
    from repro.optim import SlabOptimizer

    bank = gradient_bank(params, max(K, 4))
    codec = slab_codec(params)
    bank_slabs = [codec.encode(g) for g in bank]
    jax.block_until_ready(bank_slabs)
    weights = np.ones((K,), np.float32)
    n_gradients = n_flushes * K
    cell: Dict = {"fleet": fleet, "K": K, "optimizer": optimizer,
                  "n_flushes": n_flushes, "n_gradients": n_gradients}
    opt = SlabOptimizer(optimizer)

    paths = [(SlabPath, bank_slabs)]
    if optimizer == "sgd":
        paths.insert(0, (PytreePath, bank))
    for cls, grads in paths:
        rows = [grads[i % len(grads)] for i in range(K)]
        t0 = time.perf_counter()
        path = cls(params, fleet, lr, optimizer=opt) \
            if cls is SlabPath else cls(params, fleet, lr)
        startup_s = time.perf_counter() - t0
        lat = np.empty(n_flushes)
        t1 = time.perf_counter()
        for i in range(n_flushes):
            f0 = time.perf_counter()
            path.serve_flush(rows, weights, lr * K)
            lat[i] = time.perf_counter() - f0
        serve_s = time.perf_counter() - t1
        cell[cls.name] = {
            "startup_s": round(startup_s, 4),
            "serve_s": round(serve_s, 4),
            "grads_per_s": round(n_gradients / (startup_s + serve_s), 1),
            "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
        }
    if optimizer == "sgd":
        cell["speedup_grads_per_s"] = round(
            cell["slab"]["grads_per_s"] / cell["pytree"]["grads_per_s"],
            2)
    return cell


def run_grid(fleets, ks, n_flushes: int,
             optimizers=("sgd", "adamw")) -> Dict:
    params = ci_workload()
    codec = slab_codec(params)
    grid = []
    for fleet in fleets:
        for K in ks:
            if K > fleet:
                continue
            for optimizer in optimizers:
                cell = bench_cell(params, fleet, K, n_flushes,
                                  optimizer=optimizer)
                grid.append(cell)
                if optimizer == "sgd":
                    print(f"fleet={fleet:3d} K={K:3d} {optimizer:5s}: "
                          f"pytree {cell['pytree']['grads_per_s']:9.1f}"
                          f" g/s "
                          f"(p50 {cell['pytree']['p50_ms']:.2f}ms) | "
                          f"slab {cell['slab']['grads_per_s']:9.1f} g/s"
                          f" (p50 {cell['slab']['p50_ms']:.2f}ms) | "
                          f"speedup {cell['speedup_grads_per_s']:.2f}x",
                          flush=True)
                else:
                    print(f"fleet={fleet:3d} K={K:3d} {optimizer:5s}: "
                          f"slab {cell['slab']['grads_per_s']:9.1f} g/s"
                          f" (p50 {cell['slab']['p50_ms']:.2f}ms) "
                          f"[fused flush+update]", flush=True)
    # the acceptance cell: K >= 4 sgd cells must show >= 2x; record the
    # worst of them so the pass/fail is the conservative reading
    # (momentum/adamw cells carry no pytree baseline to diff against)
    acc_cells = [c for c in grid
                 if c["K"] >= 4 and c["optimizer"] == "sgd"]
    worst = min(acc_cells, key=lambda c: c["speedup_grads_per_s"]) \
        if acc_cells else None
    report = {
        "schema": "repro.bench.server/v3",
        "workload": "mlp",
        "P": codec.size, "P_padded": codec.padded_size,
        "leaves": len(codec.sizes),
        "definition": ("grads_per_s = n_gradients / (startup_s + "
                       "serve_s); startup includes executable "
                       "compilation (the pre-slab server compiled one "
                       "executable per K in 1..fleet; the slab server "
                       "compiles exactly one)"),
        "grid": grid,
        "acceptance": None if worst is None else {
            "criterion": "slab >= 2x pytree grads/sec at K >= 4",
            "fleet": worst["fleet"], "K": worst["K"],
            "pytree_grads_per_s": worst["pytree"]["grads_per_s"],
            "slab_grads_per_s": worst["slab"]["grads_per_s"],
            "speedup": worst["speedup_grads_per_s"],
            "pass": bool(worst["speedup_grads_per_s"] >= 2.0),
        },
        "env": {"backend": jax.default_backend(),
                "jax": jax.__version__,
                "device_count": jax.device_count()},
    }
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="server throughput: slab vs pytree flush paths, "
                    "plus end-to-end in-proc vs multi-proc transports")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized grid (fleets 4/8, K 1/4)")
    ap.add_argument("--full", action="store_true",
                    help="larger grid (fleets up to 32, K up to 16)")
    ap.add_argument("--fleets", type=int, nargs="*", default=None)
    ap.add_argument("--ks", type=int, nargs="*", default=None)
    ap.add_argument("--flushes", type=int, default=None,
                    help="flushes per cell (default 100; CI runs are "
                         "short-lived servers, so the count is sized "
                         "like a smoke run's update budget)")
    ap.add_argument("--transport", nargs="*", default=None,
                    choices=["inproc", "socket", "proc", "host", "none"],
                    help="transports for the end-to-end grid (default: "
                         "inproc proc host — in-proc vs multi-proc vs "
                         "multi-host joined process groups; 'none' "
                         "skips the section, e.g. for flush-path-only "
                         "iteration)")
    ap.add_argument("--zoo-scales", type=float, nargs="*", default=None,
                    help="zoo sweep: zoo_scale ladder (the P sweep; "
                         "default 0.125 0.25; pass an empty list to "
                         "skip the section)")
    ap.add_argument("--zoo-flushes", type=int, default=20,
                    help="zoo sweep: flushes per cell (default 20 — "
                         "the slabs are MBs, not KBs)")
    ap.add_argument("--zoo-only", action="store_true",
                    help="run only the zoo sweep (make bench-zoo): "
                         "skips the flush and transport grids, so the "
                         "output is NOT a perf-gate --fresh input")
    ap.add_argument("--out", default="BENCH_server.json")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when the acceptance criterion "
                         "(slab >= 2x pytree grads/sec at K >= 4) fails "
                         "— turns the CI step into a perf-regression "
                         "gate, not just a recorder")
    args = ap.parse_args(argv)

    if args.full:
        fleets, ks, n = [4, 8, 16, 32], [1, 4, 8, 16], 200
        t_fleets, t_ks, t_grads, t_budget = [2, 4, 8], [1, 4, 8], 600, 12.0
    elif args.quick:
        fleets, ks, n = [4, 8], [1, 4], 100
        t_fleets, t_ks, t_grads, t_budget = [2, 4], [1, 4], 300, 8.0
    else:
        fleets, ks, n = [4, 8, 16], [1, 4, 8], 100
        t_fleets, t_ks, t_grads, t_budget = [2, 4], [1, 4], 400, 10.0
    # --fleets/--ks override BOTH grids (K > fleet cells are skipped,
    # so a shrunken flush grid cannot silently keep large proc cells)
    fleets = args.fleets if args.fleets else fleets
    ks = args.ks if args.ks else ks
    n = args.flushes if args.flushes else n
    t_fleets = args.fleets if args.fleets else t_fleets
    t_ks = args.ks if args.ks else t_ks
    transports = args.transport if args.transport is not None \
        else ["inproc", "proc", "host"]
    if "none" in transports:
        transports = []
    zoo_scales = args.zoo_scales if args.zoo_scales is not None \
        else [0.125, 0.25]

    if args.zoo_only:
        report = {"schema": "repro.bench.server/v3",
                  "env": {"backend": jax.default_backend(),
                          "jax": jax.__version__,
                          "device_count": jax.device_count()}}
        transports = []
        if not zoo_scales:
            zoo_scales = [0.125, 0.25]
    else:
        report = run_grid(fleets, ks, n)
    if zoo_scales:
        print("\nzoo sweep ({f32,bf16} x {unsharded,sharded} vs P):")
        report["zoo"] = run_zoo_sweep(
            zoo_scales, ["f32", "bf16"],
            [1, max(2, jax.local_device_count())], K=4,
            n_flushes=args.zoo_flushes)
    if transports:
        print(f"\ntransport grid (hybrid const:K, {t_grads} gradients "
              f"per cell, serving window only):")
        report["transports"] = {
            "definition": ("grads_per_s = applied / serve_wall_s; the "
                           "serving window starts at the fleet-ready "
                           "barrier, so worker-process startup (JAX "
                           "import + compile) is excluded and inproc/"
                           "proc cells are comparable"),
            "max_gradients": t_grads,
            "budget_s": t_budget,
            "grid": run_transport_grid(t_fleets, t_ks, transports,
                                       t_grads, t_budget),
        }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    acc = report.get("acceptance")
    if acc:
        print(f"\nacceptance (worst K>=4 cell, fleet={acc['fleet']} "
              f"K={acc['K']}): pytree {acc['pytree_grads_per_s']} g/s, "
              f"slab {acc['slab_grads_per_s']} g/s -> "
              f"{acc['speedup']}x ({'PASS' if acc['pass'] else 'FAIL'})")
    print(f"wrote {args.out}")
    if args.check and acc and not acc["pass"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
