"""Benchmark entry point: one section per paper table/figure plus the
kernel microbenches and the roofline summary derived from the cached
dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run [--quick|--full]
  # or, via the unified CLI:
  PYTHONPATH=src python -m repro bench [--quick|--full]
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest grids (CI-sized)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (hours)")
    ap.add_argument("--transport", nargs="*", default=None,
                    choices=["inproc", "socket", "proc", "none"],
                    help="transports for the server-throughput "
                         "end-to-end grid (forwarded to "
                         "benchmarks.server_throughput; 'none' skips "
                         "it)")
    args = ap.parse_args(argv)

    t0 = time.time()
    print("#" * 70)
    print("# Paper tables (Vora et al. 2024): hybrid vs async vs sync")
    print("#" * 70)
    from benchmarks import paper_tables
    flags = []
    if args.quick:
        flags.append("--quick")
    if args.full:
        flags.append("--full")
    paper_tables.main(["--table", "all"] + flags)

    print()
    print("#" * 70)
    print("# Server throughput: flush paths + in-proc vs multi-proc")
    print("#" * 70)
    from benchmarks import server_throughput
    st_flags = (["--quick"] if args.quick
                else ["--full"] if args.full else [])
    if args.transport is not None:
        st_flags += ["--transport", *args.transport]
    server_throughput.main(st_flags)

    print()
    print("#" * 70)
    print("# Serving-plane load: training throughput + staleness under readers")
    print("#" * 70)
    from benchmarks import serve_load
    serve_load.main(["--quick"] if args.quick else [])

    print()
    print("#" * 70)
    print("# Kernel microbenchmarks (jnp reference wall-time + TPU roofline)")
    print("#" * 70)
    from benchmarks import kernels
    kernels.main()

    print()
    print("#" * 70)
    print("# Roofline summary (from experiments/dryrun artifacts)")
    print("#" * 70)
    from benchmarks import roofline
    rows = roofline.load_all("pod")
    if rows:
        print(roofline.markdown_table(rows))
    else:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")

    print(f"\ntotal benchmark time: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
