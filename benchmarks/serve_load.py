"""Serving-plane load benchmark: what do readers cost the fleet?

The serving-plane invariant is that read-only SERVE subscribers ride
the training leader for free: ``publish_params`` swaps a pointer, each
client's pushes are coalesced by a per-connection writer, and a slow
reader wedges only its own socket.  This benchmark puts a number on
"for free": the same async training run (mlp, host transport, one
joined worker group) under {0, 2, 8} concurrent serve clients, each
client hammering inference probes against every pushed params version.

Reported per cell:

  * ``train.grads_per_s`` — applied gradients over the serving window
    (fleet-ready barrier to shutdown).  The clients here run *in the
    leader's process* hammering JAX probes, so this column prices the
    worst case — co-located readers stealing leader CPU; remote
    readers cost only push bandwidth, and the wire-level invariant
    (a stalled reader never blocks a flush) is enforced by the
    conformance tests, not this number;
  * ``clients[].qps`` — inference requests per second per client;
  * ``clients[].staleness`` — per-request ``p50``/``p99``/``max`` of
    (leader's live params version − version the request ran against),
    in versions.  This is the staleness-vs-throughput readout: raising
    ``serve_every`` trades staleness for less push bandwidth;
  * ``serving`` — the leader's own per-client push accounting
    (``RunResult.extra["serving"]``), so pushes/skips are recorded
    from both ends of the wire.

Emits ``BENCH_serve.json`` (schema ``repro.bench.serve/v1``):

  PYTHONPATH=src python -m benchmarks.serve_load --quick
  # or: make bench-serve   /   python -m repro bench
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List

import numpy as np


def _client_loop(address, spec, runtime, stop, record, idx):
    """One serve client: subscribe, probe every pushed version, record
    per-request staleness against the leader's live counter."""
    from repro.serve.client import ServeClient
    from repro.serve.workload import build_infer_adapter
    try:
        client = ServeClient(address, connect_timeout=120.0)
    except Exception as e:                      # leader gone already
        record["error"] = f"connect failed: {e}"
        return
    try:
        adapter = build_infer_adapter(spec)
        last_version = None
        params = None
        lat: List[float] = []
        stale: List[int] = []
        t_first = None
        while not stop.is_set():
            msg = client.wait_params(min_version=0, timeout=0.25)
            if msg is None:
                if client.closed.is_set():
                    break
                continue
            if t_first is None:
                t_first = time.monotonic()
            if msg.version != last_version:
                params = adapter.decode(msg.params)
                last_version = msg.version
            t0 = time.monotonic()
            adapter.run(params, len(lat))
            lat.append(time.monotonic() - t0)
            server = getattr(runtime, "server", None)
            live = getattr(server, "version", msg.version)
            stale.append(max(0, int(live) - int(msg.version)))
        wall = (time.monotonic() - t_first) if t_first else 0.0
        record.update({
            "client": idx,
            "requests": len(lat),
            "qps": round(len(lat) / max(wall, 1e-9), 2),
            "req_p50_ms": round(
                float(np.percentile(lat, 50)) * 1e3, 2) if lat else None,
            "staleness": {
                "p50": float(np.percentile(stale, 50)),
                "p99": float(np.percentile(stale, 99)),
                "max": int(max(stale)),
            } if stale else None,
            "last_version": last_version,
        })
    finally:
        client.close()


def bench_cell(n_clients: int, budget_s: float, serve_every: int,
               platform=None) -> Dict:
    """One cell: a real host-transport training run (one joined worker
    process) with ``n_clients`` in-process serve-client threads probing
    every push."""
    from repro.api import ExperimentSpec
    from repro.cluster.hostlink import spawn_join_process
    from repro.cluster.trainer import ClusterTrainer

    spec = ExperimentSpec(
        arch="mlp", backend="cluster", mode="async", smoke=True,
        cluster_workers=1, wall_budget_s=budget_s,
        wall_sample_every_s=budget_s, batch=16,
        transport="host", listen="127.0.0.1:0",
        serve_every=serve_every)
    trainer = ClusterTrainer()
    runtime = trainer.build_runtime(spec)
    runtime.proc_ready_timeout_s = 180.0
    join = spawn_join_process(runtime.listen_address, workers=1,
                              platform=platform)
    stop = threading.Event()
    records: List[Dict] = [{} for _ in range(n_clients)]
    threads = [threading.Thread(
        target=_client_loop,
        args=(runtime.listen_address, spec, runtime, stop, records[i], i),
        daemon=True) for i in range(n_clients)]
    for t in threads:
        t.start()
    try:
        res = trainer.finish(runtime, spec)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30.0)
        try:
            join.wait(timeout=60)
        except Exception:
            join.kill()
    a = res.extra["accounting"]
    serve_s = res.extra["serve_wall_s"]
    return {
        "clients": n_clients,
        "serve_every": serve_every,
        "train": {
            "applied": a["applied"],
            "serve_wall_s": round(serve_s, 3),
            "grads_per_s": round(a["applied"] / max(serve_s, 1e-9), 1),
        },
        "client_stats": [r for r in records if r],
        "serving": res.extra.get("serving"),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="serving-plane load: training throughput and "
                    "per-client staleness under {0,2,8} serve clients")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: {0,2} clients, short budget")
    ap.add_argument("--clients", type=int, nargs="*", default=None,
                    help="override the client-count grid")
    ap.add_argument("--budget", type=float, default=None,
                    help="training wall budget per cell (seconds)")
    ap.add_argument("--serve-every", type=int, default=1,
                    help="leader-side push downsampling (the "
                         "staleness-vs-throughput knob)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    grid_clients = args.clients if args.clients is not None \
        else ([0, 2] if args.quick else [0, 2, 8])
    budget = args.budget if args.budget else (8.0 if args.quick else 12.0)

    import jax
    platform = None if jax.default_backend() == "cpu" else "cpu"

    cells = []
    for n in grid_clients:
        cell = bench_cell(n, budget, args.serve_every, platform)
        cells.append(cell)
        cl = cell["client_stats"]
        qps = ", ".join(f"{c.get('qps', 0)}" for c in cl) or "-"
        st = cl[0]["staleness"] if cl and cl[0].get("staleness") \
            else None
        print(f"clients={n}: train "
              f"{cell['train']['grads_per_s']:.1f} g/s | qps [{qps}]"
              + (f" | staleness p50 {st['p50']} p99 {st['p99']}"
                 if st else ""), flush=True)

    base = cells[0]["train"]["grads_per_s"] if cells else None
    report = {
        "schema": "repro.bench.serve/v1",
        "workload": "mlp",
        "definition": ("train.grads_per_s = applied / serve_wall_s "
                       "(fleet-ready barrier to shutdown); staleness "
                       "in versions = leader's live params version - "
                       "version the request ran against, sampled per "
                       "request"),
        "budget_s": budget,
        "grid": cells,
        "baseline_grads_per_s": base,
        "worst_train_ratio": None if not base else round(
            min(c["train"]["grads_per_s"] for c in cells)
            / max(base, 1e-9), 3),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
