"""Paper-table reproductions (Tables 1–5 of Vora et al. 2024).

Every table reports the difference (hybrid − async) of test accuracy /
test loss / train loss *averaged over the entire training interval*, the
paper's headline metric (positive accuracy diff & negative loss diff =
hybrid better).  Sync is also run for the Table-1/2 figures.

Fast mode (the default, used by benchmarks.run) shrinks workers / horizon /
rounds so the whole suite fits a CPU budget; --full reproduces the paper's
25-worker 100s-horizon setting.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.api import ExperimentSpec, SimulatorTrainer
from repro.core.simulator import WorkerPool
from repro.data.synthetic import (cifar10_like, mnist_like,
                                  random_classification)
from repro.models.cnn import (accuracy, cnn_forward, init_cnn, init_mlp_clf,
                              mlp_clf_forward, nll_loss)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "paper")
LR = 0.01
# Calibrated cluster profile (EXPERIMENTS.md §Paper): 25 workers at ~50
# grad/s each with a 2 ms PS apply cost — the regime where the paper's
# async baseline is parameter-server-bound and delayed workers are many
# updates stale.
BASE_COMPUTE = 0.02


def _cnn_setup(dataset, image_shape, n_train, n_test, seed):
    x_tr, y_tr, x_te, y_te = dataset(seed=seed, n_train=n_train,
                                     n_test=n_test)
    params = init_cnn(jax.random.PRNGKey(seed), image_shape)
    loss = lambda p, x, y: nll_loss(cnn_forward(p, x), y)
    acc = jax.jit(lambda p, x, y: accuracy(cnn_forward(p, x), y))
    return loss, params, (x_tr, y_tr, x_te, y_te), acc


def _mlp_setup(seed):
    data = random_classification(seed=seed)
    params = init_mlp_clf(jax.random.PRNGKey(seed))
    loss = lambda p, x, y: nll_loss(mlp_clf_forward(p, x), y)
    acc = jax.jit(lambda p, x, y: accuracy(mlp_clf_forward(p, x), y))
    return loss, params, data, acc


def run_comparison(setup, *, workers, horizon, batch, step_size,
                   rounds, pool_kwargs=None, modes=("async", "hybrid"),
                   seed0=0) -> Dict[str, Dict[str, float]]:
    """Averaged-over-interval metrics per mode, averaged over rounds with
    shared initialization per round (the paper's protocol)."""
    agg: Dict[str, List[Dict[str, float]]] = {m: [] for m in modes}
    for r in range(rounds):
        loss, params, data, acc = setup(seed0 + r)
        pool = WorkerPool(num_workers=workers, base_compute=BASE_COMPUTE,
                          **(pool_kwargs or {}))
        tr = SimulatorTrainer(loss, params, data, accuracy_fn=acc)
        base = ExperimentSpec(backend="sim", mode="hybrid",
                              schedule=f"step:{step_size}", lr=LR,
                              batch=batch, horizon=horizon, pool=pool,
                              seed=seed0 + r)
        for mode in modes:
            res = tr.run(base.with_(mode=mode))
            agg[mode].append(res.averaged())
    out = {}
    for mode, rows in agg.items():
        out[mode] = {k: float(np.mean([r[k] for r in rows]))
                     for k in rows[0]}
    return out


def diff_row(res) -> Dict[str, float]:
    """hybrid − async (the paper's table entries)."""
    return {
        "test_acc_diff": 100 * (res["hybrid"]["test_acc"]
                                - res["async"]["test_acc"]),
        "test_loss_diff": res["hybrid"]["test_loss"]
        - res["async"]["test_loss"],
        "train_loss_diff": res["hybrid"]["train_loss"]
        - res["async"]["train_loss"],
    }


def _print_table(title, cols, rows):
    print(f"\n== {title} ==")
    print("metric," + ",".join(str(c) for c in cols))
    for metric in ("test_acc_diff", "test_loss_diff", "train_loss_diff"):
        print(metric + "," + ",".join(f"{rows[c][metric]:+.3f}"
                                      for c in cols))


def table_1_2(full: bool, quick: bool = False):
    """MNIST-like / CIFAR-like, (step, batch) grid (paper Tables 1, 2)."""
    workers = 25
    horizon = 100.0 if full else (4.0 if quick else 15.0)
    rounds = 5 if full else (1 if quick else 2)
    n_train = 60000 if full else (2000 if quick else 4000)
    n_test = 10000 if full else 1000
    grid = [(300, 32), (300, 64), (500, 32), (500, 64)]
    if quick:
        grid = [(300, 32)]
    results = {}
    for name, ds, shape in (("mnist", mnist_like, (28, 28, 1)),
                            ("cifar10", cifar10_like, (32, 32, 3))):
        rows = {}
        for (ss, bs) in grid:
            res = run_comparison(
                lambda s, ds=ds, shape=shape: _cnn_setup(
                    ds, shape, n_train, n_test, s),
                workers=workers, horizon=horizon, batch=bs, step_size=ss,
                rounds=rounds, pool_kwargs={"delay_std": 0.25},
                modes=("async", "hybrid", "sync"))
            rows[(ss, bs)] = {**diff_row(res),
                              "sync_acc": 100 * res["sync"]["test_acc"],
                              "async_acc": 100 * res["async"]["test_acc"],
                              "hybrid_acc": 100 * res["hybrid"]["test_acc"]}
        _print_table(f"Table {'1' if name == 'mnist' else '2'} "
                     f"({name}-like): hybrid - async", list(rows), rows)
        results[name] = {str(k): v for k, v in rows.items()}
    return results


def table_3(full: bool, quick: bool = False):
    """Batch-size sweep at step size 500 (paper Table 3)."""
    workers = 25
    horizon = 100.0 if full else (4.0 if quick else 15.0)
    rounds = 5 if full else (1 if quick else 2)
    batches = [8, 16, 32, 64, 128] if not quick else [8, 32, 128]
    rows = {}
    for bs in batches:
        res = run_comparison(_mlp_setup, workers=workers, horizon=horizon,
                             batch=bs, step_size=500, rounds=rounds,
                             pool_kwargs={"delay_std": 0.25})
        rows[bs] = diff_row(res)
    _print_table("Table 3 (batch sizes, random 20-dim dataset)", batches,
                 rows)
    return {str(k): v for k, v in rows.items()}


def table_4(full: bool, quick: bool = False):
    """Step-size sweep (multiples of 1/lr) at batch 32 (paper Table 4)."""
    workers = 25
    horizon = 100.0 if full else (4.0 if quick else 15.0)
    rounds = 5 if full else (1 if quick else 2)
    mults = [1, 3, 5, 7, 10] if not quick else [1, 5, 10]
    rows = {}
    for m in mults:
        res = run_comparison(_mlp_setup, workers=workers, horizon=horizon,
                             batch=32, step_size=int(m / LR), rounds=rounds,
                             pool_kwargs={"delay_std": 0.25})
        rows[m] = diff_row(res)
    _print_table("Table 4 (step sizes ·1/lr, random dataset)", mults, rows)
    return {str(k): v for k, v in rows.items()}


def table_5(full: bool, quick: bool = False):
    """Delay-distribution sweep at (step 500, batch 32) (paper Table 5)."""
    workers = 25
    horizon = 100.0 if full else (4.0 if quick else 15.0)
    rounds = 5 if full else (1 if quick else 2)
    stds = [0.25, 0.5, 0.75, 1.0, 1.25] if not quick else [0.25, 1.25]
    rows = {}
    for std in stds:
        res = run_comparison(_mlp_setup, workers=workers, horizon=horizon,
                             batch=32, step_size=500, rounds=rounds,
                             pool_kwargs={"delay_std": std})
        rows[std] = diff_row(res)
    _print_table("Table 5 (delay std, random dataset)", stds, rows)
    return {str(k): v for k, v in rows.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", choices=("1_2", "3", "4", "5", "all"),
                    default="all")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (25 workers, 100s horizon, 5 rounds)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    os.makedirs(OUT_DIR, exist_ok=True)
    t0 = time.time()
    out = {}
    if args.table in ("1_2", "all"):
        out["tables_1_2"] = table_1_2(args.full, args.quick)
    if args.table in ("3", "all"):
        out["table_3"] = table_3(args.full, args.quick)
    if args.table in ("4", "all"):
        out["table_4"] = table_4(args.full, args.quick)
    if args.table in ("5", "all"):
        out["table_5"] = table_5(args.full, args.quick)
    tag = "full" if args.full else ("quick" if args.quick else "fast")
    path = os.path.join(OUT_DIR, f"paper_tables_{tag}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nsaved {path} ({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
