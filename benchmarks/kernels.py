"""Kernel benchmarks: wall-time of the jitted jnp references (the CPU
executable path) plus TPU roofline-derived expected times for the Pallas
kernels (interpret mode has no meaningful timing, so the TPU column is
bytes/bandwidth + flops/peak arithmetic on the kernel's actual traffic).

CSV columns: name,us_per_call,derived
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.hybrid_aggregate import TILE_P
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _time(fn, *args, iters=10):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_flush():
    rows = []
    for K in (4, 25):
        P = TILE_P * 16
        g = jax.random.normal(jax.random.PRNGKey(0), (K, P), jnp.float32)
        w = jnp.full((K,), 1.0 / K)
        us = _time(jax.jit(ref.flush_ref), g, w)
        bytes_moved = (K + 1) * P * 4
        tpu_us = bytes_moved / HBM_BW * 1e6
        rows.append((f"hybrid_flush_K{K}_P{P}", us,
                     f"tpu_mem_bound={tpu_us:.1f}us"))
    return rows


def bench_rmsnorm():
    rows = []
    for shape in ((8192, 4096),):
        x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
        s = jnp.ones((shape[-1],))
        us = _time(jax.jit(lambda x, s: ref.rmsnorm_ref(x, s)), x, s)
        bytes_moved = 2 * x.size * 4
        rows.append((f"rmsnorm_{shape[0]}x{shape[1]}", us,
                     f"tpu_mem_bound={bytes_moved / HBM_BW * 1e6:.1f}us"))
    return rows


def bench_attention():
    rows = []
    B, S, H, KV, d = 1, 2048, 8, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, d), jnp.float32)
    us = _time(jax.jit(lambda q, k, v: ref.attention_ref(q, k, v)), q, k, v)
    flops = 4 * B * H * S * S * d  # qk + pv
    rows.append((f"flash_attention_B{B}_S{S}_H{H}", us,
                 f"tpu_compute_bound={flops / PEAK_FLOPS_BF16 * 1e6:.1f}us"))
    return rows


def main():
    print("name,us_per_call,derived")
    for rows in (bench_flush(), bench_rmsnorm(), bench_attention()):
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
