"""Render EXPERIMENTS.md sections from experiments/dryrun artifacts:
fills the <!-- ... --> placeholders (dry-run table, roofline table,
hybrid-R pair, memory notes)."""
from __future__ import annotations

import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import roofline  # noqa: E402

DRY = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def load(pattern):
    out = []
    for p in sorted(glob.glob(os.path.join(DRY, pattern))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def peak_gib(rec):
    m = rec["memory"]
    return (m["argument_bytes"] + m["temp_bytes"] + m["output_bytes"]
            - m["alias_bytes"]) / 2 ** 30


def dryrun_table():
    rows = ["| arch | shape | mesh | status | params | peak GiB | "
            "compile s | exec coll GiB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3, "pod": 0, "multipod": 1}
    recs = [r for r in load("*.json") if r.get("tag", "") == ""]
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9),
                             order.get(r["mesh"], 9)))
    for r in recs:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skip: {r['reason']} | | | | |")
        elif r["status"] == "ok":
            coll = r["exec_collective_bytes_per_device"]["total"] / 2 ** 30
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r['num_params'] / 1e9:.1f}B | {peak_gib(r):.1f} | "
                f"{r['compile_s']} | {coll:.2f} |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | | | | |")
    return "\n".join(rows)


def hybrid_table():
    recs = [r for r in load("*hybrid_R*.json") if r["status"] == "ok"]
    recs.sort(key=lambda r: (r["mesh"], r["hybrid_rep"]))
    rows = ["| mesh | R (groups) | group size g | coll GiB/dev | "
            "all-reduce GiB | peak GiB | Δcoll vs sync |",
            "|---|---|---|---|---|---|---|"]
    base = {}
    for r in recs:
        if r["hybrid_rep"] == 1:
            base[r["mesh"]] = r["exec_collective_bytes_per_device"]["total"]
    for r in recs:
        c = r["exec_collective_bytes_per_device"]
        data_total = 32 if r["mesh"] == "multipod" else 16
        g = data_total // r["hybrid_rep"]
        b = base.get(r["mesh"])
        delta = f"{100 * (c['total'] / b - 1):+.1f}%" if b else ""
        rows.append(
            f"| {r['mesh']} | {r['hybrid_rep']} | {g} | "
            f"{c['total'] / 2**30:.1f} | "
            f"{c.get('all-reduce', 0) / 2**30:.1f} | {peak_gib(r):.1f} | "
            f"{delta} |")
    return "\n".join(rows)


def mem_notes():
    notes = []
    for r in load("*.json"):
        if r.get("tag"):
            continue
        if r["status"] == "ok" and r["mesh"] == "pod" \
                and peak_gib(r) > 16.0:
            notes.append(f"* **{r['arch']} × {r['shape']}**: "
                         f"{peak_gib(r):.1f} GiB/dev")
    return "\n".join(notes) if notes else "* all pod combos fit"


def fill(md: str, marker: str, content: str) -> str:
    return md.replace(f"<!-- {marker} -->", content)


def main():
    with open(EXP) as f:
        md = f.read()
    md = fill(md, "DRYRUN_TABLE", dryrun_table())
    rows = roofline.load_all("pod")
    md = fill(md, "ROOFLINE_TABLE", roofline.markdown_table(rows))
    notes = "\n".join(
        f"* `{r['arch']} × {r['shape']}` → **{r['dominant']}**-bound "
        f"(lower bound {r['step_lower_bound_s']:.3g} s/step): {r['hint']}"
        for r in rows)
    md = fill(md, "ROOFLINE_NOTES",
              "### Dominant bottleneck & lever per combo\n\n" + notes)
    md = fill(md, "PAIR_C", hybrid_table())
    md = fill(md, "MEM_NOTES", mem_notes())
    with open(EXP, "w") as f:
        f.write(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
