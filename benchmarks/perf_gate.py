"""Perf-regression gate: diff a fresh BENCH_server.json vs the baseline.

The flush grid's ``slab.grads_per_s`` is the repo's headline server
number; this gate keeps PRs from silently walking it backwards.  CI
runs ``make bench-server`` (fresh ``BENCH_server.json``) and then:

  PYTHONPATH=src python -m benchmarks.perf_gate \\
      --fresh BENCH_server.json \\
      --baseline benchmarks/BENCH_server.baseline.json

Every (fleet, K) cell present in the baseline must exist in the fresh
report and reach ``--tolerance`` (default 0.35) of the baseline's slab
grads/sec.  The tolerance is deliberately loose: CI machines are
shared and noisy, and the gate exists to catch structural regressions
(a lost donation, a re-compile per flush — integer-factor cliffs), not
single-digit-percent jitter.  Missing cells and a missing/partial
baseline FAIL rather than skip: a gate that silently waves through a
shrunken grid is not a gate.

Refreshing the baseline after an intentional perf change::

  make bench-server && cp BENCH_server.json \\
      benchmarks/BENCH_server.baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _flush_cells(report):
    cells = {}
    for c in report.get("grid", []):
        cells[(int(c["fleet"]), int(c["K"]))] = \
            float(c["slab"]["grads_per_s"])
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="fail when fresh slab grads/sec falls below "
                    "tolerance x baseline on any flush-grid cell")
    ap.add_argument("--fresh", default="BENCH_server.json")
    ap.add_argument("--baseline",
                    default="benchmarks/BENCH_server.baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.35,
                    help="fresh must reach this fraction of baseline "
                         "per cell (default 0.35 — catches structural "
                         "cliffs, ignores CI noise)")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"perf gate FAIL: cannot read baseline "
              f"{args.baseline}: {e}", file=sys.stderr)
        return 1
    try:
        with open(args.fresh) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"perf gate FAIL: cannot read fresh report "
              f"{args.fresh}: {e}", file=sys.stderr)
        return 1

    base_cells = _flush_cells(baseline)
    fresh_cells = _flush_cells(fresh)
    if not base_cells:
        print(f"perf gate FAIL: baseline {args.baseline} has no "
              "flush-grid cells", file=sys.stderr)
        return 1

    failures = []
    for key in sorted(base_cells):
        fleet, k = key
        base = base_cells[key]
        got = fresh_cells.get(key)
        floor = args.tolerance * base
        if got is None:
            failures.append(f"fleet={fleet} K={k}: cell missing from "
                            f"fresh report (baseline {base:.1f} g/s)")
            continue
        status = "ok" if got >= floor else "REGRESSED"
        print(f"fleet={fleet:3d} K={k:3d}: slab {got:9.1f} g/s vs "
              f"baseline {base:9.1f} (floor {floor:9.1f}) {status}")
        if got < floor:
            failures.append(
                f"fleet={fleet} K={k}: {got:.1f} g/s < "
                f"{args.tolerance} x baseline {base:.1f}")
    if failures:
        print("\nperf gate FAIL:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        print("(intentional change? refresh the baseline: "
              "make bench-server && cp BENCH_server.json "
              "benchmarks/BENCH_server.baseline.json)", file=sys.stderr)
        return 1
    print(f"perf gate PASS ({len(base_cells)} cells, tolerance "
          f"{args.tolerance})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
