"""Perf-regression gate: diff fresh benchmark reports vs their baselines.

The flush grid's ``slab.grads_per_s`` is the repo's headline server
number; this gate keeps PRs from silently walking it backwards.  CI
runs ``make bench-server`` (fresh ``BENCH_server.json``) and then:

  PYTHONPATH=src python -m benchmarks.perf_gate \\
      --fresh BENCH_server.json \\
      --baseline benchmarks/BENCH_server.baseline.json

Every (fleet, K) cell present in the baseline must exist in the fresh
report and reach ``--tolerance`` (default 0.35) of the baseline's slab
grads/sec.  The tolerance is deliberately loose: CI machines are
shared and noisy, and the gate exists to catch structural regressions
(a lost donation, a re-compile per flush — integer-factor cliffs), not
single-digit-percent jitter.  Missing cells and a missing/partial
baseline FAIL rather than skip: a gate that silently waves through a
shrunken grid is not a gate.

The serve plane is gated the same way when ``--serve-fresh`` /
``--serve-baseline`` are given (CI passes ``BENCH_serve.json`` /
``benchmarks/BENCH_serve.baseline.json``).  Per ``clients`` cell:

  * training throughput under serving load must reach ``tolerance`` x
    the baseline's ``train.grads_per_s`` — a serving plane that starts
    starving the training loop is a structural regression;
  * client-observed staleness p99 (worst client in the cell) must stay
    within ``max(base_p99 / tolerance, base_p99 + 2.0)`` versions —
    p99 staleness on a healthy leader is ~1-2 versions, so the
    additive term keeps the bound meaningful where a pure ratio of a
    tiny baseline would be vacuous (or zero).

Refreshing the baselines after an intentional perf change::

  make bench-server && cp BENCH_server.json \\
      benchmarks/BENCH_server.baseline.json
  make bench-serve && cp BENCH_serve.json \\
      benchmarks/BENCH_serve.baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _flush_cells(report):
    """(fleet, K, optimizer) -> slab grads/sec.  Pre-optimizer-column
    reports default the third key to "sgd", so an old baseline keeps
    gating the cells it actually measured."""
    cells = {}
    for c in report.get("grid", []):
        key = (int(c["fleet"]), int(c["K"]),
               str(c.get("optimizer", "sgd")))
        cells[key] = float(c["slab"]["grads_per_s"])
    return cells


def _zoo_cells(report):
    """(workload, zoo_scale, dtype, shards) -> flush grads/sec."""
    cells = {}
    for c in (report.get("zoo") or {}).get("grid", []):
        key = (str(c["workload"]), float(c["zoo_scale"]),
               str(c["dtype"]), int(c["shards"]))
        cells[key] = float(c["flush"]["grads_per_s"])
    return cells


def _serve_cells(report):
    """clients -> (train grads/sec, worst client staleness p99 or None).

    p99 is None for the clients=0 cell (no client_stats to read)."""
    cells = {}
    for c in report.get("grid", []):
        p99s = [float(s["staleness"]["p99"])
                for s in c.get("client_stats", [])]
        cells[int(c["clients"])] = (
            float(c["train"]["grads_per_s"]),
            max(p99s) if p99s else None)
    return cells


def _load(path, what):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"perf gate FAIL: cannot read {what} {path}: {e}",
              file=sys.stderr)
        return None


def gate_serve(fresh_path, baseline_path, tolerance):
    """Gate BENCH_serve.json cells; returns a list of failure lines."""
    baseline = _load(baseline_path, "serve baseline")
    fresh = _load(fresh_path, "fresh serve report")
    if baseline is None or fresh is None:
        return ["serve report/baseline unreadable (see above)"]
    base_cells = _serve_cells(baseline)
    fresh_cells = _serve_cells(fresh)
    if not base_cells:
        return [f"serve baseline {baseline_path} has no cells"]

    failures = []
    for clients in sorted(base_cells):
        base_gps, base_p99 = base_cells[clients]
        cell = fresh_cells.get(clients)
        if cell is None:
            failures.append(f"serve clients={clients}: cell missing "
                            f"from fresh report (baseline "
                            f"{base_gps:.1f} g/s)")
            continue
        got_gps, got_p99 = cell
        floor = tolerance * base_gps
        status = "ok" if got_gps >= floor else "REGRESSED"
        print(f"serve clients={clients:3d}: train {got_gps:9.1f} g/s "
              f"vs baseline {base_gps:9.1f} (floor {floor:9.1f}) "
              f"{status}")
        if got_gps < floor:
            failures.append(
                f"serve clients={clients}: train {got_gps:.1f} g/s < "
                f"{tolerance} x baseline {base_gps:.1f}")
        if base_p99 is None:
            continue
        if got_p99 is None:
            failures.append(f"serve clients={clients}: fresh report "
                            "has no client staleness stats")
            continue
        # ratio bound for big baselines, additive slack for the
        # near-zero healthy case (p99 ~ 1 version)
        ceil = max(base_p99 / tolerance, base_p99 + 2.0)
        status = "ok" if got_p99 <= ceil else "REGRESSED"
        print(f"serve clients={clients:3d}: staleness p99 "
              f"{got_p99:6.1f} vs baseline {base_p99:6.1f} "
              f"(ceiling {ceil:6.1f}) {status}")
        if got_p99 > ceil:
            failures.append(
                f"serve clients={clients}: staleness p99 {got_p99:.1f}"
                f" > ceiling {ceil:.1f} (baseline {base_p99:.1f})")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="fail when fresh slab grads/sec falls below "
                    "tolerance x baseline on any flush-grid cell")
    ap.add_argument("--fresh", default="BENCH_server.json")
    ap.add_argument("--baseline",
                    default="benchmarks/BENCH_server.baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.35,
                    help="fresh must reach this fraction of baseline "
                         "per cell (default 0.35 — catches structural "
                         "cliffs, ignores CI noise)")
    ap.add_argument("--serve-fresh", default=None,
                    help="fresh BENCH_serve.json; gates training "
                         "grads/sec under serving load and client "
                         "staleness p99 per clients cell")
    ap.add_argument("--serve-baseline",
                    default="benchmarks/BENCH_serve.baseline.json")
    args = ap.parse_args(argv)

    baseline = _load(args.baseline, "baseline")
    fresh = _load(args.fresh, "fresh report")
    if baseline is None or fresh is None:
        return 1

    base_cells = _flush_cells(baseline)
    fresh_cells = _flush_cells(fresh)
    if not base_cells:
        print(f"perf gate FAIL: baseline {args.baseline} has no "
              "flush-grid cells", file=sys.stderr)
        return 1

    failures = []
    for key in sorted(base_cells):
        fleet, k, opt = key
        base = base_cells[key]
        got = fresh_cells.get(key)
        floor = args.tolerance * base
        if got is None:
            failures.append(f"fleet={fleet} K={k} opt={opt}: cell "
                            f"missing from fresh report (baseline "
                            f"{base:.1f} g/s)")
            continue
        status = "ok" if got >= floor else "REGRESSED"
        print(f"fleet={fleet:3d} K={k:3d} {opt:5s}: slab {got:9.1f} "
              f"g/s vs baseline {base:9.1f} (floor {floor:9.1f}) "
              f"{status}")
        if got < floor:
            failures.append(
                f"fleet={fleet} K={k} opt={opt}: {got:.1f} g/s < "
                f"{args.tolerance} x baseline {base:.1f}")

    # zoo grid (schema v3): gated only when the baseline carries one,
    # so a pre-v3 baseline keeps gating its own cells without lying
    # about coverage it never measured
    zoo_base = _zoo_cells(baseline)
    zoo_fresh = _zoo_cells(fresh)
    for key in sorted(zoo_base):
        workload, scale, dtype, shards = key
        base = zoo_base[key]
        got = zoo_fresh.get(key)
        floor = args.tolerance * base
        label = (f"zoo {workload}@x{scale:g} dtype={dtype} "
                 f"shards={shards}")
        if got is None:
            failures.append(f"{label}: cell missing from fresh report "
                            f"(baseline {base:.1f} g/s)")
            continue
        status = "ok" if got >= floor else "REGRESSED"
        print(f"{label}: flush {got:9.1f} g/s vs baseline "
              f"{base:9.1f} (floor {floor:9.1f}) {status}")
        if got < floor:
            failures.append(f"{label}: {got:.1f} g/s < "
                            f"{args.tolerance} x baseline {base:.1f}")

    serve_cells = 0
    if args.serve_fresh is not None:
        failures += gate_serve(args.serve_fresh, args.serve_baseline,
                               args.tolerance)
        serve_report = _load(args.serve_baseline, "serve baseline")
        if serve_report is not None:
            serve_cells = len(_serve_cells(serve_report))

    if failures:
        print("\nperf gate FAIL:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        print("(intentional change? refresh the baseline: "
              "make bench-server && cp BENCH_server.json "
              "benchmarks/BENCH_server.baseline.json; for the serve "
              "plane: make bench-serve && cp BENCH_serve.json "
              "benchmarks/BENCH_serve.baseline.json)", file=sys.stderr)
        return 1
    parts = [f"{len(base_cells)} server cells"]
    if zoo_base:
        parts.append(f"{len(zoo_base)} zoo cells")
    if serve_cells:
        parts.append(f"{serve_cells} serve cells")
    print(f"perf gate PASS ({' + '.join(parts)}, tolerance "
          f"{args.tolerance})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
