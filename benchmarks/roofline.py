"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw
(cost_analysis of an SPMD executable reports the per-device program, so no
further ÷chips.)  Also: MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D
(prefill/decode), the useful-compute ratio, the dominant term, and one
sentence on what would move the dominant term down.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, Optional

from repro.configs.registry import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def active_params(arch: str) -> float:
    """Active (per-token) parameter count: total minus the un-routed share
    of expert parameters."""
    cfg = get_config(arch)
    import jax
    from repro.models import model as M
    sds = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    total = sum(x.size for x in jax.tree.leaves(sds))
    if not cfg.num_experts:
        return float(total)
    flat, _ = jax.tree_util.tree_flatten_with_path(sds)
    expert = sum(
        leaf.size for path, leaf in flat
        if any(getattr(p, "key", None) == "experts" for p in path))
    frac = cfg.num_experts_per_tok / cfg.num_experts
    return float(total - expert + expert * frac)


def model_flops(arch: str, shape_name: str, chips: int) -> float:
    """Per-device useful model FLOPs for one step."""
    shape = SHAPES[shape_name]
    n_act = active_params(arch)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_act * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch / chips


def analyse(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "multipod" else 256
    # prefer the trip-count-aware executed costs (repro.launch.hlo_cost)
    flops = rec.get("exec_flops_per_device") or rec["flops_per_device"]
    hbm = rec.get("exec_hbm_bytes_per_device") \
        or rec["bytes_accessed_per_device"]
    coll = rec.get("exec_collective_bytes_per_device",
                   rec["collective_bytes_per_device"]).get("total", 0.0)
    t_c = flops / PEAK_FLOPS_BF16
    t_m = hbm / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], chips)
    mem = rec["memory"]
    peak_gib = (mem["argument_bytes"] + mem["temp_bytes"]
                + mem["output_bytes"] - mem["alias_bytes"]) / 2 ** 30
    hints = {
        "compute": "raise MFU: bigger MXU tiles / fewer rematerialised "
                   "flops (remat policy), overlap collectives",
        "memory": "cut HBM traffic: fuse elementwise chains, larger "
                  "blocks, avoid fp32 round-trips",
        "collective": "reshard: reduce tensor-parallel activation "
                      "all-reduces / FSDP gathers; keep reductions "
                      "intra-pod (hybrid group schedule)",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant,
        "step_lower_bound_s": max(terms.values()),
        "model_flops_per_dev": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "peak_mem_gib": peak_gib,
        "fits_16g": peak_gib <= 16.0,
        "hint": hints[dominant],
    }


def load_all(mesh: str = "pod", tag: str = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        if rec.get("tag", "") != tag:
            continue
        row = analyse(rec)
        if row:
            rows.append(row)
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful FLOP ratio | peak GiB | fits |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order[r["shape"]])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['peak_mem_gib']:.1f} | {'yes' if r['fits_16g'] else 'NO'} |")
    return "\n".join(lines)


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod"
    rows = load_all(mesh)
    print(markdown_table(rows))
    print()
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} -> {r['dominant']:10s}: "
              f"{r['hint']}")


if __name__ == "__main__":
    main()
