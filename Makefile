# Developer / CI entry points.  Everything runs on CPU; multi-device
# scenarios use XLA's forced host devices.
PY ?= python
export PYTHONPATH := src

.PHONY: test quickstart smoke-sim smoke-train smoke-cluster smoke-proc \
	smoke-host smoke-elastic smoke-zoo examples bench-server bench-serve \
	bench-zoo perf-gate

# Benchmark env tuning (standard JAX-on-CPU serving practice): force a
# small multi-device host topology so device placement is exercised,
# and preload tcmalloc when the box has it — glibc malloc contends
# visibly under the slab churn.  Empty when absent; never a dependency.
TCMALLOC := $(firstword $(wildcard /usr/lib/x86_64-linux-gnu/libtcmalloc*.so*))
BENCH_DEVICES ?= 2
BENCH_ENV := XLA_FLAGS=--xla_force_host_platform_device_count=$(BENCH_DEVICES)
ifneq ($(TCMALLOC),)
BENCH_ENV += LD_PRELOAD=$(TCMALLOC)
endif

test:
	$(PY) -m pytest -x -q

quickstart:
	$(PY) examples/quickstart.py

# seconds-scale simulator run through the unified CLI (CI smoke)
smoke-sim:
	$(PY) -m repro simulate --smoke --out /tmp/repro_sim_smoke.json

# SPMD hybrid annealing g: 1 -> 2 on two forced host devices
smoke-train:
	XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	$(PY) -m repro run --backend spmd --arch xlstm-350m --smoke \
	    --steps 8 --mode hybrid --schedule step:4 --batch 4 --seq 32 \
	    --out /tmp/repro_spmd_smoke.json

# wall-clock cluster backend with one injected straggler; the hard
# `timeout` turns a deadlocked barrier into a fast failure, not a hang
smoke-cluster:
	timeout 120 $(PY) -m repro run --backend cluster --arch mlp --smoke \
	    --cluster-workers 4 --wall-budget 10 --wall-sample-every 1 \
	    --mode hybrid --schedule step:40 --straggler 0:0.1 --quiet \
	    --out /tmp/repro_cluster_smoke.json

# multi-process transport: every worker is its own OS process with its
# own JAX runtime, talking slab frames to the server over Unix-domain
# sockets.  Ends on the gradient budget; the hard timeout turns a hung
# fleet (a worker that never connected, a deadlocked barrier) into a
# fast failure
smoke-proc:
	timeout 240 $(PY) -m repro run --backend cluster --arch mlp --smoke \
	    --transport proc --cluster-workers 2 --wall-budget 8 \
	    --wall-sample-every 2 --mode hybrid --schedule step:40 \
	    --max-gradients 400 --quiet --out /tmp/repro_proc_smoke.json

# multi-host transport: a leader bound to a real TCP host:port plus two
# separately-launched `repro join` worker process groups — the
# two-terminal quickstart, scripted (the joins retry until the leader
# is up).  Ends on the gradient budget; the hard timeout turns a lost
# leader or a worker that never joined into a fast failure
smoke-host:
	timeout 240 sh -c ' \
	  $(PY) -m repro serve --listen 127.0.0.1:7781 --arch mlp --smoke \
	      --cluster-workers 2 --wall-budget 8 --wall-sample-every 2 \
	      --mode hybrid --schedule step:40 --max-gradients 400 --quiet \
	      --out /tmp/repro_host_smoke.json & LEADER=$$!; \
	  $(PY) -m repro join 127.0.0.1:7781 --workers 1 --quiet \
	      --connect-timeout 120 & J1=$$!; \
	  $(PY) -m repro join 127.0.0.1:7781 --workers 1 --quiet \
	      --connect-timeout 120 & J2=$$!; \
	  wait $$LEADER; RC=$$?; \
	  wait $$J1; R1=$$?; wait $$J2; R2=$$?; \
	  [ $$RC -eq 0 ] && [ $$R1 -eq 0 ] && [ $$R2 -eq 0 ]'

# elastic fleet: a leader seeded at 2 workers with an admission
# ceiling of 3 admits a late joiner mid-run, survives a SIGKILLed
# worker whose shard is re-leased at a bumped generation, and is gated
# on exit codes AND the exact conservation ledger.  The hard timeout
# turns any membership hang (a barrier that never degraded, a lease
# never reclaimed) into a fast failure
smoke-elastic:
	timeout 360 $(PY) examples/smoke_elastic.py

# model zoo on the cluster path: a registry-built zoo:transformer
# (real forward/backward) trains over the proc transport with the slab
# wire negotiated to bf16; gated on exit codes, the exact conservation
# ledger, non-empty telemetry, and rx bytes/gradient actually halving.
# The hard timeout turns a worker stuck compiling or a hung barrier
# into a fast failure
smoke-zoo:
	timeout 360 $(PY) examples/smoke_zoo.py

# server aggregation hot path (slab vs pre-PR pytree) plus the
# end-to-end transport grid (in-proc threads vs multi-proc workers),
# emitting BENCH_server.json (stable schema, diffed across PRs).  The
# hard timeout turns a wedged benchmark into a fast failure; CI records
# the numbers rather than gating on them (wall-clock speedups on shared
# runners are too noisy for a hard >= 2x gate — pass --check locally
# for the strict version).
bench-server:
	timeout 900 env $(BENCH_ENV) $(PY) -m benchmarks.server_throughput \
	    --quick --out BENCH_server.json

# zoo P-sweep only: the {f32,bf16} x {unsharded,sharded} flush/wire
# grid over real zoo model sizes, written to its own report file.
# BENCH_zoo.json is a standalone artifact — the perf gate's fresh
# input stays the full bench-server report (whose v3 schema embeds the
# same zoo grid alongside the flush grid)
bench-zoo:
	timeout 900 env $(BENCH_ENV) $(PY) -m benchmarks.server_throughput \
	    --zoo-only --out BENCH_zoo.json

# serving-plane load: the same training run under {0,2} serve clients
# (CI-sized grid), emitting BENCH_serve.json — training grads/sec,
# per-client QPS, and p50/p99 params staleness per cell
bench-serve:
	timeout 900 env $(BENCH_ENV) $(PY) -m benchmarks.serve_load \
	    --quick --out BENCH_serve.json

# perf-regression gate: fresh BENCH_server.json flush cells must reach
# tolerance x the committed baseline (structural cliffs, not CI noise);
# fresh BENCH_serve.json cells are gated too — training grads/sec under
# serving load plus client-observed staleness p99 per clients cell
perf-gate:
	$(PY) -m benchmarks.perf_gate --fresh BENCH_server.json \
	    --baseline benchmarks/BENCH_server.baseline.json \
	    --serve-fresh BENCH_serve.json \
	    --serve-baseline benchmarks/BENCH_serve.baseline.json

examples:
	$(PY) examples/quickstart.py
	$(PY) examples/threshold_functions.py
