"""Mamba-1 selective SSM block, chunkwise-parallel for TPU.

The CUDA reference fuses the recurrence into a single kernel with
recomputation; the TPU-native adaptation here processes the sequence in
chunks (`cfg.ssm_chunk`): an outer `lax.scan` carries the SSM state across
chunks while an inner `associative_scan` parallelises within a chunk —
bounding the materialised (B, chunk, d_inner, d_state) tensor so 4k–500k
sequences fit VMEM/HBM budgets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.sharding import lconstraint


def init_mamba(key, cfg: ModelConfig, dtype):
    d, di = cfg.d_model, cfg.mamba_d_inner
    ds, dc, dtr = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.resolved_dt_rank
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_in": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) * dc ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_x": (jax.random.normal(ks[2], (di, dtr + 2 * ds)) * di ** -0.5).astype(dtype),
        "w_dt": (jax.random.normal(ks[3], (dtr, di)) * dtr ** -0.5).astype(dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds)).copy()),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dtype),
    }


def _causal_conv(x, w, b, d_conv: int, init_state=None):
    """Depthwise causal conv.  x: (B, S, di); returns (y, last_state)."""
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], d_conv - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(d_conv))
    return y + b, xp[:, -(d_conv - 1):]


def _ssm_inputs(params, xc, cfg: ModelConfig):
    """xc (B,S,di) post-conv -> (a, bx, C, xc) scan inputs (fp32)."""
    ds, dtr = cfg.mamba_d_state, cfg.resolved_dt_rank
    proj = jnp.einsum("bsd,de->bse", xc, params["w_x"]).astype(jnp.float32)
    dt, Bm, Cm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt, params["w_dt"].astype(jnp.float32))
                         + params["dt_bias"])                       # (B,S,di)
    A = -jnp.exp(params["A_log"])                                    # (di,ds)
    a = jnp.exp(dt[..., None] * A)                                   # (B,S,di,ds)
    bx = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, :, None, :]  # (B,S,di,ds)
    return a, bx, Cm


def _chunk_scan(h0, a, bx):
    """Within-chunk associative scan.  h0 (B,di,ds); a,bx (B,c,di,ds)."""
    def op(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])
    A_cum, B_cum = jax.lax.associative_scan(op, (a, bx), axis=1)
    h = A_cum * h0[:, None] + B_cum                                  # (B,c,di,ds)
    return h, h[:, -1]


def mamba_forward(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    di, chunk = cfg.mamba_d_inner, min(cfg.ssm_chunk, S)
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = lconstraint(xi, ("batch", "seq", "inner"))
    xc, _ = _causal_conv(xi, params["conv_w"], params["conv_b"], cfg.mamba_d_conv)
    xc = jax.nn.silu(xc)

    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    # The (B, c, di, d_state) scan inputs are computed *inside* the chunk
    # body so only one chunk's worth is ever materialised (the classic
    # mamba memory blow-up avoided TPU-side; see module docstring).
    xc_c = xc.reshape(B, n, chunk, di).swapaxes(0, 1)            # (n,B,c,di)

    def body(h, xci):
        ai, bxi, Ci = _ssm_inputs(params, xci, cfg)
        hs, h_new = _chunk_scan(h, ai, bxi)
        y = jnp.einsum("bcds,bcs->bcd", hs, Ci)                      # (B,c,di)
        return h_new, y

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    h0 = jnp.zeros((B, di, cfg.mamba_d_state), jnp.float32)
    _, ys = jax.lax.scan(body, h0, xc_c)
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    y = lconstraint(y, ("batch", "seq", "inner"))
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return lconstraint(out, ("batch", "seq", None))


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    di = cfg.mamba_d_inner
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
    }


def mamba_decode(params, x, cache, cfg: ModelConfig):
    """One-token recurrence.  x: (B, 1, D)."""
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xi, params["conv_w"], params["conv_b"],
                                  cfg.mamba_d_conv, cache["conv"])
    xc = jax.nn.silu(xc)
    a, bx, Cm = _ssm_inputs(params, xc, cfg)
    h = a[:, 0] * cache["h"] + bx[:, 0]
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0])[:, None]
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, {"conv": conv_state, "h": h}
