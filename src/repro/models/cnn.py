"""The paper's experiment models: small CNNs for MNIST/CIFAR-10-like data
and an MLP for the random 20-dim/10-class dataset (paper §5–6).

Pure-JAX functional models (params = pytrees) used by the parameter-server
simulator and by the paper-table benchmarks.  Negative log-likelihood loss,
matching the paper.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def init_cnn(key, image_shape: Tuple[int, int, int], num_classes: int = 10):
    """image_shape = (H, W, C)."""
    H, W, C = image_shape
    ks = jax.random.split(key, 4)
    c1, c2 = 16, 32
    flat = (H // 4) * (W // 4) * c2
    return {
        "conv1_w": jax.random.normal(ks[0], (3, 3, C, c1)) * (9 * C) ** -0.5,
        "conv1_b": jnp.zeros((c1,)),
        "conv2_w": jax.random.normal(ks[1], (3, 3, c1, c2)) * (9 * c1) ** -0.5,
        "conv2_b": jnp.zeros((c2,)),
        "fc1_w": jax.random.normal(ks[2], (flat, 128)) * flat ** -0.5,
        "fc1_b": jnp.zeros((128,)),
        "fc2_w": jax.random.normal(ks[3], (128, num_classes)) * 128 ** -0.5,
        "fc2_b": jnp.zeros((num_classes,)),
    }


def cnn_forward(params, x):
    """x: (B, H, W, C) -> logits (B, num_classes)."""
    h = jax.nn.relu(_conv(x, params["conv1_w"], params["conv1_b"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = jax.nn.relu(_conv(h, params["conv2_w"], params["conv2_b"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1_w"] + params["fc1_b"])
    return h @ params["fc2_w"] + params["fc2_b"]


def init_mlp_clf(key, in_dim: int = 20, hidden: int = 64,
                 num_classes: int = 10):
    ks = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(ks[0], (in_dim, hidden)) * in_dim ** -0.5,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(ks[1], (hidden, hidden)) * hidden ** -0.5,
        "b2": jnp.zeros((hidden,)),
        "w3": jax.random.normal(ks[2], (hidden, num_classes)) * hidden ** -0.5,
        "b3": jnp.zeros((num_classes,)),
    }


def mlp_clf_forward(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


def nll_loss(logits, labels):
    """Negative log-likelihood (the paper's loss)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
