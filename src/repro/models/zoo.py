"""The model zoo on the cluster path: ``zoo:<kind>`` sim workloads.

The registry tiers (:mod:`repro.configs.registry`) describe *published*
model configurations — 350M to 110B — that the SPMD/dryrun planes
lower and analyze but that no CI box can train.  The zoo puts scaled
instances of those same families onto the cluster backend: real
forward/backward through the shared decoder stack
(:mod:`repro.models.model`), the slab aggregation path, the socket /
proc / host wire — so ``ExperimentSpec(arch="zoo:xlstm",
backend="cluster", transport="proc")`` just works, serving plane
included (the serve client rebuilds the same workload from the wire
spec via :class:`repro.serve.workload.ProbeAdapter`).

Two members:

* ``zoo:xlstm`` — the registry's ``xlstm-350m`` tier (mLSTM/sLSTM
  blocks, arXiv:2405.04517).
* ``zoo:transformer`` — the registry's dense ATTN+MLP family
  (``h2o-danube-1.8b``) re-tiered to the same 350M class, so both zoo
  members scale down from the same starting point.

``spec.zoo_scale`` is a width multiplier applied to the tier:
``d_model``, ``d_ff`` and depth scale linearly, the vocabulary
quadratically (embedding tables otherwise dominate the slab), and every
dimension is rounded to hardware-friendly multiples.  ``zoo_scale=1.0``
reproduces the published tier's shape; the default 0.25 yields a
multi-million-parameter model that trains end-to-end on a CPU cluster
in seconds.  Zoo configs train in float32 with tied embeddings and no
remat — the cluster plane's reproducibility contract (bitwise f32
slabs) extends to the zoo unchanged.

The training task is the serving demo's synthetic next-symbol
succession (``label = (token + 1) mod V``): learnable by the
embedding/head alone, so the loss drops within a handful of applied
gradients and smoke runs can assert on it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.models.config import ModelConfig

ZOO_SEQ = 32


def _transformer_350m() -> ModelConfig:
    """The registry's dense ATTN+MLP family at the xlstm-350m class."""
    from repro.configs.registry import get_config
    base = get_config("h2o-danube-1.8b")
    return dataclasses.replace(
        base, name="transformer-350m", d_model=1024, num_heads=16,
        num_kv_heads=8, head_dim=64, d_ff=2816, num_groups=24,
        sliding_window=None, vocab_size=50304,
        source="repro.models.zoo")


def _xlstm_350m() -> ModelConfig:
    from repro.configs.registry import get_config
    return get_config("xlstm-350m")


ZOO_TIERS: Dict[str, Callable[[], ModelConfig]] = {
    "xlstm": _xlstm_350m,
    "transformer": _transformer_350m,
}


def _mult(x: float, m: int, lo: int) -> int:
    """Round ``x`` to a positive multiple of ``m``, at least ``lo``."""
    return max(lo, m * max(1, round(x / m)))


def _scaled_kv_heads(num_heads: int, base: ModelConfig) -> int:
    """Largest divisor of ``num_heads`` preserving (roughly) the
    tier's GQA ratio — head grouping must stay exact."""
    if base.num_kv_heads <= 0:
        return 0
    want = max(1, round(num_heads * base.num_kv_heads
                        / max(1, base.num_heads)))
    return max(d for d in range(1, num_heads + 1)
               if num_heads % d == 0 and d <= want)


def zoo_config(kind: str, scale: float = 0.25) -> ModelConfig:
    """Scaled-tier config for zoo member ``kind`` at width multiplier
    ``scale`` (1.0 = the published tier's shape)."""
    tier = ZOO_TIERS.get(kind)
    if tier is None:
        known = ", ".join(f"zoo:{k}" for k in sorted(ZOO_TIERS))
        raise ValueError(f"unknown zoo member {kind!r} "
                         f"(known: {known})")
    base = tier()
    s = float(scale)
    d_model = _mult(base.d_model * s, 64, 64)
    num_heads = max(1, min(base.num_heads, d_model // 64))
    return dataclasses.replace(
        base,
        name=f"zoo-{kind}-x{s:g}",
        d_model=d_model,
        vocab_size=_mult(base.vocab_size * s * s, 64, 256),
        num_groups=max(1, round(base.num_groups * s)),
        num_heads=num_heads,
        num_kv_heads=_scaled_kv_heads(num_heads, base),
        head_dim=d_model // num_heads,
        d_ff=_mult(base.d_ff * s, 64, 64) if base.d_ff else 0,
        # training knobs, not family shape: f32 params ride the slab
        # plane's bitwise contract, tied embeddings halve the dominant
        # table, remat is pointless at these sizes
        tie_embeddings=True, dtype="float32", param_dtype="float32",
        remat="none", source="repro.models.zoo")


def num_params(params) -> int:
    import jax
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def _data(seed: int, n: int, seq: int, vocab: int):
    import numpy as np
    rng = np.random.default_rng(seed)
    x = rng.integers(0, vocab, (n, seq)).astype(np.int32)
    y = ((x + 1) % vocab).astype(np.int32)
    n_test = max(1, n // 8)
    return (x[n_test:], y[n_test:], x[:n_test], y[:n_test])


def zoo_workload(spec):
    """``SIM_WORKLOADS`` builder for ``spec.arch == "zoo:<kind>"``:
    the shared registry contract — ``(loss_fn, init_params, (x_tr,
    y_tr, x_te, y_te), accuracy_fn)`` with ``loss_fn(p, x, y)``
    scalar."""
    import jax
    import jax.numpy as jnp

    from repro.models import model as M

    kind = spec.arch.split(":", 1)[1]
    cfg = zoo_config(kind, getattr(spec, "zoo_scale", 0.25))
    n = 256 if spec.smoke else 2_048
    x_tr, y_tr, x_te, y_te = _data(spec.seed, n, ZOO_SEQ,
                                   cfg.vocab_size)
    params = M.init_params(jax.random.PRNGKey(spec.seed), cfg)

    def loss(p, x, y):
        return M.loss_fn(p, {"tokens": x, "labels": y}, cfg)[0]

    def _acc(p, x, y):
        logits, _ = M.forward(p, {"tokens": x}, cfg)
        preds = jnp.argmax(logits, axis=-1)
        return jnp.mean((preds == y).astype(jnp.float32))

    return loss, params, (x_tr, y_tr, x_te, y_te), jax.jit(_acc)
