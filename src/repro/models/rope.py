"""Rotary position embeddings."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return inv  # (half,)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (half,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
