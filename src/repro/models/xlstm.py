"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM uses a stabilized *chunkwise-parallel* formulation (linear-attention
style): an outer lax.scan carries (C, n, m) across chunks; within a chunk
the update is dense matmuls — MXU-friendly, with fp32 stabilizer state.
sLSTM has recurrent gate connections and is inherently sequential: a
lax.scan over time with block-diagonal (per-head) recurrent weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.sharding import lconstraint

MLSTM_CHUNK = 64


# ================================================================= mLSTM

def init_mlstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = int(cfg.xlstm_proj_factor * d)
    H = cfg.num_heads
    dh = di // H
    ks = jax.random.split(key, 8)
    s, si = d ** -0.5, di ** -0.5
    return {
        "w_up": (jax.random.normal(ks[0], (d, di)) * s).astype(dtype),
        "w_z": (jax.random.normal(ks[1], (d, di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.xlstm_conv, di))
                   * cfg.xlstm_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "lq": (jax.random.normal(ks[3], (di, H, dh)) * si).astype(dtype),
        "lk": (jax.random.normal(ks[4], (di, H, dh)) * si).astype(dtype),
        "lv": (jax.random.normal(ks[5], (di, H, dh)) * si).astype(dtype),
        # scalar input/forget gates per head
        "w_if": (jax.random.normal(ks[6], (di, H, 2)) * si).astype(jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H, 1)), jnp.full((H, 1), 3.0)],
                                axis=-1).astype(jnp.float32),
        "gn_scale": jnp.ones((H, dh), jnp.float32),
        "w_down": (jax.random.normal(ks[7], (di, d)) * si).astype(dtype),
    }


def _mlstm_qkv_gates(params, x, cfg, conv_state=None):
    u = jnp.einsum("bsd,de->bse", x, params["w_up"])
    z = jnp.einsum("bsd,de->bse", x, params["w_z"])
    dc = cfg.xlstm_conv
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], dc - 1, u.shape[-1]), u.dtype)
    up = jnp.concatenate([conv_state, u], axis=1)
    xc = sum(up[:, i:i + u.shape[1]] * params["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(xc + params["conv_b"])
    new_conv = up[:, -(dc - 1):]
    q = jnp.einsum("bse,ehk->bshk", xc, params["lq"])
    k = jnp.einsum("bse,ehk->bshk", xc, params["lk"])
    v = jnp.einsum("bse,ehk->bshk", u, params["lv"])
    gates = jnp.einsum("bse,ehg->bshg", xc.astype(jnp.float32), params["w_if"]) \
        + params["b_if"]
    li = gates[..., 0]                       # log input gate (B,S,H)
    lf = jax.nn.log_sigmoid(gates[..., 1])   # log forget gate
    return q, k, v, li, lf, z, new_conv


def _headnorm(h, scale, eps=1e-5):
    """Per-head RMS norm over dh.  h: (..., H, dh) fp32."""
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    return h * jax.lax.rsqrt(var + eps) * scale


def _mlstm_chunk(carry, inp, dh):
    """One chunk.  carry: (C (B,H,dh,dh), n (B,H,dh), m (B,H)) fp32.
    inp: q,k,v (B,c,H,dh), li,lf (B,c,H)."""
    C0, n0, m0 = carry
    q, k, v, li, lf = inp
    q = q.astype(jnp.float32) * dh ** -0.5
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    b = jnp.cumsum(lf, axis=1)                                   # (B,c,H)
    # intra-chunk log weights: D[t,s] = b_t - b_s + li_s  (s<=t)
    ld = b[:, :, None, :] - b[:, None, :, :] + li[:, None, :, :]  # (B,t,s,H)
    c = q.shape[1]
    tri = jnp.tril(jnp.ones((c, c), bool))
    ld = jnp.where(tri[None, :, :, None], ld, -jnp.inf)
    m_intra = jnp.max(ld, axis=2)                                # (B,t,H)
    m_inter = b + m0[:, None, :]
    m_t = jnp.maximum(m_inter, m_intra)
    m_t = jnp.maximum(m_t, -30.0)

    Dw = jnp.exp(ld - m_t[:, :, None, :])                        # (B,t,s,H)
    qk = jnp.einsum("bthd,bshd->btsh", q, k)
    w = Dw * qk
    h_intra = jnp.einsum("btsh,bshd->bthd", w, v)
    inter_scale = jnp.exp(m_inter - m_t)                          # (B,t,H)
    h_inter = jnp.einsum("bthd,bhde->bthe", q, C0) * inter_scale[..., None]
    n_inter = jnp.einsum("bthd,bhd->bth", q, n0) * inter_scale
    n_intra = jnp.sum(w, axis=2)                                  # Σ_s Dw·qk
    h = h_intra + h_inter
    n = n_intra + n_inter
    denom = jnp.maximum(jnp.abs(n), jnp.exp(-m_t))[..., None]
    out = h / denom                                               # (B,c,H,dh)

    # ---- end-of-chunk state
    bc = b[:, -1, :]                                              # (B,H)
    m_state = jnp.maximum(bc + m0, jnp.max(bc[:, None] - b + li, axis=1))
    m_state = jnp.maximum(m_state, -30.0)
    sw = jnp.exp(bc[:, None] - b + li - m_state[:, None])         # (B,c,H)
    C_new = jnp.exp(bc + m0 - m_state)[:, :, None, None] * C0 \
        + jnp.einsum("bch,bchd,bche->bhde", sw, k, v)
    n_new = jnp.exp(bc + m0 - m_state)[:, :, None] * n0 \
        + jnp.einsum("bch,bchd->bhd", sw, k)
    return (C_new, n_new, m_state), out


def mlstm_forward(params, x, cfg: ModelConfig):
    B, S, D = x.shape
    H = cfg.num_heads
    di = int(cfg.xlstm_proj_factor * D)
    dh = di // H
    q, k, v, li, lf, z, _ = _mlstm_qkv_gates(params, x, cfg)
    c = min(MLSTM_CHUNK, S)
    assert S % c == 0
    n = S // c
    resh = lambda t: t.reshape(B, n, c, *t.shape[2:]).swapaxes(0, 1)
    carry = (jnp.zeros((B, H, dh, dh), jnp.float32),
             jnp.zeros((B, H, dh), jnp.float32),
             jnp.zeros((B, H), jnp.float32))
    chunk_fn = lambda cr, inp: _mlstm_chunk(cr, inp, dh)
    if cfg.remat != "none":
        chunk_fn = jax.checkpoint(chunk_fn)
    (_, _, _), outs = jax.lax.scan(
        chunk_fn, carry,
        (resh(q), resh(k), resh(v), resh(li), resh(lf)))
    h = outs.swapaxes(0, 1).reshape(B, S, H, dh)
    h = _headnorm(h, params["gn_scale"]).reshape(B, S, di).astype(x.dtype)
    y = h * jax.nn.silu(z)
    y = lconstraint(y, ("batch", "seq", "inner"))
    return jnp.einsum("bse,ed->bsd", y, params["w_down"])


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype):
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    dh = di // H
    return {
        "conv": jnp.zeros((batch, cfg.xlstm_conv - 1, di), dtype),
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -30.0, jnp.float32),
    }


def mlstm_decode(params, x, cache, cfg: ModelConfig):
    """x: (B, 1, D) -> (y, cache). Recurrent mLSTM step."""
    H = cfg.num_heads
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    dh = di // H
    q, k, v, li, lf, z, conv = _mlstm_qkv_gates(params, x, cfg, cache["conv"])
    q = q[:, 0].astype(jnp.float32) * dh ** -0.5
    k = k[:, 0].astype(jnp.float32)
    v = v[:, 0].astype(jnp.float32)
    li, lf = li[:, 0], lf[:, 0]                                    # (B,H)
    m_new = jnp.maximum(lf + cache["m"], li)
    m_new = jnp.maximum(m_new, -30.0)
    fdec = jnp.exp(lf + cache["m"] - m_new)[:, :, None]
    iexp = jnp.exp(li - m_new)[:, :, None]
    # C[d, e] = k_d v_e — same layout as the chunkwise state update
    C = fdec[..., None] * cache["C"] + iexp[..., None] * k[:, :, :, None] \
        * v[:, :, None, :]
    nst = fdec * cache["n"] + iexp * k
    num = jnp.einsum("bhde,bhd->bhe", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", nst, q)),
                      jnp.exp(-m_new))[..., None]
    h = _headnorm(num / den, params["gn_scale"])
    h = h.reshape(x.shape[0], 1, di).astype(x.dtype)
    y = h * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["w_down"])
    return out, {"conv": conv, "C": C, "n": nst, "m": m_new}


# ================================================================= sLSTM

def init_slstm(key, cfg: ModelConfig, dtype):
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    # gates: z, i, f, o
    return {
        "w_x": (jax.random.normal(ks[0], (d, 4, d)) * s).astype(jnp.float32),
        "r_h": (jax.random.normal(ks[1], (H, dh, 4, dh)) * dh ** -0.5
                ).astype(jnp.float32),
        "b": jnp.zeros((4, d), jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        "w_up": (jax.random.normal(ks[2], (d, int(4 * d / 3))) * s).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (int(4 * d / 3), d))
                   * (4 * d / 3) ** -0.5).astype(dtype),
    }


def _slstm_step(params, xg, state, H, dh):
    """xg: (B, 4, d) pre-computed W_x x + b; state: (c,n,m,h) each (B,d)."""
    c0, n0, m0, h0 = state
    hh = h0.reshape(-1, H, dh)
    rec = jnp.einsum("bhd,hdge->bhge", hh, params["r_h"])
    g = xg + rec.reshape(xg.shape[0], 4, H * dh)
    z = jnp.tanh(g[:, 0])
    li = g[:, 1]
    lf = jax.nn.log_sigmoid(g[:, 2])
    o = jax.nn.sigmoid(g[:, 3])
    m1 = jnp.maximum(lf + m0, li)
    m1 = jnp.maximum(m1, -30.0)
    fdec = jnp.exp(lf + m0 - m1)
    iexp = jnp.exp(li - m1)
    c1 = fdec * c0 + iexp * z
    n1 = fdec * n0 + iexp
    h1 = o * c1 / jnp.maximum(n1, 1e-6)
    return (c1, n1, m1, h1)


def slstm_forward(params, x, cfg: ModelConfig):
    B, S, D = x.shape
    H = cfg.num_heads
    dh = D // H
    xg = jnp.einsum("bsd,dge->bsge", x.astype(jnp.float32), params["w_x"]) \
        + params["b"]

    def body(state, xg_t):
        new = _slstm_step(params, xg_t, state, H, dh)
        return new, new[3]

    zeros = jnp.zeros((B, D), jnp.float32)
    init = (zeros, zeros, jnp.full((B, D), -30.0), zeros)
    _, hs = jax.lax.scan(body, init, xg.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)                                         # (B,S,D)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = (h * jax.lax.rsqrt(var + 1e-5) * params["gn_scale"]).astype(x.dtype)
    up = jnp.einsum("bsd,de->bse", h, params["w_up"])
    return jnp.einsum("bse,ed->bsd", jax.nn.gelu(up), params["w_down"])


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, d), -30.0, jnp.float32),
            "h": z}


def slstm_decode(params, x, cache, cfg: ModelConfig):
    B, _, D = x.shape
    H = cfg.num_heads
    dh = D // H
    xg = jnp.einsum("bsd,dge->bsge", x.astype(jnp.float32), params["w_x"])[:, 0] \
        + params["b"]
    c1, n1, m1, h1 = _slstm_step(
        params, xg, (cache["c"], cache["n"], cache["m"], cache["h"]), H, dh)
    var = jnp.mean(jnp.square(h1), axis=-1, keepdims=True)
    h = (h1 * jax.lax.rsqrt(var + 1e-5) * params["gn_scale"]).astype(x.dtype)
    up = jnp.einsum("bd,de->be", h, params["w_up"])
    y = jnp.einsum("be,ed->bd", jax.nn.gelu(up), params["w_down"])[:, None]
    return y, {"c": c1, "n": n1, "m": m1, "h": h1}
