"""The language/encoder model: embedding → scanned block groups → head.

Layers are grouped into `cfg.num_groups` identical repeating groups; group
parameters are stacked along a leading axis and the groups are traversed
with `lax.scan`, so the lowered HLO is depth-independent (an 80-layer
qwen1.5-110b compiles as fast as a 2-layer smoke model).  The scan body is
optionally rematerialised (`cfg.remat == "block"`).

Modality frontends (audio conv codec / vision tower) are stubs per the
assignment: `input_specs` feeds precomputed frame/patch embeddings and the
model owns only the learned projection into d_model.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.blocks import (init_layer, init_layer_cache, layer_decode,
                                 layer_forward)
from repro.models.config import ModelConfig
from repro.models.norms import apply_norm, init_norm
from repro.parallel.sharding import lconstraint


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------ init

def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    cfg.validate()
    dtype = _dtype(cfg)
    k_emb, k_groups, k_head, k_fe = jax.random.split(key, 4)
    params: Dict[str, Any] = {}

    if cfg.frontend != "audio":
        params["embed"] = (jax.random.normal(
            k_emb, (cfg.vocab_size, cfg.d_model)) * cfg.d_model ** -0.5
        ).astype(dtype)
    if cfg.frontend is not None:
        k1, k2 = jax.random.split(k_fe)
        fd = cfg.frontend_dim
        params["frontend_proj"] = {
            "w1": (jax.random.normal(k1, (fd, cfg.d_model)) * fd ** -0.5
                   ).astype(dtype),
            "w2": (jax.random.normal(k2, (cfg.d_model, cfg.d_model))
                   * cfg.d_model ** -0.5).astype(dtype),
        }

    group_keys = jax.random.split(k_groups, cfg.num_groups)

    def init_group(k):
        lk = jax.random.split(k, len(cfg.block_pattern))
        return tuple(
            init_layer(lk[j], mixer, ffn, cfg, dtype)
            for j, (mixer, ffn) in enumerate(cfg.block_pattern))

    params["groups"] = jax.vmap(init_group)(group_keys)
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model)
    if cfg.frontend == "audio" or not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size)) * cfg.d_model ** -0.5
        ).astype(dtype)
    return params


# ------------------------------------------------------------- embedding

def embed_inputs(params, batch: Dict[str, Any], cfg: ModelConfig):
    """Returns (x (B,S,D), positions (B,S))."""
    dtype = _dtype(cfg)
    if cfg.frontend == "audio":
        feats = batch["features"].astype(dtype)           # (B, S, fd)
        w = params["frontend_proj"]
        x = jnp.einsum("bsf,fd->bsd", feats, w["w1"])
        x = jnp.einsum("bsd,de->bse", jax.nn.gelu(x), w["w2"])
    elif cfg.frontend == "vision":
        img = batch["image_embeds"].astype(dtype)         # (B, N, fd)
        w = params["frontend_proj"]
        xi = jnp.einsum("bsf,fd->bsd", img, w["w1"])
        xi = jnp.einsum("bsd,de->bse", jax.nn.gelu(xi), w["w2"])
        xt = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = jnp.concatenate([xi, xt], axis=1)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = lconstraint(x, ("batch", "seq", None))
    return x, positions


# --------------------------------------------------------------- forward

def forward(params, batch: Dict[str, Any], cfg: ModelConfig,
            q_block: int = 512):
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    x, positions = embed_inputs(params, batch, cfg)

    def group_body(x, group_params):
        aux = jnp.zeros((), jnp.float32)
        for j, (mixer, ffn) in enumerate(cfg.block_pattern):
            x, a = layer_forward(group_params[j], x, mixer, ffn, cfg,
                                 positions, q_block=q_block)
            aux = aux + a
        return x, aux

    body = group_body
    if cfg.remat == "block":
        body = jax.checkpoint(group_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(x, gp):
        return body(x, gp)

    x, auxs = jax.lax.scan(scan_body, x, params["groups"])
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = lconstraint(logits, ("batch", "seq", "vocab"))
    return logits, jnp.sum(auxs)


def loss_fn(params, batch: Dict[str, Any], cfg: ModelConfig,
            q_block: int = 512):
    """Cross-entropy LM / masked-prediction loss.  Returns (loss, metrics)."""
    logits, aux = forward(params, batch, cfg, q_block=q_block)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        # loss only over the text region (image tokens are prefix)
        logits = logits[:, cfg.num_image_tokens:]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if "loss_mask" in batch:
        mask = batch["loss_mask"].astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    total = loss + cfg.router_aux_coef * aux
    return total, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------- decode

def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked per-group KV/state cache pytree."""
    dtype = _dtype(cfg)

    def one_group(_):
        return tuple(
            init_layer_cache(mixer, cfg, batch, max_seq, dtype)
            for (mixer, ffn) in cfg.block_pattern)

    caches = [one_group(g) for g in range(cfg.num_groups)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches) \
        if cfg.num_groups > 1 else jax.tree.map(lambda x: x[None], caches[0])


def decode_step(params, cache, tokens, cur_index, cfg: ModelConfig):
    """One-token decode.  tokens: (B, 1) int32; cur_index: scalar int32
    (number of tokens already in the cache).  Returns (logits, new_cache)."""
    dtype = _dtype(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = lconstraint(x, ("batch", "seq", None))

    def scan_body(x, xs):
        gp, gcache = xs
        new_caches = []
        for j, (mixer, ffn) in enumerate(cfg.block_pattern):
            x, nc = layer_decode(gp[j], x, gcache[j], cur_index, mixer, ffn,
                                 cfg)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(scan_body, x, (params["groups"], cache))
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = lconstraint(logits, ("batch", "seq", "vocab"))
    return logits, new_cache
