"""DeepSeek-V2 Multi-head Latent Attention (MLA).

KV is compressed into a low-rank latent c_kv (kv_lora_rank) plus a single
shared RoPE key (rope_head_dim); per-head keys/values are re-expanded from
the latent.  The decode cache stores only (c_kv, k_rope) — the memory win
that defines MLA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import rowblock_attention, NEG_INF
from repro.models.config import ModelConfig
from repro.models.rope import apply_rope
from repro.parallel.sharding import lconstraint


def init_mla(key, cfg: ModelConfig, dtype):
    d, H = cfg.d_model, cfg.num_heads
    hd, r, rh = cfg.resolved_head_dim, cfg.kv_lora_rank, cfg.rope_head_dim
    vh = cfg.resolved_v_head_dim
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        # query: nope part + rope part per head
        "wq": (jax.random.normal(ks[0], (d, H, hd + rh)) * s).astype(dtype),
        # kv down-projection to latent
        "w_dkv": (jax.random.normal(ks[1], (d, r)) * s).astype(dtype),
        # shared rope key
        "w_kr": (jax.random.normal(ks[2], (d, rh)) * s).astype(dtype),
        # up-projections latent -> per-head k_nope / v
        "w_uk": (jax.random.normal(ks[3], (r, H, hd)) * r ** -0.5).astype(dtype),
        "w_uv": (jax.random.normal(ks[4], (r, H, vh)) * r ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[5], (H, vh, d)) * (H * vh) ** -0.5).astype(dtype),
    }


def _latent(params, x, positions, cfg: ModelConfig):
    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    k_rope = jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)  # (B,S,1,rh)
    return c_kv, k_rope[:, :, 0, :]


def _queries(params, x, positions, cfg: ModelConfig):
    hd, rh = cfg.resolved_head_dim, cfg.rope_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,H,hd+rh)


def _expand_kv(params, c_kv, k_rope, H):
    """latent (B,S,r), k_rope (B,S,rh) -> k (B,S,H,hd+rh), v (B,S,H,vh)."""
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (*k_nope.shape[:2], H, k_rope.shape[-1]))
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    return k, v


def mla_forward(params, x, cfg: ModelConfig, positions, q_block: int = 512,
                global_layer: bool = False):
    B, S, _ = x.shape
    H = cfg.num_heads
    q = _queries(params, x, positions, cfg)
    c_kv, k_rope = _latent(params, x, positions, cfg)
    k, v = _expand_kv(params, c_kv, k_rope, H)
    q = lconstraint(q, ("batch", "seq", "heads", "head_dim"))
    k = lconstraint(k, ("batch", "seq", "heads", "head_dim"))
    v = lconstraint(v, ("batch", "seq", "heads", "head_dim"))
    out = rowblock_attention(q, k, v, positions, cfg, global_layer=True,
                             q_block=q_block)
    out = lconstraint(out, ("batch", "seq", "heads", "head_dim"))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return lconstraint(y, ("batch", "seq", None))


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.rope_head_dim), dtype),
    }


def mla_decode(params, x, cache, cur_index, cfg: ModelConfig):
    """One-token MLA decode from the latent cache."""
    B = x.shape[0]
    H, hd, rh = cfg.num_heads, cfg.resolved_head_dim, cfg.rope_head_dim
    positions = jnp.full((B, 1), cur_index, jnp.int32)
    q = _queries(params, x, positions, cfg)          # (B,1,H,hd+rh)
    c_new, kr_new = _latent(params, x, positions, cfg)
    # one-hot select: a DUS at a traced index into the sequence-sharded
    # cache makes GSPMD gather it (see attention_decode)
    L = cache["c_kv"].shape[1]
    hit = (jnp.arange(L) == cur_index)[None, :, None]
    c = jnp.where(hit, c_new.astype(cache["c_kv"].dtype), cache["c_kv"])
    kr = jnp.where(hit, kr_new.astype(cache["k_rope"].dtype),
                   cache["k_rope"])

    # absorbed attention: score = q_nope·(c W_uk) + q_rope·k_rope
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    # project q_nope into latent space: (B,1,H,r)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope.astype(jnp.float32),
                       params["w_uk"].astype(jnp.float32))
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat, c.astype(jnp.float32))
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                        kr.astype(jnp.float32))
    scores = (s_lat + s_rope) * ((hd + rh) ** -0.5)
    L = c.shape[1]
    valid = jnp.arange(L) <= cur_index
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    # out in latent space then up-project with W_uv
    o_lat = jnp.einsum("bhst,btr->bshr", w, c.astype(jnp.float32))
    out = jnp.einsum("bshr,rhk->bshk", o_lat,
                     params["w_uv"].astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"c_kv": c, "k_rope": kr}
