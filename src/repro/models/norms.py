"""Normalization layers (functional, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def init_norm(kind: str, dim: int, dtype=jnp.float32):
    return init_rmsnorm(dim, dtype) if kind == "rmsnorm" else init_layernorm(dim, dtype)


def apply_norm(kind: str, params, x, eps: float = 1e-5):
    return rmsnorm(params, x, eps) if kind == "rmsnorm" else layernorm(params, x, eps)
