"""Per-layer block assembly: (mixer, ffn) pairs with pre-norm residuals."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mla as mla_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import (ATTN, ATTN_GLOBAL, MAMBA, MLA, MLP, MLSTM,
                                 MOE, NONE, SLSTM, ModelConfig)
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.moe import init_moe, moe_forward
from repro.models.norms import apply_norm, init_norm

MIXER_INIT = {
    ATTN: attn_mod.init_attention,
    ATTN_GLOBAL: attn_mod.init_attention,
    MLA: mla_mod.init_mla,
    MAMBA: mamba_mod.init_mamba,
    MLSTM: xlstm_mod.init_mlstm,
    SLSTM: xlstm_mod.init_slstm,
}


def init_layer(key, mixer: str, ffn: str, cfg: ModelConfig, dtype):
    km, kf = jax.random.split(key)
    p: Dict[str, Any] = {
        "mixer_norm": init_norm(cfg.norm, cfg.d_model),
        "mixer": MIXER_INIT[mixer](km, cfg, dtype),
    }
    if ffn != NONE:
        p["ffn_norm"] = init_norm(cfg.norm, cfg.d_model)
        if ffn == MLP:
            p["ffn"] = init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
        else:
            p["ffn"] = init_moe(kf, cfg, dtype)
    return p


def layer_forward(p, x, mixer: str, ffn: str, cfg: ModelConfig, positions,
                  q_block: int = 512):
    """Full-sequence layer.  Returns (x, aux)."""
    h = apply_norm(cfg.norm, p["mixer_norm"], x, cfg.norm_eps)
    if mixer in (ATTN, ATTN_GLOBAL):
        h = attn_mod.attention_forward(p["mixer"], h, cfg, positions,
                                       global_layer=(mixer == ATTN_GLOBAL),
                                       q_block=q_block)
    elif mixer == MLA:
        h = mla_mod.mla_forward(p["mixer"], h, cfg, positions, q_block=q_block)
    elif mixer == MAMBA:
        h = mamba_mod.mamba_forward(p["mixer"], h, cfg)
    elif mixer == MLSTM:
        h = xlstm_mod.mlstm_forward(p["mixer"], h, cfg)
    elif mixer == SLSTM:
        h = xlstm_mod.slstm_forward(p["mixer"], h, cfg)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if ffn != NONE:
        h = apply_norm(cfg.norm, p["ffn_norm"], x, cfg.norm_eps)
        if ffn == MLP:
            h = mlp_forward(p["ffn"], h, cfg.mlp_act)
        else:
            h, aux = moe_forward(p["ffn"], h, cfg)
        x = x + h
    return x, aux


def init_layer_cache(mixer: str, cfg: ModelConfig, batch: int, max_seq: int,
                     dtype):
    if mixer == ATTN:
        return attn_mod.init_attn_cache(cfg, batch, max_seq, dtype,
                                        global_layer=False)
    if mixer == ATTN_GLOBAL:
        return attn_mod.init_attn_cache(cfg, batch, max_seq, dtype,
                                        global_layer=True)
    if mixer == MLA:
        return mla_mod.init_mla_cache(cfg, batch, max_seq, dtype)
    if mixer == MAMBA:
        return mamba_mod.init_mamba_cache(cfg, batch, dtype)
    if mixer == MLSTM:
        return xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
    if mixer == SLSTM:
        return xlstm_mod.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(mixer)


def layer_decode(p, x, cache, cur_index, mixer: str, ffn: str,
                 cfg: ModelConfig):
    """One-token layer step.  Returns (x, new_cache)."""
    h = apply_norm(cfg.norm, p["mixer_norm"], x, cfg.norm_eps)
    if mixer in (ATTN, ATTN_GLOBAL):
        h, cache = attn_mod.attention_decode(
            p["mixer"], h, cache, cur_index, cfg,
            global_layer=(mixer == ATTN_GLOBAL))
    elif mixer == MLA:
        h, cache = mla_mod.mla_decode(p["mixer"], h, cache, cur_index, cfg)
    elif mixer == MAMBA:
        h, cache = mamba_mod.mamba_decode(p["mixer"], h, cache, cfg)
    elif mixer == MLSTM:
        h, cache = xlstm_mod.mlstm_decode(p["mixer"], h, cache, cfg)
    elif mixer == SLSTM:
        h, cache = xlstm_mod.slstm_decode(p["mixer"], h, cache, cfg)
    x = x + h
    if ffn != NONE:
        h = apply_norm(cfg.norm, p["ffn_norm"], x, cfg.norm_eps)
        if ffn == MLP:
            h = mlp_forward(p["ffn"], h, cfg.mlp_act)
        else:
            h, _ = moe_forward(p["ffn"], h, cfg)
        x = x + h
    return x, cache
