"""Model configuration.

One config dataclass drives every assigned architecture: the per-layer
composition is declared as a *block pattern* — a repeating group of
(mixer, ffn) pairs — so a single scan-based decoder stack covers dense,
MoE, SSM, hybrid, audio and VLM backbones.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# Mixer kinds.
ATTN = "attn"          # softmax attention (GQA / MHA, optional SWA / chunked)
ATTN_GLOBAL = "attn_global"  # full attention even when cfg.sliding_window set
MLA = "mla"            # DeepSeek multi-head latent attention
MAMBA = "mamba"        # Mamba-1 selective SSM
MLSTM = "mlstm"        # xLSTM matrix-memory LSTM
SLSTM = "slstm"        # xLSTM scalar-memory LSTM

# FFN kinds.
MLP = "mlp"            # dense MLP (swiglu / gelu per cfg.mlp_act)
MOE = "moe"            # mixture-of-experts
NONE = "none"          # no FFN (xLSTM blocks carry their own projections)

SUBQUADRATIC_MIXERS = (MAMBA, MLSTM, SLSTM)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    vocab_size: int
    # Block pattern: a group of (mixer, ffn) pairs repeated `num_groups`
    # times.  total layers == len(block_pattern) * num_groups.
    block_pattern: Tuple[Tuple[str, str], ...]
    num_groups: int

    # ---- attention ----
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    attn_bias: bool = False             # qwen-style QKV bias
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None       # SWA width (h2o-danube)
    attn_chunk: Optional[int] = None           # llama4 chunked-local width
    causal: bool = True                 # False for encoder-only (hubert)
    attn_logit_softcap: Optional[float] = None

    # ---- MLA (deepseek) ----
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0                 # defaults to head_dim

    # ---- FFN ----
    d_ff: int = 0
    mlp_act: str = "swiglu"             # swiglu | gelu

    # ---- MoE ----
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                   # expert hidden size (may differ from d_ff)
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 512           # GShard dispatch group (tokens)
    router_aux_coef: float = 0.01

    # ---- mamba ----
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0              # 0 -> ceil(d_model/16)
    ssm_chunk: int = 256                # chunkwise scan length (memory fit)

    # ---- xlstm ----
    xlstm_proj_factor: float = 2.0      # block up-projection
    xlstm_conv: int = 4                 # causal conv width in mLSTM block

    # ---- embedding / head ----
    tie_embeddings: bool = False
    encoder_only: bool = False
    frontend: Optional[str] = None      # None | "audio" | "vision"
    frontend_dim: int = 0               # stub embedding dim (512 audio / 1024 clip)
    num_image_tokens: int = 0           # vlm: patch tokens prefixed to text

    # ---- numerics / memory ----
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"             # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "block"                # none | block (checkpoint scan bodies)

    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.block_pattern) * self.num_groups

    @property
    def kv_groups(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.num_heads))

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim or self.resolved_head_dim

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    @property
    def subquadratic(self) -> bool:
        """True if the model supports O(seq) decode memory at 500k context."""
        mixers = {m for m, _ in self.block_pattern}
        # hybrid archs (jamba): a minority of attention layers hold a full
        # cache but the dominant state is SSM; long-context capable with
        # the cache sharded over sequence.
        if mixers & set(SUBQUADRATIC_MIXERS):
            return True
        for m in mixers:
            if m in (ATTN, ATTN_GLOBAL):
                if self.sliding_window is None and self.attn_chunk is None:
                    return False
            if m == MLA:
                return False
        return True

    def validate(self) -> None:
        assert self.num_groups >= 1 and self.block_pattern
        for mixer, ffn in self.block_pattern:
            assert mixer in (ATTN, ATTN_GLOBAL, MLA, MAMBA, MLSTM, SLSTM), mixer
            assert ffn in (MLP, MOE, NONE), ffn
            if ffn == MOE:
                assert self.num_experts > 0 and self.num_experts_per_tok > 0
        if any(m in (ATTN, ATTN_GLOBAL) for m, _ in self.block_pattern):
            assert self.num_heads > 0 and self.num_kv_heads > 0
            assert self.num_heads % self.num_kv_heads == 0


def uniform_pattern(mixer: str, ffn: str, layers_per_group: int = 1
                    ) -> Tuple[Tuple[str, str], ...]:
    return tuple((mixer, ffn) for _ in range(layers_per_group))
