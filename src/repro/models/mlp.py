"""Dense MLP blocks: SwiGLU (llama-style) and GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.sharding import lconstraint


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    p = {
        "w_up": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in).astype(dtype)
    return p


def mlp_forward(params, x, act: str):
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    up = lconstraint(up, ("batch", "seq", "mlp"))
    if act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        gate = lconstraint(gate, ("batch", "seq", "mlp"))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    y = jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    return lconstraint(y, ("batch", "seq", None))
