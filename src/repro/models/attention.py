"""Softmax attention: GQA/MHA, optional QKV bias, RoPE, sliding-window and
chunked-local (llama4 iRoPE) variants, full-sequence and single-token decode
paths.

Full-sequence attention is computed *row-blockwise* (lax.scan over query
blocks, fp32 softmax) so 32k-token prefill never materialises a full
(S, S) score matrix.  SWA / chunked layers slice only the reachable KV slab
per query block, so their FLOPs are genuinely sub-quadratic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.rope import apply_rope
from repro.parallel.sharding import lconstraint

NEG_INF = -1e30


# ---------------------------------------------------------------- params

def init_attention(key, cfg: ModelConfig, dtype):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": (jax.random.normal(kq, (d, H, hd)) * scale).astype(dtype),
        "wk": (jax.random.normal(kk, (d, KV, hd)) * scale).astype(dtype),
        "wv": (jax.random.normal(kv, (d, KV, hd)) * scale).astype(dtype),
        "wo": (jax.random.normal(ko, (H, hd, d)) * (H * hd) ** -0.5).astype(dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def _project_qkv(params, x, cfg: ModelConfig, positions):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd), rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.attn_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = lconstraint(q, ("batch", "seq", "heads", "head_dim"))
    k = lconstraint(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = lconstraint(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


# ---------------------------------------------------------- mask helpers

def _mask_block(q_pos, k_pos, cfg: ModelConfig, global_layer: bool):
    """(qb,) x (kb,) -> bool (qb, kb), True = attend."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if cfg.causal:
        m = kp <= qp
    else:
        m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if not global_layer:
        if cfg.sliding_window is not None:
            m &= kp > (qp - cfg.sliding_window)
        if cfg.attn_chunk is not None:
            m &= (kp // cfg.attn_chunk) == (qp // cfg.attn_chunk)
    return m


def _sdpa_block(q, k, v, mask):
    """q (B,qb,H,hd), k/v (B,kb,KV,hd), mask (qb,kb) -> (B,qb,H,hd).

    GQA via kv-head *repeat* rather than regrouping q's head dim: the head
    dim is model-sharded and a (KV, group) reshape would force GSPMD to
    all-gather q around every attention block (measured 6×96 GiB/step on
    qwen2.5-32b train_4k — EXPERIMENTS.md §Perf it.1).  Repeating the
    replicated kv heads is communication-free and numerically identical.
    """
    B, qb, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows produce uniform weights over NEG_INF; zero them
    any_valid = jnp.any(mask, axis=-1)[None, None, :, None]
    w = jnp.where(any_valid, w, 0.0)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ------------------------------------------------------------- full-seq

def rowblock_attention(q, k, v, positions, cfg: ModelConfig,
                       global_layer: bool = False, q_block: int = 512):
    """Row-blockwise SDPA.  q (B,S,H,hd), k/v (B,S,KV,hd) -> (B,S,H,hd_v).

    lax.scan over query blocks; each block slices only the statically
    reachable KV slab (window / chunk), fp32 softmax inside the block.
    """
    B, S = q.shape[:2]
    if S <= q_block:
        m = _mask_block(positions[0], positions[0], cfg, global_layer)
        return _sdpa_block(q, k, v, m)

    assert S % q_block == 0, (S, q_block)
    n_blocks = S // q_block
    # Static KV slab size per query block.
    if not global_layer and cfg.attn_chunk is not None and cfg.attn_chunk < S:
        slab = max(cfg.attn_chunk, q_block)
    elif not global_layer and cfg.sliding_window is not None \
            and cfg.sliding_window + q_block < S:
        slab = cfg.sliding_window + q_block
    else:
        slab = S

    qs = q.reshape(B, n_blocks, q_block, *q.shape[2:])
    base_pos = positions[0]

    def body(_, i):
        qi = qs[:, i]
        q_pos = jax.lax.dynamic_slice_in_dim(base_pos, i * q_block, q_block)
        if slab == S:
            start = 0
        elif cfg.attn_chunk is not None and not global_layer:
            start = (i * q_block // cfg.attn_chunk) * cfg.attn_chunk
            start = jnp.minimum(start, S - slab)
        else:
            start = jnp.maximum(i * q_block + q_block - slab, 0)
        ki = jax.lax.dynamic_slice_in_dim(k, start, slab, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(v, start, slab, axis=1)
        k_pos = jax.lax.dynamic_slice_in_dim(base_pos, start, slab)
        m = _mask_block(q_pos, k_pos, cfg, global_layer)
        return None, _sdpa_block(qi, ki, vi, m)

    if cfg.remat != "none":
        # don't store per-block score matrices for backward — recompute
        body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, jnp.arange(n_blocks))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, q.shape[2], v.shape[3])


def attention_forward(params, x, cfg: ModelConfig, positions,
                      global_layer: bool = False, q_block: int = 512):
    """Full-sequence attention.  x: (B, S, D) -> (B, S, D)."""
    q, k, v = _project_qkv(params, x, cfg, positions)
    out = rowblock_attention(q, k, v, positions, cfg, global_layer, q_block)
    out = lconstraint(out, ("batch", "seq", "heads", "head_dim"))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return lconstraint(y, ("batch", "seq", None))


# ---------------------------------------------------------------- decode

def init_attn_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype,
                    global_layer: bool = False):
    """KV cache for one attention layer.

    SWA / chunked local layers use a ring buffer of the window size, so a
    500k-context danube decode holds only `window` keys per layer.
    """
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if not global_layer and cfg.sliding_window is not None:
        L = min(max_seq, cfg.sliding_window)
    elif not global_layer and cfg.attn_chunk is not None:
        L = min(max_seq, cfg.attn_chunk)
    else:
        L = max_seq
    return {
        "k": jnp.zeros((batch, L, KV, hd), dtype),
        "v": jnp.zeros((batch, L, KV, hd), dtype),
    }


def attention_decode(params, x, cache, cur_index, cfg: ModelConfig,
                     global_layer: bool = False):
    """One-token decode.  x: (B, 1, D); cur_index: scalar int32 (tokens so
    far).  Returns (y, new_cache)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), cur_index, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.attn_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    L = cache["k"].shape[1]
    slot = jnp.mod(cur_index, L)          # ring for SWA/chunked; linear else
    # one-hot select instead of dynamic-update-slice: a DUS at a traced
    # index into the sequence-sharded cache makes GSPMD gather the whole
    # cache (measured 16 GiB/token on jamba long_500k); the where-update
    # partitions cleanly (EXPERIMENTS.md §Perf pair B).
    hit = (jnp.arange(L) == slot)[None, :, None, None]
    ck = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])

    # positions held in each cache slot (ring-aware)
    slots = jnp.arange(L)
    wraps = (cur_index - slots + L) // L            # how many writes ahead
    slot_pos = cur_index - jnp.mod(cur_index - slots, L)
    valid = (slot_pos >= 0) & (slot_pos <= cur_index)
    if not global_layer and cfg.sliding_window is not None:
        valid &= slot_pos > cur_index - cfg.sliding_window
    if not global_layer and cfg.attn_chunk is not None:
        valid &= (slot_pos // cfg.attn_chunk) == (cur_index // cfg.attn_chunk)

    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    H = cfg.num_heads
    g = H // KV
    # Decode-side GQA groups *q* (one token, replicated — the reshape is
    # free) and leaves the big sequence-sharded cache untouched: repeating
    # the cache's head dim makes GSPMD re-lay-out the 500k-deep cache
    # (EXPERIMENTS.md §Perf pair B).  The train path does the opposite
    # (repeat kv) because there q is the model-sharded big tensor.
    qg = q.reshape(B, 1, KV, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * (hd ** -0.5)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, cv.astype(jnp.float32))
    out = out.reshape(B, 1, H, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": ck, "v": cv}
