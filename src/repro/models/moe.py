"""Mixture-of-Experts FFN (GShard/t5x-style grouped masked dispatch).

Tokens are partitioned into fixed-size *groups* (`cfg.moe_group_size`,
default 512); each group dispatches into per-expert capacity buffers with
one-hot einsums.  Grouping bounds both the dispatch tensor
(groups × Sg × E × C) and the dispatch FLOPs at O(tokens · E · C · d) with
C = Sg·k·cf/E — without grouping, capacity scales with the full token
count and masked dispatch degenerates to O(T²·d) (measured: 20× the FFN
FLOPs on deepseek prefill_32k).  Groups are batch-like and shard over the
(pod, data) axes; the expert axis shards over `model` (expert parallelism
— the dispatch/combine einsums lower to all-to-alls on the mesh).

Shared experts (DeepSeek / llama4) run densely on every token.  Returns
the Switch/GShard load-balance auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.mlp import init_mlp, mlp_forward
from repro.parallel.sharding import lconstraint


def init_moe(key, cfg: ModelConfig, dtype):
    d, E = cfg.d_model, cfg.num_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    kr, ke, ks = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, ff ** -0.5
    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "router": (jax.random.normal(kr, (d, E)) * s_in).astype(jnp.float32),
        "experts": {
            "w_gate": (jax.random.normal(k1, (E, d, ff)) * s_in).astype(dtype),
            "w_up": (jax.random.normal(k2, (E, d, ff)) * s_in).astype(dtype),
            "w_down": (jax.random.normal(k3, (E, ff, d)) * s_out).astype(dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks, d, ff * cfg.num_shared_experts, "swiglu",
                               dtype)
    return p


def _group_size(cfg: ModelConfig, T: int) -> int:
    sg = getattr(cfg, "moe_group_size", 512) or 512
    if T % sg:
        sg = T            # tiny batches (decode): one group
    return min(sg, T)


def _capacity(sg: int, cfg: ModelConfig) -> int:
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = int(sg * k * cfg.moe_capacity_factor / E)
    cap = max(cap, k, 4)
    return ((cap + 3) // 4) * 4


def moe_forward(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    E, topk = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    sg = _group_size(cfg, T)
    G = T // sg
    xg = x.reshape(G, sg, D)
    xg = lconstraint(xg, ("batch", None, None))

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (G,sg,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, topk)            # (G,sg,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- load-balance aux (Switch): E * sum_e f_e * p_e  (global means)
    me = jnp.mean(probs, axis=(0, 1))
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)     # (G,sg,k,E)
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # ---- capacity-limited positions within each group's expert queue
    C = _capacity(sg, cfg)
    flat = onehot.reshape(G, sg * topk, E)
    pos = jnp.cumsum(flat, axis=1) - flat                        # (G,sg*k,E)
    pos_in_e = jnp.sum(pos * flat, axis=-1).reshape(G, sg, topk)
    keep = pos_in_e < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, C), C + 1,
                            dtype=jnp.float32)[..., :C]          # (G,sg,k,C)
    masked_oh = onehot * keep[..., None]
    dispatch = jnp.einsum("gske,gskc->gsec", masked_oh, pos_oh)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot, pos_oh, gate_vals)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg.astype(jnp.float32))
    xe = xe.astype(x.dtype)
    xe = lconstraint(xe, ("batch", "experts", None, None))

    ep = params["experts"]
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, ep["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, ep["w_up"])
    h = lconstraint(h, ("batch", "experts", None, None))
    ye = jnp.einsum("gecf,efd->gecd", h, ep["w_down"])
    ye = lconstraint(ye, ("batch", "experts", None, None))

    y = jnp.einsum("gsec,gecd->gsd", combine, ye.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(B, S, D)

    if "shared" in params:
        y = y + mlp_forward(params["shared"], x, "swiglu")
    return lconstraint(y, ("batch", "seq", None)), aux
