"""Production meshes.

Functions, not module constants — importing this module never touches JAX
device state.  Target: TPU v5e, 256 chips/pod (16×16), 2 pods multi-pod.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Small mesh over whatever local devices exist (tests / examples)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_hybrid_mesh(rep: int, *, multi_pod: bool = False):
    """Factor the production mesh's (pod×)data axis into (rep, data/rep).

    Used by the group-annealed hybrid phases: replica groups live on the
    ``rep`` axis.  rep divides pod*data; the pod axis is consumed first so
    small groups never cross pods (cheap early flushes — see DESIGN §2.2).
    """
    pods = 2 if multi_pod else 1
    data_total = pods * 16
    assert data_total % rep == 0, (rep, data_total)
    devices = np.asarray(jax.devices()[:pods * 256]).reshape(
        rep, data_total // rep, 16)
    return Mesh(devices, ("rep", "data", "model"))


# --------------------------------------------------- hardware constants
# TPU v5e per chip (roofline constants per the assignment)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
HBM_PER_CHIP = 16 * 2 ** 30     # bytes
