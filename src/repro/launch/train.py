"""SPMD training driver (the ``spmd`` backend of ``repro.api``).

Modes:
  * ``sync``   — standard fully-synchronous data parallelism (the paper's
                 synchronous baseline; also the hybrid schedule's endpoint);
  * ``async``  — group size 1 throughout (per-device local SGD, the SPMD
                 analogue of the asynchronous baseline);
  * ``hybrid`` — the Smooth Switch: reduction-group size annealed by the
                 threshold schedule, replicas merged at phase switches.

The engine is :func:`run_training`, which consumes a declarative
:class:`repro.api.ExperimentSpec` (the same spec the simulator backend
consumes) and returns ``(params, history)``.  The legacy keyword surface
:func:`train` remains as a deprecation shim.

Runs on whatever devices exist (CPU tests use
XLA_FLAGS=--xla_force_host_platform_device_count=8); the same code drives
the production mesh.

Example (the end-to-end driver; equivalently ``python -m repro run
--backend spmd ...``):
  python -m repro.launch.train --arch xlstm-350m --smoke --steps 200 \
      --mode hybrid --schedule step:30
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.configs.registry import ARCH_NAMES, get_config, smoke_variant
from repro.core.spmd_hybrid import (build_phases, make_replica_step,
                                    merge_replicas_slab, replica_divergence,
                                    replica_param_shardings,
                                    replicate_params, reshard_replicas)
from repro.data.synthetic import token_stream
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import adamw, momentum, sgd
from repro.parallel.partition import param_shardings
from repro.parallel.sharding import axis_rules


def build_hybrid_mesh(rep: int, model: int = 1) -> Mesh:
    n = jax.device_count()
    assert n % (rep * model) == 0, (n, rep, model)
    devices = np.asarray(jax.devices()).reshape(rep, n // (rep * model),
                                                model)
    return Mesh(devices, ("rep", "data", "model"))


def _shard_batch_R(batch, mesh, R):
    def f(x):
        x = np.asarray(x)
        x = x.reshape(R, x.shape[0] // R, *x.shape[1:])
        return jax.device_put(x, NamedSharding(
            mesh, P("rep", "data", *([None] * (x.ndim - 2)))))
    return jax.tree.map(f, batch)


def run_training(spec, ckpt_dir: Optional[str] = None,
                 out_json: Optional[str] = None, verbose: bool = True):
    """Run the SPMD driver from an :class:`repro.api.ExperimentSpec`.

    Returns ``(params_final, history, stats)`` where ``history`` is the
    logged list of per-step metric dicts and ``stats`` carries the
    driver's exact counters (``num_updates``, ``num_gradients`` — one
    gradient per replica per executed step, accumulated as the steps
    run, not reconstructed from the log_every-thinned history).
    ``repro.api.SpmdTrainer`` adapts all of it into the unified
    ``RunResult``.
    """
    from repro.api.schedules import parse_schedule

    cfg = get_config(spec.arch)
    if spec.smoke:
        cfg = dataclasses.replace(smoke_variant(cfg), name=cfg.name)
    assert cfg.frontend is None, "train driver uses token streams"

    n_dev = jax.device_count()
    if n_dev % spec.mesh_model != 0:
        raise ValueError(f"mesh_model={spec.mesh_model} must divide the "
                         f"device count ({n_dev})")
    data_axis = n_dev // spec.mesh_model
    # the per-replica optimizer comes from the spec — the same
    # optimizer/beta1/beta2/weight_decay fields the server-side slab
    # optimizer reads, so one spec names the update rule on every
    # backend.  (Historically this driver hard-coded AdamW; pass
    # optimizer="adamw" for that behavior.)
    if spec.optimizer == "adamw":
        opt = adamw(spec.lr, b1=spec.beta1, b2=spec.beta2,
                    weight_decay=spec.weight_decay)
    elif spec.optimizer == "momentum":
        opt = momentum(spec.lr, beta=spec.beta1)
    else:
        opt = sgd(spec.lr)
    stream = token_stream(spec.seed, cfg.vocab_size, spec.batch, spec.seq)

    # --- schedule -> group-size phases
    if spec.mode == "sync":
        phases = [(0, data_axis)]
    elif spec.mode == "async":
        phases = [(0, 1)]
    else:
        sched = parse_schedule(spec.schedule, data_axis)
        phases = [(p.t_start, p.group_size)
                  for p in build_phases(sched, spec.steps, data_axis)]

    params = M.init_params(jax.random.PRNGKey(spec.seed), cfg)

    def loss_fn(p, b):
        return M.loss_fn(p, b, cfg)

    def opt_update(grads, state, p):
        return opt.update(grads, state, p)

    history = []
    t0 = time.time()
    tokens_done = 0
    grads_done = 0
    params_R = None
    step = 0
    steps = spec.steps

    for idx, (t_start, g) in enumerate(phases):
        t_end = phases[idx + 1][0] if idx + 1 < len(phases) else steps
        R = max(1, data_axis // g)
        if params_R is None:
            host_R = replicate_params(jax.device_get(params), R)
        else:
            # Phase switch (the paper's buffer flush): merge replicas and
            # change the group factor.  Done host-side — the device arrays
            # are fetched, merged/resharded outside the mesh, and re-placed
            # under the new mesh.  This keeps exactly one SPMD executable
            # alive per phase (XLA-CPU's in-process communicator deadlocks
            # if modules with collectives interleave; on TPU this is one
            # host-sync per phase, a handful per run).  The merge itself
            # routes through the slab aggregation path — the same fused
            # flush the parameter server applies.
            host = jax.device_get(params_R)
            host = merge_replicas_slab(host, alpha=spec.merge_alpha)
            host_R = reshard_replicas(host, R)
        mesh = build_hybrid_mesh(R, spec.mesh_model)
        with axis_rules(mesh):
            p_sh = replica_param_shardings(params, mesh)
            params_R = jax.device_put(host_R, p_sh)
            opt_R = jax.jit(jax.vmap(opt.init))(params_R)
            jax.block_until_ready((params_R, opt_R))
            replica_step = make_replica_step(loss_fn, opt_update)
            step_fn = jax.jit(replica_step, donate_argnums=(0, 1))

            while step < t_end:
                b = next(stream)
                b_R = _shard_batch_R(b, mesh, R)
                params_R, opt_R, metrics = step_fn(params_R, opt_R, b_R)
                tokens_done += spec.batch * spec.seq
                grads_done += R     # one gradient per replica this step
                if step % spec.log_every == 0 or step == t_end - 1:
                    div = float(metrics["divergence"]) if R > 1 else 0.0
                    # the executable reports its own replica axis; it must
                    # agree with the R this phase launched
                    assert int(metrics["replicas"]) == R, \
                        (int(metrics["replicas"]), R)
                    rec = {"step": step, "group_size": g, "replicas": R,
                           "loss": float(metrics["loss"]),
                           "divergence": div,
                           "wall_s": round(time.time() - t0, 2),
                           "tokens": tokens_done}
                    history.append(rec)
                    if verbose:
                        print(f"step {step:5d}  g={g:3d} R={R:3d} "
                              f"loss={rec['loss']:.4f} div={div:.3e}",
                              flush=True)
                step += 1

            jax.block_until_ready((params_R, opt_R))
            if ckpt_dir:
                merged = merge_replicas_slab(jax.device_get(params_R))
                one = jax.tree.map(lambda x: np.asarray(x[0]), merged)
                save_checkpoint(os.path.join(ckpt_dir, f"step_{step}"),
                                one, step, extra={"arch": spec.arch,
                                                  "mode": spec.mode})

    # final merge for the returned model
    params_final = jax.tree.map(lambda x: np.asarray(x[0]),
                                merge_replicas_slab(jax.device_get(params_R)))
    stats = {"num_updates": step, "num_gradients": grads_done}
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"arch": spec.arch, "mode": spec.mode,
                       "spec": spec.to_dict(), "stats": stats,
                       "history": history}, f, indent=2)
    return params_final, history, stats


def _legacy_schedule_spec(schedule_kind: str, step_size: int,
                          steps: int) -> str:
    """Map the old (schedule_kind, step_size) kwargs onto a spec string —
    the branch the old driver hard-coded (``step`` took a step size while
    every other family took the step horizon)."""
    if schedule_kind == "step":
        return f"step:{step_size}"
    return f"{schedule_kind}:horizon={steps}"


def train(arch: str, steps: int, mode: str, batch: int, seq: int,
          lr: float, schedule_kind: str, step_size: int, smoke: bool,
          merge_alpha: float = 1.0, log_every: int = 10,
          ckpt_dir: Optional[str] = None, seed: int = 0,
          out_json: Optional[str] = None):
    """Deprecated keyword surface; use ``repro.api`` (ExperimentSpec ->
    run()) or :func:`run_training` directly."""
    from repro.api.spec import ExperimentSpec

    warnings.warn(
        "repro.launch.train.train(...) is deprecated; build a "
        "repro.api.ExperimentSpec and call repro.api.run() or "
        "run_training()", DeprecationWarning, stacklevel=2)
    spec = ExperimentSpec(
        arch=arch, backend="spmd", mode=mode,
        schedule=_legacy_schedule_spec(schedule_kind, step_size, steps)
        if mode == "hybrid" else None,
        seed=seed, lr=lr, batch=batch, steps=steps, seq=seq,
        merge_alpha=merge_alpha, smoke=smoke, log_every=log_every)
    params, history, _ = run_training(spec, ckpt_dir=ckpt_dir,
                                      out_json=out_json)
    return params, history   # the legacy (params, history) contract


def main(argv=None):
    from repro.api.spec import ExperimentSpec

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="xlstm-350m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mode", choices=("sync", "async", "hybrid"),
                    default="hybrid")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="step",
                    help='schedule spec, e.g. "step:30" or '
                         '"cosine:horizon=200" (a bare family name combines '
                         "with --step-size/--steps, legacy style)")
    ap.add_argument("--step-size", type=int, default=30,
                    help="legacy: step size when --schedule is a bare "
                         "family name")
    ap.add_argument("--merge-alpha", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out-json", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    schedule = args.schedule
    if schedule and ":" not in schedule:
        schedule = _legacy_schedule_spec(schedule, args.step_size,
                                         args.steps)
    try:
        spec = ExperimentSpec(
            arch=args.arch, backend="spmd", mode=args.mode,
            schedule=schedule if args.mode == "hybrid" else None,
            seed=args.seed, lr=args.lr, batch=args.batch, steps=args.steps,
            seq=args.seq, merge_alpha=args.merge_alpha, smoke=args.smoke)
    except ValueError as e:
        ap.error(str(e))     # clean CLI error, as the old choices= gave
    run_training(spec, ckpt_dir=args.ckpt_dir, out_json=args.out_json)


if __name__ == "__main__":
    main()
