"""Training driver.

Modes:
  * ``sync``   — standard fully-synchronous data parallelism (the paper's
                 synchronous baseline; also the hybrid schedule's endpoint);
  * ``async``  — group size 1 throughout (per-device local SGD, the SPMD
                 analogue of the asynchronous baseline);
  * ``hybrid`` — the Smooth Switch: reduction-group size annealed by the
                 threshold schedule, replicas merged at phase switches.

Runs on whatever devices exist (CPU tests use
XLA_FLAGS=--xla_force_host_platform_device_count=8); the same code drives
the production mesh.

Example (the end-to-end driver):
  python -m repro.launch.train --arch xlstm-350m --smoke --steps 200 \
      --mode hybrid --schedule step --step-size 30
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.configs.registry import ARCH_NAMES, get_config, smoke_variant
from repro.core.schedule import (SCHEDULES, constant_schedule)
from repro.core.spmd_hybrid import (build_phases, make_replica_step,
                                    merge_replicas, replica_divergence,
                                    replica_param_shardings,
                                    replicate_params, reshard_replicas)
from repro.data.synthetic import token_stream
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import adamw
from repro.parallel.partition import param_shardings
from repro.parallel.sharding import axis_rules


def build_hybrid_mesh(rep: int, model: int = 1) -> Mesh:
    n = jax.device_count()
    assert n % (rep * model) == 0, (n, rep, model)
    devices = np.asarray(jax.devices()).reshape(rep, n // (rep * model),
                                                model)
    return Mesh(devices, ("rep", "data", "model"))


def _shard_batch_R(batch, mesh, R):
    def f(x):
        x = np.asarray(x)
        x = x.reshape(R, x.shape[0] // R, *x.shape[1:])
        return jax.device_put(x, NamedSharding(
            mesh, P("rep", "data", *([None] * (x.ndim - 2)))))
    return jax.tree.map(f, batch)


def train(arch: str, steps: int, mode: str, batch: int, seq: int,
          lr: float, schedule_kind: str, step_size: int, smoke: bool,
          merge_alpha: float = 1.0, log_every: int = 10,
          ckpt_dir: Optional[str] = None, seed: int = 0,
          out_json: Optional[str] = None):
    cfg = get_config(arch)
    if smoke:
        cfg = dataclasses.replace(smoke_variant(cfg), name=cfg.name)
    assert cfg.frontend is None, "train driver uses token streams"

    n_dev = jax.device_count()
    data_axis = n_dev
    opt = adamw(lr)
    stream = token_stream(seed, cfg.vocab_size, batch, seq)

    # --- schedule -> phases
    if mode == "sync":
        phases = [(0, data_axis)]
    elif mode == "async":
        phases = [(0, 1)]
    else:
        sched = (SCHEDULES[schedule_kind](data_axis, step_size)
                 if schedule_kind == "step"
                 else SCHEDULES[schedule_kind](data_axis, steps))
        phases = [(p.t_start, p.group_size)
                  for p in build_phases(sched, steps, data_axis)]

    params = M.init_params(jax.random.PRNGKey(seed), cfg)

    def loss_fn(p, b):
        return M.loss_fn(p, b, cfg)

    def opt_update(grads, state, p):
        return opt.update(grads, state, p)

    history = []
    t0 = time.time()
    tokens_done = 0
    params_R = None
    step = 0

    for idx, (t_start, g) in enumerate(phases):
        t_end = phases[idx + 1][0] if idx + 1 < len(phases) else steps
        R = max(1, data_axis // g)
        if params_R is None:
            host_R = replicate_params(jax.device_get(params), R)
        else:
            # Phase switch (the paper's buffer flush): merge replicas and
            # change the group factor.  Done host-side — the device arrays
            # are fetched, merged/resharded in numpy, and re-placed under
            # the new mesh.  This keeps exactly one SPMD executable alive
            # per phase (XLA-CPU's in-process communicator deadlocks if
            # modules with collectives interleave; on TPU this is one
            # host-sync per phase, a handful per run).
            host = jax.device_get(params_R)
            host = merge_replicas(host, alpha=merge_alpha)
            host_R = reshard_replicas(host, R)
        mesh = build_hybrid_mesh(R)
        with axis_rules(mesh):
            p_sh = replica_param_shardings(params, mesh)
            params_R = jax.device_put(host_R, p_sh)
            opt_R = jax.jit(jax.vmap(opt.init))(params_R)
            jax.block_until_ready((params_R, opt_R))
            replica_step = make_replica_step(loss_fn, opt_update)
            step_fn = jax.jit(replica_step, donate_argnums=(0, 1))

            while step < t_end:
                b = next(stream)
                b_R = _shard_batch_R(b, mesh, R)
                params_R, opt_R, metrics = step_fn(params_R, opt_R, b_R)
                tokens_done += batch * seq
                if step % log_every == 0 or step == t_end - 1:
                    div = float(metrics["divergence"]) if R > 1 else 0.0
                    rec = {"step": step, "group_size": g, "replicas": R,
                           "loss": float(metrics["loss"]),
                           "divergence": div,
                           "wall_s": round(time.time() - t0, 2),
                           "tokens": tokens_done}
                    history.append(rec)
                    print(f"step {step:5d}  g={g:3d} R={R:3d} "
                          f"loss={rec['loss']:.4f} div={div:.3e}", flush=True)
                step += 1

            jax.block_until_ready((params_R, opt_R))
            if ckpt_dir:
                merged = merge_replicas(jax.device_get(params_R))
                one = jax.tree.map(lambda x: np.asarray(x[0]), merged)
                save_checkpoint(os.path.join(ckpt_dir, f"step_{step}"),
                                one, step, extra={"arch": arch,
                                                  "mode": mode})

    # final merge for the returned model
    params_final = jax.tree.map(lambda x: np.asarray(x[0]),
                                merge_replicas(jax.device_get(params_R)))
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"arch": arch, "mode": mode, "history": history}, f,
                      indent=2)
    return params_final, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="xlstm-350m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mode", choices=("sync", "async", "hybrid"),
                    default="hybrid")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", choices=tuple(SCHEDULES), default="step")
    ap.add_argument("--step-size", type=int, default=30)
    ap.add_argument("--merge-alpha", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out-json", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    train(args.arch, args.steps, args.mode, args.batch, args.seq, args.lr,
          args.schedule, args.step_size, args.smoke,
          merge_alpha=args.merge_alpha, ckpt_dir=args.ckpt_dir,
          seed=args.seed, out_json=args.out_json)


if __name__ == "__main__":
    main()
