"""Step builders shared by train.py, dryrun.py and the examples."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.parallel.sharding import lconstraint


def make_train_step(cfg: ModelConfig, opt, q_block: int = 512,
                    microbatch: int = 1, accum_dtype=jnp.float32):
    """(params, opt_state, batch) -> (params, opt_state, loss).

    microbatch > 1 runs gradient accumulation: the global batch is split
    into `microbatch` slices scanned sequentially with an `accum_dtype`
    gradient accumulator — the standard memory lever for the big train
    shapes (saved scan-group inputs scale with the *micro* batch).
    accum_dtype=bfloat16 halves the accumulator footprint (§Perf knob).
    """

    def loss_fn(p, b):
        return M.loss_fn(p, b, cfg, q_block=q_block)

    def train_step(params, opt_state, batch):
        if microbatch == 1:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def resh(x):
                x = x.reshape(microbatch, x.shape[0] // microbatch,
                              *x.shape[1:])
                return lconstraint(x, (None, "batch")
                                   + (None,) * (x.ndim - 2))

            mb = jax.tree.map(resh, batch)

            def body(carry, b_i):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, b_i)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(accum_dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (g_sum, l_sum), _ = jax.lax.scan(body, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatch, g_sum)
            loss = l_sum / microbatch
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss

    return train_step


# Per-arch gradient-accumulation defaults for train_4k on the 256-chip pod
# (global batch 256 → per-device batch 16): chosen so saved scan-group
# inputs + logits fit the 16 GiB HBM budget (EXPERIMENTS.md §Dry-run).
TRAIN_MICROBATCH = {
    "xlstm-350m": 2,
    "qwen1.5-110b": 16,
    "qwen2.5-32b": 16,
    "llama4-scout-17b-a16e": 8,
    "deepseek-v2-lite-16b": 4,
    "hubert-xlarge": 4,
    "phi-3-vision-4.2b": 4,
    "h2o-danube-1.8b": 2,
    "jamba-v0.1-52b": 8,
    "phi4-mini-3.8b": 4,
}
