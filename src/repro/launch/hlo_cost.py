"""Trip-count-aware cost analysis of compiled HLO.

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE —
useless for scan-based models (an 80-layer scanned transformer reports
1/80th of its flops, and collective bytes inside the loop are equally
undercounted).  This module parses `compiled.as_text()` into a symbol
table + call graph and accumulates *executed* costs, multiplying loop
bodies by their trip counts (from the while op's
`backend_config={"known_trip_count":...}`, falling back to the loop
condition's comparison constant).

Costs per executed step:
  * flops            — dot: 2·prod(result)·prod(lhs contracting dims);
                       convolution: 2·prod(result)·prod(kernel)/out_ch
  * hbm_bytes        — at fusion/op granularity: operand + result bytes
                       (fusion internals never round-trip HBM, so this is
                       the natural HBM-traffic model of a fused program)
  * collective_bytes — result bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,]*(?:,[\w:()]+)?\})?")
OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.*?\)?)\s+([a-z][\w\-]*)\(")
TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_by_op.items():
            self.collective_by_op[k] = self.collective_by_op.get(k, 0) + v
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.hbm_bytes * n,
                    self.collective_bytes * n,
                    {k: v * n for k, v in self.collective_by_op.items()})


def _bytes_of(shape_text: str) -> float:
    total = 0.0
    for dt, dims in SHAPE_RE.findall(shape_text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _first_dims(shape_text: str) -> List[int]:
    m = SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    result: str
    kind: str
    line: str
    operands: List[str]


class HloModule:
    def __init__(self, text: str):
        # computation name -> (list of Ops, symtab name->result shape text)
        self.comps: Dict[str, Tuple[List[Op], Dict[str, str]]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._cache: Dict[str, Cost] = {}

    # ------------------------------------------------------------ parse
    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.strip()
            if cur is None:
                if line.endswith("{") and "->" in line and (
                        line.startswith("%") or line.startswith("ENTRY")):
                    is_entry = line.startswith("ENTRY")
                    name = line.split()[1] if is_entry else line.split()[0]
                    name = name.lstrip("%").split("(")[0].rstrip()
                    self.comps[name] = ([], {})
                    cur = name
                    if is_entry:
                        self.entry = name
                continue
            if line.startswith("}"):
                cur = None
                continue
            m = OP_RE.match(line)
            if not m:
                # parameters: "%x = f32[..] parameter(0)" matches OP_RE;
                # anything else (attrs on continuation lines) is skipped
                continue
            name, result, kind = m.groups()
            ops_list, symtab = self.comps[cur]
            symtab[name] = result
            # operand names: within the first balanced paren group
            start = line.index(kind + "(") + len(kind)
            depth, end = 0, start
            for i in range(start, len(line)):
                if line[i] == "(":
                    depth += 1
                elif line[i] == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            args = line[start + 1:end]
            operands = re.findall(r"%([\w\.\-]+)", args)
            ops_list.append(Op(name, result, kind, line, operands))

    # ------------------------------------------------------------ trips
    def _trip_count(self, op: Op) -> float:
        m = TRIP_RE.search(op.line)
        if m:
            return float(m.group(1))
        mc = re.search(r"condition=%?([\w\.\-]+)", op.line)
        if mc and mc.group(1) in self.comps:
            ops, _ = self.comps[mc.group(1)]
            consts = []
            for o in ops:
                c = re.match(r".*constant\((\d+)\)", o.line)
                if c:
                    consts.append(int(c.group(1)))
            if consts:
                return float(max(consts))
        return 1.0

    # ------------------------------------------------------------- cost
    def _operand_bytes(self, op: Op, symtab: Dict[str, str]) -> float:
        return sum(_bytes_of(symtab.get(o, "")) for o in op.operands)

    def comp_cost(self, name: str) -> Cost:
        if name in self._cache:
            return self._cache[name]
        total = Cost()
        self._cache[name] = total
        ops, symtab = self.comps.get(name, ([], {}))
        for op in ops:
            if op.kind == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.line)
                if mb and mb.group(1) in self.comps:
                    total += self.comp_cost(mb.group(1)).scaled(
                        self._trip_count(op))
                continue
            if op.kind in ("call", "conditional"):
                for called in re.findall(
                        r"(?:to_apply|true_computation|false_computation|"
                        r"branch_computations=\{[^}]*\})=?%?([\w\.\-{},% ]+)",
                        op.line):
                    for nm in re.findall(r"[\w\.\-]+", called):
                        if nm in self.comps:
                            total += self.comp_cost(nm)
                continue
            if op.kind == "fusion":
                # CPU fusion granularity is far finer than TPU's (and the
                # Pallas kernels keep e.g. attention scores in VMEM), so
                # fusion boundaries are NOT charged HBM traffic — only the
                # irreducible ops below (dot/conv operands, cache slicing,
                # reduces, collectives) count.  The memory term is thus a
                # kernel-granularity estimate of the deployment target.
                mf = re.search(r"calls=%?([\w\.\-]+)", op.line)
                inner = Cost()
                if mf and mf.group(1) in self.comps:
                    inner = self.comp_cost(mf.group(1))
                total += Cost(
                    flops=inner.flops,
                    hbm_bytes=inner.hbm_bytes,
                    collective_bytes=inner.collective_bytes,
                    collective_by_op=dict(inner.collective_by_op))
                continue
            if op.kind == "dot":
                res = 1
                for d in _first_dims(op.result):
                    res *= d
                contract = 1
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
                lhs_shape = symtab.get(op.operands[0], "") if op.operands \
                    else ""
                lhs_dims = _first_dims(lhs_shape)
                if m and lhs_dims:
                    for idx in m.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
                total += Cost(flops=2.0 * res * contract,
                              hbm_bytes=_bytes_of(op.result)
                              + self._operand_bytes(op, symtab))
                continue
            if op.kind == "convolution":
                res_dims = _first_dims(op.result)
                res = 1
                for d in res_dims:
                    res *= d
                kernel = 1
                if len(op.operands) > 1:
                    for d in _first_dims(symtab.get(op.operands[1], "")):
                        kernel *= d
                out_ch = res_dims[-1] if res_dims else 1
                total += Cost(
                    flops=2.0 * res * max(1, kernel) / max(1, out_ch),
                    hbm_bytes=_bytes_of(op.result)
                    + self._operand_bytes(op, symtab))
                continue
            hit_coll = False
            for coll in COLLECTIVES:
                if op.kind in (coll, coll + "-start"):
                    b = _bytes_of(op.result)
                    total += Cost(hbm_bytes=b + self._operand_bytes(
                        op, symtab), collective_bytes=b,
                        collective_by_op={coll: b})
                    hit_coll = True
                    break
            if hit_coll:
                continue
            if op.kind in ("parameter", "constant", "get-tuple-element",
                           "tuple", "bitcast", "after-all",
                           "partition-id", "replica-id"):
                continue
            if op.kind in ("reduce", "scatter", "gather", "dynamic-slice",
                           "dynamic-update-slice", "sort", "concatenate",
                           "pad", "reduce-window", "select-and-scatter",
                           "cholesky", "triangular-solve", "rng",
                           "rng-bit-generator"):
                # genuinely memory-touching ops (cache updates, gathers...)
                total += Cost(hbm_bytes=_bytes_of(op.result)
                              + self._operand_bytes(op, symtab))
                continue
            # Remaining kinds are elementwise / layout ops (copy, convert,
            # transpose, reshape, broadcast, add, multiply, ...).  The CPU
            # pipeline leaves many of them unfused, but the TPU compiler
            # fuses them into neighbours — counting them would inflate the
            # HBM term ~10x relative to the deployment target, so they are
            # excluded from the fused-traffic model.
        self._cache[name] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_hlo_text(text: str) -> Cost:
    return HloModule(text).entry_cost()
