"""Serving driver: batched prefill + decode with a ring-buffer-aware KV
cache.

Example (CPU-runnable):
  python -m repro.launch.serve --arch h2o-danube-1.8b --smoke \
      --batch 4 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_config, smoke_variant
from repro.models import model as M

# One jitted decode step per ModelConfig (frozen dataclass -> hashable
# key).  Params are an *argument*, not a closure capture: capturing
# them would bake each params pytree into the jaxpr as constants, so
# every generation — and in the serve loop, every pushed params
# version — would recompile.  With params as a tracer the executable
# is shared across calls and across versions.
_DECODE_CACHE: dict = {}


def _decode_step_fn(cfg):
    fn = _DECODE_CACHE.get(cfg)
    if fn is None:
        fn = jax.jit(lambda p, c, t, i: M.decode_step(p, c, t, i, cfg))
        _DECODE_CACHE[cfg] = fn
    return fn


def greedy_generate(cfg, params, prompts: np.ndarray, gen_len: int,
                    max_seq: int = 0):
    """prompts: (B, P) int32.  Returns (B, P+gen_len) tokens.

    Prefill runs the full forward once; decode then extends one token at a
    time through the cache (attention KV / SSM state / mLSTM matrix
    memory, per layer kind).
    """
    B, P = prompts.shape
    max_seq = max_seq or (P + gen_len)
    cache = M.init_cache(cfg, B, max_seq)

    step = _decode_step_fn(cfg)

    def decode(c, t, i):
        return step(params, c, t, i)

    # prefill by replaying the prompt through decode steps (cache-exact;
    # a fused prefill that bulk-writes the cache is the TPU fast path and
    # is exercised by the dry-run's prefill shape)
    toks = prompts
    last = None
    for i in range(P):
        last, cache = decode(cache, toks[:, i:i + 1], jnp.int32(i))

    out = [prompts]
    cur = jnp.argmax(last, axis=-1).astype(jnp.int32)
    for j in range(gen_len):
        out.append(np.asarray(cur))
        logits, cache = decode(cache, cur, jnp.int32(P + j))
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return np.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(smoke_variant(cfg), name=cfg.name)
    assert cfg.has_decode, f"{cfg.name} is encoder-only"
    assert cfg.frontend != "audio"

    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = greedy_generate(cfg, params, prompts, args.gen_len)
    dt = time.time() - t0
    n_new = args.batch * args.gen_len
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen_len}")
    print(f"generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s on this host)")
    print("sample:", out[0, -args.gen_len:].tolist())


if __name__ == "__main__":
    main()
