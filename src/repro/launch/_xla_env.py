"""Process-level XLA environment knobs that must be set *before* jax is
imported (device topology is fixed at first import).  jax-free on
purpose: both ``repro.launch.dryrun`` (under ``__main__``) and the
``python -m repro dryrun`` CLI call this before touching jax."""
from __future__ import annotations

import os
import sys
import warnings

DRYRUN_DEVICE_COUNT = 512   # the multi-pod dry-run's forced host devices


def force_host_device_count(n: int = DRYRUN_DEVICE_COUNT) -> bool:
    """Force ``n`` XLA host devices for this process.

    No-ops (with a warning) when jax is already imported — too late to
    change the topology.  An existing XLA_FLAGS is preserved: the force
    flag is appended to it, unless the user already forced a device
    count themselves (their explicit override wins).  Returns True when
    the requested count is in effect.
    """
    flag = f"--xla_force_host_platform_device_count={n}"
    if "jax" in sys.modules:
        in_effect = flag in os.environ.get("XLA_FLAGS", "")
        if not in_effect:
            warnings.warn(
                f"jax is already imported; cannot force {n} host devices "
                f"(set XLA_FLAGS={flag} before starting python)",
                RuntimeWarning, stacklevel=2)
        return in_effect
    current = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in current:
        os.environ["XLA_FLAGS"] = f"{current} {flag}".strip()
    in_effect = flag in os.environ["XLA_FLAGS"]
    if not in_effect:
        warnings.warn(
            f"XLA_FLAGS already forces a different host device count "
            f"({current!r}); leaving it in place instead of forcing {n}",
            RuntimeWarning, stacklevel=2)
    return in_effect
