"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination against the production meshes and record memory/cost/
collective analyses — the proof that the distribution config is coherent
without real hardware.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-110b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
  python -m repro.launch.dryrun --arch jamba-v0.1-52b --shape train_4k \
      --hybrid-rep 4            # group-annealed hybrid phase variant

Results are cached as JSON under experiments/dryrun/.

The 512 forced host devices are configured only when this module is the
entry point (``__main__`` below, before jax is imported, since topology
is fixed at first jax import) or via ``python -m repro dryrun``.  Plain
``import repro.launch.dryrun`` — e.g. for the HLO-parsing helpers — no
longer clobbers the process's device configuration.
"""
import os

if __name__ == "__main__":      # must precede the jax import below
    from repro.launch._xla_env import force_host_device_count
    force_host_device_count()

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import (ARCH_NAMES, SHAPES, get_config,
                                    input_specs, shape_applicable)
from repro.launch import mesh as mesh_lib
from repro.launch.steps import TRAIN_MICROBATCH, make_train_step
from repro.models import model as M
from repro.optim import adamw
from repro.parallel.partition import (cache_shardings, param_shardings,
                                      opt_state_shardings)
from repro.parallel.sharding import axis_rules

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(", re.I)
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a flat dict on some jax
    versions and a one-entry-per-program list on others (0.4.3x);
    normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def parse_collective_bytes(hlo: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in compiled HLO."""
    out: Dict[str, float] = {}
    for line in hlo.splitlines():
        line = line.strip()
        m = re.match(
            r"^[%\w.\-]*\s*=\s*((?:\([^)]*\)|\S+))\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", line)
        if not m:
            continue
        shapes_str, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(shapes_str):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[op] = out.get(op, 0) + nbytes
        out["total"] = out.get("total", 0) + nbytes
        out[f"count_{op}"] = out.get(f"count_{op}", 0) + 1
    return out


def batch_shardings(batch, mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b = axes if len(axes) > 1 else axes[0]

    def f(x):
        return NamedSharding(mesh, P(b, *([None] * (x.ndim - 1))))

    return jax.tree.map(f, batch)


def replicated(mesh):
    return NamedSharding(mesh, P())


def build_lowered(arch: str, shape_name: str, mesh, remat: Optional[str]
                  = None, q_block: int = 512,
                  microbatch: Optional[int] = None,
                  accum_dtype: str = "float32"):
    """Returns (lowered, meta)."""
    import dataclasses
    cfg = get_config(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if microbatch is None:
        microbatch = TRAIN_MICROBATCH.get(arch, 1)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)

    with axis_rules(mesh):
        params_sds = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        p_sh = param_shardings(params_sds)

        if shape.kind in ("train", "prefill"):
            batch_sds = specs["batch"]
            b_sh = batch_shardings(batch_sds, mesh)
            if shape.kind == "train":
                opt = adamw(3e-4)
                opt_sds = jax.eval_shape(lambda: opt.init(params_sds))
                o_sh = opt_state_shardings(opt_sds, params_sds)
                train_step = make_train_step(cfg, opt, q_block=q_block,
                                             microbatch=microbatch,
                                             accum_dtype=jnp.dtype(
                                                 accum_dtype))
                fn = jax.jit(train_step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, replicated(mesh)),
                             donate_argnums=(0, 1))
                lowered = fn.lower(params_sds, opt_sds, batch_sds)
            else:
                def prefill_step(params, batch):
                    logits, _ = M.forward(params, batch, cfg,
                                          q_block=q_block)
                    # return last-position logits (serving prefill output)
                    return logits[:, -1]

                fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                             out_shardings=replicated(mesh))
                lowered = fn.lower(params_sds, batch_sds)
        else:
            B = shape.global_batch
            cache_sds = specs["cache"]
            c_sh = cache_shardings(cache_sds, B, mesh)
            axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            dsz = 1
            for a in axes:
                dsz *= mesh.shape[a]
            tok_spec = P(axes if len(axes) > 1 else axes[0], None) \
                if B % dsz == 0 else P(None, None)
            t_sh = NamedSharding(mesh, tok_spec)

            def serve_step(params, cache, tokens, cur_index):
                logits, new_cache = M.decode_step(params, cache, tokens,
                                                  cur_index, cfg)
                next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return next_tok, new_cache

            fn = jax.jit(serve_step,
                         in_shardings=(p_sh, c_sh, t_sh, replicated(mesh)),
                         out_shardings=(t_sh, c_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(params_sds, cache_sds, specs["tokens"],
                               specs["cur_index"])

    n_params = sum(int(x.size) for x in jax.tree.leaves(params_sds))
    return lowered, {"num_params": n_params, "cfg_name": cfg.name}


def build_hybrid_lowered(arch: str, rep: int, mesh_kind: str,
                         q_block: int = 512,
                         microbatch: Optional[int] = None):
    """Lower the group-annealed hybrid train step (train_4k) with R
    replica groups: params carry a leading replica axis sharded over
    ``rep``; gradients reduce only within each group (DESIGN.md §2.2).
    R=1 is the fully-synchronous paper-faithful endpoint."""
    import dataclasses
    from repro.core.spmd_hybrid import (make_replica_step,
                                        replica_param_shardings,
                                        replicate_params)

    cfg = get_config(arch)
    if microbatch is None:
        microbatch = TRAIN_MICROBATCH.get(arch, 1)
    mesh = mesh_lib.make_hybrid_mesh(rep,
                                     multi_pod=(mesh_kind == "multipod"))
    shape = SHAPES["train_4k"]
    opt = adamw(3e-4)

    with axis_rules(mesh):
        params_sds = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        params_R = jax.eval_shape(lambda p: replicate_params(p, rep),
                                  params_sds)
        p_sh = replica_param_shardings(params_sds, mesh)
        opt_R = jax.eval_shape(lambda p: jax.vmap(opt.init)(p), params_R)
        o_sh = jax.tree.map(
            lambda s: s, {
                "count": replicated(mesh),
                "mu": p_sh, "nu": p_sh})
        # opt state structure: vmap(init) gives {count:(R,), mu, nu}
        o_sh = {"count": NamedSharding(mesh, P("rep")),
                "mu": p_sh, "nu": p_sh}

        B = shape.global_batch
        assert B % rep == 0
        batch_sds = {
            "tokens": jax.ShapeDtypeStruct((rep, B // rep, shape.seq_len),
                                           jnp.int32),
            "labels": jax.ShapeDtypeStruct((rep, B // rep, shape.seq_len),
                                           jnp.int32)}
        if cfg.frontend is not None:
            raise NotImplementedError("hybrid dry-run uses token archs")
        b_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, P("rep", "data",
                                            *([None] * (x.ndim - 2)))),
            batch_sds)

        def loss_fn(p, b):
            return M.loss_fn(p, b, cfg, q_block=q_block)

        def one(params, opt_state, batch):
            step = make_train_step(cfg, opt, q_block=q_block,
                                   microbatch=microbatch)
            return step(params, opt_state, batch)

        def hybrid_step(params_R, opt_R, batch_R):
            new_p, new_o, loss = jax.vmap(one)(params_R, opt_R, batch_R)
            return new_p, new_o, jnp.mean(loss)

        fn = jax.jit(hybrid_step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, replicated(mesh)),
                     donate_argnums=(0, 1))
        lowered = fn.lower(params_R, opt_R, batch_sds)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params_sds))
    return lowered, {"num_params": n_params, "cfg_name": cfg.name}


def run_one(arch: str, shape_name: str, mesh_kind: str,
            remat: Optional[str] = None, q_block: int = 512,
            microbatch: Optional[int] = None, accum_dtype: str = "float32",
            tag: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    result: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                              "mesh": mesh_kind, "tag": tag,
                              "remat": remat, "q_block": q_block,
                              "microbatch": microbatch
                              if microbatch is not None
                              else TRAIN_MICROBATCH.get(arch, 1)}
    try:
        lowered, meta = build_lowered(arch, shape_name, mesh, remat=remat,
                                      q_block=q_block,
                                      microbatch=microbatch,
                                      accum_dtype=accum_dtype)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        hlo_text = compiled.as_text()
        coll = parse_collective_bytes(hlo_text)
        # trip-count-aware executed costs (XLA cost_analysis counts while
        # bodies once — see repro.launch.hlo_cost)
        from repro.launch.hlo_cost import analyze_hlo_text
        exec_cost = analyze_hlo_text(hlo_text)
        result.update({
            "status": "ok",
            "num_params": meta["num_params"],
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
            "exec_flops_per_device": exec_cost.flops,
            "exec_hbm_bytes_per_device": exec_cost.hbm_bytes,
            "exec_collective_bytes_per_device": {
                "total": exec_cost.collective_bytes,
                **exec_cost.collective_by_op},
            "collective_bytes_per_device": coll,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            },
        })
    except Exception as e:  # noqa: BLE001 — record the failure verbatim
        result.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-2000:]})
    return result


def run_hybrid_one(arch: str, rep: int, mesh_kind: str,
                   q_block: int = 512,
                   microbatch: Optional[int] = None) -> Dict[str, Any]:
    t0 = time.time()
    result: Dict[str, Any] = {"arch": arch, "shape": "train_4k",
                              "mesh": mesh_kind, "tag": f"hybrid_R{rep}",
                              "hybrid_rep": rep,
                              "microbatch": microbatch
                              if microbatch is not None
                              else TRAIN_MICROBATCH.get(arch, 1)}
    try:
        lowered, meta = build_hybrid_lowered(arch, rep, mesh_kind,
                                             q_block=q_block,
                                             microbatch=microbatch)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        from repro.launch.hlo_cost import analyze_hlo_text
        exec_cost = analyze_hlo_text(hlo_text)
        result.update({
            "status": "ok",
            "num_params": meta["num_params"],
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "exec_flops_per_device": exec_cost.flops,
            "exec_hbm_bytes_per_device": exec_cost.hbm_bytes,
            "exec_collective_bytes_per_device": {
                "total": exec_cost.collective_bytes,
                **exec_cost.collective_by_op},
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            },
        })
    except Exception as e:  # noqa: BLE001
        result.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-2000:]})
    return result


def result_path(arch, shape_name, mesh_kind, tag=""):
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    return os.path.join(OUT_DIR,
                        f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--q-block", type=int, default=512)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--accum-dtype", default="float32")
    ap.add_argument("--hybrid-rep", type=int, default=None,
                    help="lower the group-annealed hybrid train step with "
                         "R replica groups (train_4k only)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    if args.hybrid_rep is not None:
        assert args.arch, "--hybrid-rep requires --arch"
        mesh_kind = "pod" if args.mesh == "both" else args.mesh
        res = run_hybrid_one(args.arch, args.hybrid_rep, mesh_kind,
                             q_block=args.q_block,
                             microbatch=args.microbatch)
        path = result_path(args.arch, "train_4k", mesh_kind,
                           f"hybrid_R{args.hybrid_rep}")
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
        if res["status"] == "ok":
            coll = res["exec_collective_bytes_per_device"]
            print(f"hybrid R={args.hybrid_rep}: "
                  f"{res['exec_flops_per_device']:.3e} flops/dev, "
                  f"coll {coll.get('total', 0) / 2**30:.2f} GiB/dev "
                  f"(compile {res['compile_s']}s)")
        else:
            print("ERROR:", res["error"])
            return 1
        return 0

    combos = []
    meshes = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)
    archs = ARCH_NAMES if args.all or not args.arch else (args.arch,)
    shapes = tuple(SHAPES) if args.all or not args.shape else (args.shape,)
    for a in archs:
        for s in shapes:
            for mk in meshes:
                combos.append((a, s, mk))

    failures = 0
    for a, s, mk in combos:
        path = result_path(a, s, mk, args.tag)
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                prev = json.load(f)
            print(f"[cached] {a} × {s} × {mk}: {prev['status']}")
            if prev["status"] == "error":
                failures += 1
            continue
        print(f"[run] {a} × {s} × {mk} ...", flush=True)
        res = run_one(a, s, mk, remat=args.remat, q_block=args.q_block,
                      microbatch=args.microbatch,
                      accum_dtype=args.accum_dtype, tag=args.tag)
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
        if res["status"] == "ok":
            mem = res["memory"]
            per_dev = (mem["argument_bytes"] + mem["temp_bytes"]
                       + mem["output_bytes"] - mem["alias_bytes"])
            print(f"  ok: {res['flops_per_device']:.3e} flops/dev, "
                  f"{per_dev/2**30:.2f} GiB/dev, "
                  f"coll {res['collective_bytes_per_device'].get('total', 0)/2**30:.3f} GiB "
                  f"(lower {res['lower_s']}s compile {res['compile_s']}s)",
                  flush=True)
        elif res["status"] == "skipped":
            print(f"  skipped: {res['reason']}")
        else:
            failures += 1
            print(f"  ERROR: {res['error']}")
    print(f"done: {len(combos)} combos, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
