"""Multi-process slab transport: sockets + one-process-per-worker.

:class:`SocketTransport` implements the :class:`~repro.cluster.
transport.Transport` protocol over real sockets (TCP or Unix-domain):
the server side is a *hub* — a listener plus one reader/writer thread
pair per accepted worker connection — and the worker side is a
:class:`SocketWorkerClient` endpoint created by :meth:`SocketTransport.
connect` (same process) or by connecting to ``hub.address`` from
another process.  :class:`ProcTransport` extends the hub with a
``multiprocessing`` launcher that runs each worker in its own OS
process with its own JAX runtime, so GIL contention, stale parameter
reads, stragglers, and SIGKILL worker death are physical across address
spaces.

**Wire format** — the slab layout (:mod:`repro.core.slab`) is the
schema on both ends, so every message is ONE length-prefixed frame with
no per-leaf serialization::

    frame   := header payload
    header  := !BI            (type: u8, payload length: u32)
    HELLO   := !Ii            worker_id, generation     (worker -> hub)
    GRAD    := !IiQ raw-slab  worker_id, version, seq   (worker -> hub)
    PARAMS  := !ii  raw-slab  version, restore-epoch    (hub -> worker)

``raw-slab`` is the ``(P_pad,)`` float32 slab's native byte image —
f32 round-trips bitwise, which is what makes the cross-process parity
test exact.  (Frame headers are network order; slab bytes are native
order — a true multi-host transport would pin them, see ROADMAP.)

**Channel semantics** match :class:`~repro.cluster.transport.
InProcTransport` exactly (the conformance suite in
``tests/test_transport.py`` runs against all three):

  * gradients: per-connection FIFO into one bounded hub queue.  A full
    queue blocks the connection's reader, TCP/UDS flow control
    propagates the stall to the worker's socket, and the worker's small
    outbound queue fills — ``send_gradient`` returning ``False`` is
    end-to-end physical backpressure;
  * params: versioned broadcast.  The hub keeps the latest published
    frame; per-connection writers push it, *coalescing* intermediate
    versions for slow readers (only the newest publication matters —
    including a checkpoint restore that moves the version backwards).

**Shutdown / accounting**: a SIGKILLed worker can die mid-frame; the
hub discards the torn tail frame (``torn_frames``) and counts only
complete frames in :meth:`received_counts` — which is therefore the
exact "computed" side of the conservation ledger on both socket
transports (whatever never reached the hub died with the sender,
exactly like a thread worker killed before ``send``).  ``quiesce()``
joins the connection readers after the producers are gone, making
``pending_gradients()`` exact for the final drain.

**Membership / barrier**: the runtime registers a worker with the
server when its HELLO arrives (:attr:`SocketTransport.on_worker_ready`)
and deregisters it when its connection dies
(:attr:`~SocketTransport.on_worker_gone`) — a child that is still
importing JAX must not stall a sync barrier it cannot contribute to.
``hold_params``/``release_params`` implement the fleet-ready barrier's
starting gun: until release, connected workers idle in
``fetch_params`` instead of banking gradients before the clock starts.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import socket
import struct
import sys
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.cluster.transport import GradientMsg, ParamsMsg

_HDR = struct.Struct("!BI")          # frame type, payload length
_HELLO = struct.Struct("!Ii")        # worker_id, generation
_GRAD = struct.Struct("!IiQ")        # worker_id, version, seq
_PARAMS = struct.Struct("!ii")       # version, restore epoch

_F_HELLO, _F_GRAD, _F_PARAMS = 1, 2, 3

# one frame must fit in memory several times over; anything bigger is a
# corrupted header (e.g. a reader that lost frame sync), not a real slab
_MAX_FRAME = 1 << 31


def _recv_exact(sock: socket.socket, n: int
                ) -> "tuple[Optional[bytes], bool]":
    """Read exactly ``n`` bytes.  Returns ``(data, partial)``: data is
    ``None`` on EOF / error, and ``partial`` is True when the peer died
    after delivering *some* of the bytes — a torn read, as opposed to a
    clean EOF on a frame boundary.  (Mattering for accounting: a
    SIGKILL can cut a frame mid-header, not just mid-payload.)"""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        try:
            k = sock.recv_into(view[got:], n - got)
        except (OSError, ValueError):
            return None, got > 0
        if k == 0:
            return None, got > 0
        got += k
    return bytes(buf), False


def _grad_frame(msg: GradientMsg) -> bytes:
    slab = np.ascontiguousarray(np.asarray(msg.grad, dtype=np.float32))
    payload_len = _GRAD.size + slab.nbytes
    return (_HDR.pack(_F_GRAD, payload_len)
            + _GRAD.pack(msg.worker_id, msg.version, msg.seq)
            + slab.tobytes())


def _params_frame(msg: ParamsMsg) -> bytes:
    slab = np.ascontiguousarray(np.asarray(msg.params, dtype=np.float32))
    return (_HDR.pack(_F_PARAMS, _PARAMS.size + slab.nbytes)
            + _PARAMS.pack(msg.version, msg.epoch) + slab.tobytes())


def _configure(sock: socket.socket) -> None:
    if sock.family == socket.AF_INET:
        # grad/params frames are latency-critical; never Nagle-delay them
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)


# ======================================================== server side


class _Conn:
    """One accepted worker connection: a reader thread (gradients in)
    and a writer thread (coalesced params broadcast out)."""

    def __init__(self, hub: "SocketTransport", sock: socket.socket):
        self.hub = hub
        self.sock = sock
        self.worker_id: Optional[int] = None
        self.generation = 0
        self.closed = threading.Event()
        self._params_ev = threading.Event()
        self._last_sent: Optional[bytes] = None
        self._lock = threading.Lock()       # close() idempotence
        _configure(sock)
        self.reader = threading.Thread(target=self._read_loop,
                                       name="hub-reader", daemon=True)
        self.writer = threading.Thread(target=self._write_loop,
                                       name="hub-writer", daemon=True)
        self._params_ev.set()               # push current params on join
        self.reader.start()
        self.writer.start()

    # ------------------------------------------------------- gradients in
    def _read_loop(self) -> None:
        try:
            while not self.closed.is_set():
                hdr, partial = _recv_exact(self.sock, _HDR.size)
                if hdr is None:
                    if partial:
                        self.hub._note_torn()   # died mid-header
                    break                       # else: clean EOF
                ftype, n = _HDR.unpack(hdr)
                if n > _MAX_FRAME:
                    self.hub._note_torn()
                    break
                payload, _ = _recv_exact(self.sock, n)
                if payload is None:
                    self.hub._note_torn()       # died mid-frame: discard
                    break
                if ftype == _F_HELLO:
                    wid, gen = _HELLO.unpack(payload)
                    self.worker_id, self.generation = wid, gen
                    self.hub._on_hello(self)
                elif ftype == _F_GRAD:
                    wid, version, seq = _GRAD.unpack(
                        payload[:_GRAD.size])
                    grad = np.frombuffer(payload, np.float32,
                                         offset=_GRAD.size)
                    msg = GradientMsg(wid, grad, version, seq)
                    if self.hub._enqueue(msg):  # blocks: backpressure
                        self.hub._count_received(wid)
                # unknown frame types are ignored (forward compat)
        finally:
            self.close()
            self.hub._conn_closed(self)

    # ----------------------------------------------------- params out
    def notify_params(self) -> None:
        self._params_ev.set()

    def _write_loop(self) -> None:
        while not self.closed.is_set():
            if not self._params_ev.wait(0.2):
                continue
            self._params_ev.clear()
            frame = self.hub._pub_frame     # latest only: coalesced
            if frame is None or frame is self._last_sent:
                continue
            try:
                self.sock.sendall(frame)
            except OSError:
                break
            self._last_sent = frame

    # ------------------------------------------------------------- misc
    def half_close(self) -> None:
        """Stop the params direction (worker sees EOF and shuts down)
        while still reading its in-flight gradient frames to the end."""
        try:
            self.sock.shutdown(socket.SHUT_WR)
        except OSError:
            pass

    def close(self) -> None:
        with self._lock:
            if self.closed.is_set():
                return
            self.closed.set()
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class SocketTransport:
    """The server-side hub: a full :class:`Transport` over real sockets.

    ``recv_gradient`` / ``publish_params`` / ``pending_gradients`` /
    ``quiesce`` are the parameter server's half and run in the hub
    process.  Workers use :class:`SocketWorkerClient` endpoints —
    :meth:`connect` builds one in-process (thread workers), and child
    processes connect to :attr:`address` themselves.  The hub's own
    ``send_gradient`` / ``fetch_params`` are local loopbacks (no
    socket), kept so the hub satisfies the whole protocol.

    ``grad_capacity`` bounds the hub gradient queue exactly like
    :class:`InProcTransport` (0 = unbounded); the bound propagates to
    workers through socket flow control (see module docstring).
    """

    def __init__(self, grad_capacity: int = 0, *, family: str = "unix",
                 host: str = "127.0.0.1"):
        assert family in ("unix", "tcp"), family
        self.family = family
        self._sockdir: Optional[str] = None
        if family == "unix":
            self._sockdir = tempfile.mkdtemp(prefix="repro-slab-hub-")
            self.address: Any = os.path.join(self._sockdir, "hub.sock")
            lsock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            lsock.bind(self.address)
        else:
            lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            lsock.bind((host, 0))
            self.address = lsock.getsockname()
        lsock.listen(128)
        lsock.settimeout(0.2)               # close() unblocks accept
        self._lsock = lsock
        self._grads: "queue.Queue[GradientMsg]" = \
            queue.Queue(maxsize=grad_capacity)
        self._closed = threading.Event()
        self._conns: List[_Conn] = []
        self._conns_cond = threading.Condition()
        self._received: Dict[int, int] = {}
        self._recv_lock = threading.Lock()
        self._torn = 0
        self._pub_frame: Optional[bytes] = None
        self._pub_msg: Optional[ParamsMsg] = None
        self._pub_cond = threading.Condition()
        self._held_frame: Optional[bytes] = None
        self._hold = False          # hold_params(): see fleet barrier
        self._draining = False      # half_close_workers() was called
        # membership hooks (set by the runtime before spawning): called
        # from hub reader threads with (worker_id, generation) when a
        # worker finishes connecting / when its connection dies.  The
        # proc runtime registers workers with the server on HELLO — a
        # worker that is still importing JAX must not hold up a sync
        # barrier it cannot yet contribute to
        self.on_worker_ready: Optional[Any] = None
        self.on_worker_gone: Optional[Any] = None
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="hub-accept", daemon=True)
        self._accept_thread.start()

    # ------------------------------------------------------- accept side
    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                sock, _ = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            with self._conns_cond:
                conn = _Conn(self, sock)
                self._conns.append(conn)
            if self._draining:
                # shutdown already began: a late joiner (e.g. a respawn
                # that was still compiling) gets its EOF immediately,
                # so it stops instead of training against a dead run
                conn.half_close()

    def _on_hello(self, conn: _Conn) -> None:
        with self._conns_cond:
            self._conns_cond.notify_all()
        if self.on_worker_ready is not None:
            self.on_worker_ready(conn.worker_id, conn.generation)

    def _conn_closed(self, conn: _Conn) -> None:
        with self._conns_cond:
            self._conns_cond.notify_all()
        if self.on_worker_gone is not None and conn.worker_id is not None:
            self.on_worker_gone(conn.worker_id, conn.generation)

    def _enqueue(self, msg: GradientMsg) -> bool:
        # bounded put that stays interruptible by close(): the reader
        # blocking here is what turns a full hub queue into socket
        # backpressure all the way to the worker
        while not self._closed.is_set():
            try:
                self._grads.put(msg, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _count_received(self, worker_id: int) -> None:
        with self._recv_lock:
            self._received[worker_id] = \
                self._received.get(worker_id, 0) + 1

    def _note_torn(self) -> None:
        with self._recv_lock:
            self._torn += 1

    # ----------------------------------------------- Transport (server)
    def recv_gradient(self, timeout: Optional[float] = None
                      ) -> Optional[GradientMsg]:
        try:
            if timeout is not None and timeout <= 0:
                return self._grads.get_nowait()
            return self._grads.get(timeout=timeout)
        except queue.Empty:
            return None

    def publish_params(self, msg: ParamsMsg) -> None:
        frame = _params_frame(msg)
        with self._pub_cond:
            # unconditional replace — a restore publishes an OLDER
            # version and workers must resync to it (see Transport)
            self._pub_msg = ParamsMsg(
                msg.version, np.frombuffer(frame, np.float32,
                                           offset=_HDR.size + _PARAMS.size),
                epoch=msg.epoch)
            if self._hold:
                self._held_frame = frame
                self._pub_cond.notify_all()
                return                  # workers see it on release
            self._pub_frame = frame
            self._pub_cond.notify_all()
        self._notify_all_conns()

    def _notify_all_conns(self) -> None:
        with self._conns_cond:
            conns = list(self._conns)
        for conn in conns:
            conn.notify_params()

    def hold_params(self) -> None:
        """Withhold the params broadcast from workers (the hub-local
        cell still updates).  Workers that connect meanwhile block in
        ``fetch_params`` instead of free-running — the fleet-ready
        barrier uses this so no gradient work predates the serving
        clock (which would flatter the multi-process benchmark)."""
        with self._pub_cond:
            self._hold = True
            if self._pub_frame is not None:
                self._held_frame = self._pub_frame
                self._pub_frame = None

    def release_params(self) -> None:
        """Release a :meth:`hold_params` hold: push the latest params
        to every connected worker (the starting gun)."""
        with self._pub_cond:
            self._hold = False
            if self._held_frame is not None:
                self._pub_frame = self._held_frame
                self._held_frame = None
        self._notify_all_conns()

    def pending_gradients(self) -> int:
        return self._grads.qsize()

    # --------------------------------------------- Transport (loopback)
    def send_gradient(self, msg: GradientMsg,
                      timeout: Optional[float] = None) -> bool:
        try:
            if timeout is not None and timeout <= 0:
                self._grads.put_nowait(msg)
            else:
                self._grads.put(msg, timeout=timeout)
        except queue.Full:
            return False
        self._count_received(msg.worker_id)
        return True

    def fetch_params(self, min_version: int = 0,
                     timeout: Optional[float] = None
                     ) -> Optional[ParamsMsg]:
        with self._pub_cond:
            ok = self._pub_cond.wait_for(
                lambda: self._pub_msg is not None
                and self._pub_msg.version >= min_version,
                0 if (timeout is not None and timeout <= 0) else timeout)
            return self._pub_msg if ok else None

    # ------------------------------------------------------- lifecycle
    def connect(self, worker_id: int, generation: int = 0,
                send_capacity: int = 2) -> "SocketWorkerClient":
        """A worker-side endpoint in this process (thread workers)."""
        return SocketWorkerClient(self.address, worker_id,
                                  generation=generation,
                                  family=self.family,
                                  send_capacity=send_capacity)

    def wait_for_workers(self, n: int,
                         timeout: Optional[float] = None) -> bool:
        """Block until ``n`` distinct workers have said HELLO and are
        still connected (process workers connect only after their JAX
        runtime is warm, so this is the fleet-ready barrier)."""
        def ready() -> bool:
            live = {c.worker_id for c in self._conns
                    if c.worker_id is not None and not c.closed.is_set()}
            return len(live) >= n
        with self._conns_cond:
            return self._conns_cond.wait_for(ready, timeout)

    def live_workers(self) -> Set[int]:
        with self._conns_cond:
            return {c.worker_id for c in self._conns
                    if c.worker_id is not None and not c.closed.is_set()}

    def received_counts(self) -> Dict[int, int]:
        """Complete gradient frames received, per worker id — the exact
        "computed" ledger column for process workers.  Read only after
        :meth:`quiesce` returned ``True``."""
        with self._recv_lock:
            return dict(self._received)

    @property
    def torn_frames(self) -> int:
        """Frames discarded because the sender died mid-write."""
        with self._recv_lock:
            return self._torn

    def half_close_workers(self) -> None:
        """Send EOF to every worker (params direction) while still
        draining their in-flight gradient frames — the clean-shutdown
        signal for process workers.  Workers that connect *after* this
        call are half-closed on arrival (see the accept loop), so a
        late-starting respawn can never outlive the run."""
        self._draining = True
        with self._conns_cond:
            conns = list(self._conns)
        for conn in conns:
            conn.half_close()

    def quiesce(self, timeout: Optional[float] = None) -> bool:
        """True once every connection reader has drained to EOF (all
        producers must already be stopped/closed).  Interleave with
        ``recv_gradient(timeout=0)`` drains: a reader blocked on the
        bounded queue needs the caller to make room."""
        deadline = None if timeout is None else \
            time.monotonic() + max(0.0, timeout)
        with self._conns_cond:
            conns = list(self._conns)
        for conn in conns:
            remain = None if deadline is None else \
                max(0.0, deadline - time.monotonic())
            conn.reader.join(timeout=remain)
            if conn.reader.is_alive():
                return False
        return True

    def close(self) -> None:
        self._closed.set()
        try:
            self._lsock.close()
        except OSError:
            pass
        with self._conns_cond:
            conns = list(self._conns)
        for conn in conns:
            conn.close()
        self._accept_thread.join(timeout=2.0)
        if self.family == "unix":
            for path in (self.address,):
                try:
                    os.unlink(path)
                except OSError:
                    pass
            if self._sockdir:
                try:
                    os.rmdir(self._sockdir)
                except OSError:
                    pass


# ======================================================== worker side


class SocketWorkerClient:
    """The worker half of the protocol over one socket connection.

    ``send_gradient`` enqueues into a small bounded outbound queue
    drained by a sender thread (so a timed-out send never leaves a torn
    frame on the wire — the frame is sent whole or not at all), and
    ``fetch_params`` waits on a local versioned cell kept current by a
    reader thread — the same broadcast-cell semantics as
    :class:`InProcTransport`.

    :attr:`closed` is set when the connection dies (server shutdown,
    kill, network error); runtimes wire it up as the worker's stop
    event so a dead server can never leave a live worker spinning.
    """

    def __init__(self, address: Any, worker_id: int, *,
                 generation: int = 0, family: str = "unix",
                 send_capacity: int = 2, connect_timeout: float = 10.0):
        self.worker_id = worker_id
        self.generation = generation
        if family == "unix":
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.settimeout(connect_timeout)
            sock.connect(address)
        else:
            sock = socket.create_connection(tuple(address),
                                            timeout=connect_timeout)
        sock.settimeout(None)
        _configure(sock)
        self.sock = sock
        self.closed = threading.Event()
        self._cell: Optional[ParamsMsg] = None
        self._cond = threading.Condition()
        self._sendq: "queue.Queue[GradientMsg]" = \
            queue.Queue(maxsize=max(1, send_capacity))
        self._close_lock = threading.Lock()
        self._closed_once = False
        self.sock.sendall(_HDR.pack(_F_HELLO, _HELLO.size)
                          + _HELLO.pack(worker_id, generation))
        self._reader = threading.Thread(
            target=self._read_loop, name=f"client-reader-{worker_id}",
            daemon=True)
        self._sender = threading.Thread(
            target=self._send_loop, name=f"client-sender-{worker_id}",
            daemon=True)
        self._reader.start()
        self._sender.start()

    # ------------------------------------------------------ wire threads
    def _read_loop(self) -> None:
        try:
            while not self.closed.is_set():
                hdr, _ = _recv_exact(self.sock, _HDR.size)
                if hdr is None:
                    break
                ftype, n = _HDR.unpack(hdr)
                if n > _MAX_FRAME:
                    break
                payload, _ = _recv_exact(self.sock, n)
                if payload is None:
                    break
                if ftype == _F_PARAMS:
                    version, epoch = _PARAMS.unpack(
                        payload[:_PARAMS.size])
                    slab = np.frombuffer(payload, np.float32,
                                         offset=_PARAMS.size)
                    with self._cond:
                        self._cell = ParamsMsg(version, slab,
                                               epoch=epoch)
                        self._cond.notify_all()
        finally:
            self._mark_closed()

    def _send_loop(self) -> None:
        while True:
            try:
                msg = self._sendq.get(timeout=0.1)
            except queue.Empty:
                if self.closed.is_set():
                    return
                continue
            try:
                self.sock.sendall(_grad_frame(msg))
            except OSError:
                # the frame was accepted but never shipped: do NOT
                # task_done() it — flush() must not claim it landed
                self._mark_closed()
                return
            self._sendq.task_done()

    def _mark_closed(self) -> None:
        self.closed.set()
        with self._cond:
            self._cond.notify_all()         # wake blocked fetch_params

    # ------------------------------------------- Transport (worker half)
    def send_gradient(self, msg: GradientMsg,
                      timeout: Optional[float] = None) -> bool:
        if timeout is not None and timeout <= 0:
            if self.closed.is_set():
                return False
            try:
                self._sendq.put_nowait(msg)
                return True
            except queue.Full:
                return False
        deadline = None if timeout is None else \
            time.monotonic() + timeout
        while not self.closed.is_set():
            remain = None if deadline is None else \
                deadline - time.monotonic()
            if remain is not None and remain <= 0:
                return False
            try:
                self._sendq.put(msg, timeout=0.05 if remain is None
                                else min(0.05, remain))
                return True
            except queue.Full:
                continue
        return False

    def fetch_params(self, min_version: int = 0,
                     timeout: Optional[float] = None
                     ) -> Optional[ParamsMsg]:
        def ok() -> bool:
            return (self._cell is not None
                    and self._cell.version >= min_version)
        with self._cond:
            if timeout is not None and timeout <= 0:
                return self._cell if ok() else None
            deadline = None if timeout is None else \
                time.monotonic() + timeout
            while not ok():
                if self.closed.is_set():
                    return None
                remain = None if deadline is None else \
                    deadline - time.monotonic()
                if remain is not None and remain <= 0:
                    return None
                self._cond.wait(0.1 if remain is None
                                else min(0.1, remain))
            return self._cell

    def pending_gradients(self) -> int:
        return self._sendq.qsize()

    # the worker half never receives gradients or publishes params
    def recv_gradient(self, timeout: Optional[float] = None):
        raise NotImplementedError("worker-side endpoint")

    def publish_params(self, msg: ParamsMsg) -> None:
        raise NotImplementedError("worker-side endpoint")

    # ------------------------------------------------------- lifecycle
    def flush(self, timeout: float = 5.0) -> bool:
        """Block until every accepted gradient is on the wire — clean
        shutdown must not strand sent-but-unshipped gradients (the
        ledger counts them as computed).  Note this waits on the sender
        *thread*, not on :attr:`closed`: a hub half-close (EOF on the
        params direction) sets ``closed`` while the gradient direction
        is still perfectly writable, and bailing there would tear the
        final frames."""
        deadline = time.monotonic() + timeout
        while self._sendq.unfinished_tasks:
            if not self._sender.is_alive() \
                    or time.monotonic() > deadline:
                return self._sendq.unfinished_tasks == 0
            time.sleep(0.01)
        return True

    def can_flush(self) -> bool:
        """Whether unshipped frames can still make progress — the
        sender thread is alive.  A dead sender means the connection is
        gone and the remaining frames are lost; waiting on them is
        pointless."""
        return self._sender.is_alive()

    def quiesce(self, timeout: Optional[float] = None) -> bool:
        return self.flush(timeout if timeout is not None else 5.0)

    def close(self) -> None:
        with self._close_lock:
            if self._closed_once:
                return
            self._closed_once = True
        self._mark_closed()
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


# ================================================== process launcher


@dataclasses.dataclass
class ProcWorkerConfig:
    """Everything a worker process needs to rebuild its world: the
    experiment spec (to rebuild the workload via the ``SIM_WORKLOADS``
    registry — code does not cross the process boundary, only this
    picklable description does), its identity/shard, and the hub
    address.  ``platform`` forces ``JAX_PLATFORMS`` in the child (set
    to ``"cpu"`` when the parent holds an exclusive accelerator — two
    processes cannot share one TPU)."""
    spec: Dict[str, Any]
    worker_id: int
    generation: int
    num_workers: int
    mode: str
    straggle_s: float
    seed: int
    batch: int
    address: Any = None
    family: str = "unix"
    platform: Optional[str] = None


def _proc_worker_main(cfg: ProcWorkerConfig) -> None:
    """Child entry point: rebuild the workload, compile the slab
    gradient executable, and only then connect (HELLO == ready), so the
    parent's wall-clock budget measures contention — not XLA."""
    if cfg.platform:
        os.environ["JAX_PLATFORMS"] = cfg.platform
    try:
        import jax

        from repro.api.spec import ExperimentSpec
        from repro.api.trainers import SIM_WORKLOADS
        from repro.cluster.worker import Worker
        from repro.core.slab import slab_codec
        from repro.data.pipeline import shard_iterator

        spec = ExperimentSpec.from_dict(cfg.spec)
        loss_fn, init_params, data, _ = SIM_WORKLOADS[spec.arch](spec)
        x_tr, y_tr = data[0], data[1]
        codec = slab_codec(init_params)
        grad_fn = jax.grad(loss_fn)

        def _grad_slab(p_slab, x, y):
            return codec.encode(grad_fn(codec.decode(p_slab), x, y))

        grad = jax.jit(_grad_slab)

        def fresh_batches():
            return shard_iterator(x_tr, y_tr, cfg.worker_id,
                                  cfg.num_workers, cfg.batch,
                                  seed=cfg.seed,
                                  generation=cfg.generation)

        # warm up on a throwaway iterator: the training stream must
        # start at batch 0, exactly like an in-process worker's
        wx, wy = next(fresh_batches())
        jax.block_until_ready(grad(codec.encode(init_params), wx, wy))

        client = SocketWorkerClient(cfg.address, cfg.worker_id,
                                    generation=cfg.generation,
                                    family=cfg.family)
    except Exception:
        import traceback
        traceback.print_exc()
        sys.stderr.flush()
        os._exit(2)

    worker = Worker(cfg.worker_id, grad_fn=grad,
                    batches=fresh_batches(), transport=client,
                    mode=cfg.mode, straggle_s=cfg.straggle_s,
                    generation=cfg.generation)
    # server shutdown/death closes the connection -> closed is set ->
    # the loop exits: a dead server can never leave this process alive
    worker.stop_event = client.closed
    worker.run()                            # inline, not as a thread
    client.flush(5.0)
    client.close()
    code = 0
    if worker.error:
        print(worker.error, file=sys.stderr, flush=True)
        code = 3
    sys.stdout.flush()
    sys.stderr.flush()
    # skip interpreter finalization: tearing down a JAX runtime's C++
    # thread pools from a fast-exiting spawned child intermittently
    # aborts (std::terminate) after all real work is already flushed
    os._exit(code)


class ProcTransport(SocketTransport):
    """The multi-process transport: a Unix-domain (or TCP) socket hub
    plus a ``multiprocessing`` *spawn* launcher — each worker is a
    fresh OS process with its own JAX runtime that connects back to the
    hub once compiled.  ``FaultPlan`` kills are **SIGKILL**: worker
    death is an OS fact, and the hub's torn-frame handling plus
    received-side accounting keep the conservation ledger exact through
    it.  Spawn (not fork) because forking a process with a live JAX
    runtime is undefined behaviour."""

    def __init__(self, grad_capacity: int = 0, *, family: str = "unix",
                 host: str = "127.0.0.1"):
        super().__init__(grad_capacity, family=family, host=host)
        import multiprocessing
        self._ctx = multiprocessing.get_context("spawn")
        self._procs: Dict[int, Any] = {}            # live, by worker id
        self._all_procs: List[Tuple[int, int, Any]] = []
        self._killed: Set[int] = set()              # pids we SIGKILLed

    # -------------------------------------------------------- processes
    def spawn_worker(self, cfg: ProcWorkerConfig):
        cfg = dataclasses.replace(cfg, address=self.address,
                                  family=self.family)
        p = self._ctx.Process(
            target=_proc_worker_main, args=(cfg,),
            name=f"worker-{cfg.worker_id}.{cfg.generation}", daemon=True)
        p.start()
        self._procs[cfg.worker_id] = p
        self._all_procs.append((cfg.worker_id, cfg.generation, p))
        return p

    def kill_worker(self, worker_id: int) -> bool:
        """SIGKILL the worker's current process (no cooperation, no
        cleanup — the fault the paper's cluster baseline worries
        about).  Returns True if a live process was signalled."""
        p = self._procs.get(worker_id)
        if p is None or not p.is_alive():
            return False
        self._killed.add(p.pid)
        p.kill()
        return True

    def procs_alive(self) -> bool:
        """Any spawned worker process still running?"""
        return any(p.is_alive() for _, _, p in self._all_procs)

    def kill_unconnected(self) -> None:
        """SIGKILL worker processes that never finished connecting —
        e.g. a respawned worker still importing JAX / compiling when
        the run ends.  They have sent nothing, so there is nothing to
        flush or account; the EOF-based shutdown can't reach them (no
        connection), and waiting out their startup would stall
        teardown.  Planned kills, not errors."""
        with self._conns_cond:
            connected = {(c.worker_id, c.generation)
                         for c in self._conns
                         if c.worker_id is not None}
        for wid, gen, p in self._all_procs:
            if p.is_alive() and (wid, gen) not in connected:
                self._killed.add(p.pid)
                p.kill()

    def dead_workers(self) -> List[str]:
        """Processes that already exited abnormally (no planned SIGKILL)
        — lets the fleet-ready barrier fail fast instead of waiting out
        its timeout on a child that crashed during startup."""
        out = []
        for wid, gen, p in self._all_procs:
            code = p.exitcode
            if code is None or code == 0:
                continue
            if code < 0 and p.pid in self._killed:
                continue
            out.append(f"worker process {wid}.{gen} exited with code "
                       f"{code} (see its stderr above)")
        return out

    def join_workers(self, timeout: float = 10.0) -> List[str]:
        """Join every spawned process, escalating to SIGKILL past the
        deadline.  Returns human-readable errors for processes that
        failed (crashed with a traceback) rather than exited cleanly or
        by a planned SIGKILL."""
        errors: List[str] = []
        deadline = time.monotonic() + timeout
        for wid, gen, p in self._all_procs:
            p.join(timeout=max(0.0, deadline - time.monotonic()))
            if p.is_alive():
                self._killed.add(p.pid)
                p.kill()
                p.join(timeout=2.0)
                errors.append(f"worker process {wid}.{gen} did not stop "
                              "within the join timeout (SIGKILLed)")
                continue
            code = p.exitcode
            planned_kill = (code is not None and code < 0
                            and p.pid in self._killed)
            if code not in (0, None) and not planned_kill:
                errors.append(f"worker process {wid}.{gen} exited with "
                              f"code {code} (see its stderr above)")
        return errors

    def close(self) -> None:
        for _, _, p in self._all_procs:
            if p.is_alive():
                self._killed.add(p.pid)
                p.kill()
        for _, _, p in self._all_procs:
            if p.is_alive():
                p.join(timeout=2.0)
        super().close()
