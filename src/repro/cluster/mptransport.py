"""Multi-process slab transport: sockets + one-process-per-worker.

:class:`SocketTransport` implements the :class:`~repro.cluster.
transport.Transport` protocol over real sockets (TCP or Unix-domain):
the server side is a *hub* — a listener plus one reader/writer thread
pair per accepted worker connection — and the worker side is a
:class:`SocketWorkerClient` endpoint created by :meth:`SocketTransport.
connect` (same process) or by connecting to ``hub.address`` from
another process.  :class:`ProcTransport` extends the hub with a
``multiprocessing`` launcher that runs each worker in its own OS
process with its own JAX runtime, so GIL contention, stale parameter
reads, stragglers, and SIGKILL worker death are physical across address
spaces.

**Wire format** — the slab layout (:mod:`repro.core.slab`) is the
schema on both ends, so every message is ONE length-prefixed frame with
no per-leaf serialization.  The format is **versioned and pinned**::

    frame   := header payload
    header  := !BI            (type: u8, payload length: u32)
    HELLO   := !IHIi          magic, proto, worker_id, generation
    HELLO'  := !IHIiB         ... + slab dtype code (non-f32 peers only)
    JOIN    := !IHi           magic, proto, requested worker id (-1=auto)
    WELCOME := !IH json       magic, proto, lease + spec JSON (hub ->)
    REJECT  := !IH utf-8      magic, proto, readable reason   (hub ->)
    GRAD    := !IiQ raw-slab  worker_id, version, seq
    PARAMS  := !ii  raw-slab  version, restore-epoch          (hub ->)
    SERVE   := !IH            magic, proto — read-only subscribe
    PING    := !IH            magic, proto — leader liveness  (hub ->)
    PONG    := !IH            magic, proto — liveness reply
    STATS   := !IH [json]     magic, proto — read-only stats subscribe
                              (client ->, empty body); stats payload
                              push (hub ->, JSON body)
    CHALLENGE := !IH nonce    magic, proto, 32-byte nonce    (hub ->)
    AUTH    := !IH digest     magic, proto, HMAC-SHA256(secret, nonce)

``raw-slab`` is the ``(P_pad,)`` slab as **little-endian ``<f4``** —
pinned on both encode and decode (a big-endian host byteswaps at the
boundary, a little-endian host pays nothing), so f32 payloads
round-trip bitwise across any pair of hosts, which is what makes the
cross-process and cross-host parity tests exact.

**Negotiated slab dtype** — a peer whose run declares ``slab_dtype``
other than f32 (``ExperimentSpec.slab_dtype="bf16"``) says so with ONE
trailing byte on its HELLO (``HELLO'`` above, dtype code 0=f32,
1=bf16); its GRAD/PARAMS payloads then carry the slab as little-endian
raw bf16 (``<u2`` bit patterns), halving every slab frame on the wire
(``wire.tx_bytes``/``rx_bytes``).  The negotiation is strictly
additive: an f32 peer sends the original 14-byte HELLO — byte for byte
the pinned v1 frame — and old hubs reject an extended HELLO readably
(length check), so mixed builds fail fast instead of misparsing slabs.
The hub tracks the dtype per connection, validates GRAD frame lengths
against the connection's element size, and caches one encoded PARAMS
frame per dtype per published version (the broadcast stays
swap-a-pointer cheap).  Read-only SERVE subscribers inherit the run's
dtype (it rides the WELCOME spec).  The first frame on
every accepted connection must be a HELLO or JOIN carrying the protocol
magic and version: a stray TCP client, or a peer from an incompatible
build, is rejected with a logged, readable error (and a best-effort
REJECT frame) instead of being misparsed as a worker —
:attr:`SocketTransport.rejected_peers` counts them, and a rejected
connection never enters the fleet barrier.  Every frame length is
validated against ``_MAX_FRAME`` (and HELLO/JOIN against their exact
struct sizes) before any payload is read, so a peer that lost frame
sync cannot wedge a reader on a garbage multi-gigabyte length.
JOIN/WELCOME implement the multi-host leader handshake — worker-id
leases with generation fencing — in :mod:`repro.cluster.hostlink`.

**Channel semantics** match :class:`~repro.cluster.transport.
InProcTransport` exactly (the conformance suite in
``tests/test_transport.py`` runs against all three):

  * gradients: per-connection FIFO into one bounded hub queue.  A full
    queue blocks the connection's reader, TCP/UDS flow control
    propagates the stall to the worker's socket, and the worker's small
    outbound queue fills — ``send_gradient`` returning ``False`` is
    end-to-end physical backpressure;
  * params: versioned broadcast.  The hub keeps the latest published
    frame; per-connection writers push it, *coalescing* intermediate
    versions for slow readers (only the newest publication matters —
    including a checkpoint restore that moves the version backwards).

**Shutdown / accounting**: a SIGKILLed worker can die mid-frame; the
hub discards the torn tail frame (``torn_frames``) and counts only
complete frames in :meth:`received_counts` — which is therefore the
exact "computed" side of the conservation ledger on both socket
transports (whatever never reached the hub died with the sender,
exactly like a thread worker killed before ``send``).  ``quiesce()``
joins the connection readers after the producers are gone, making
``pending_gradients()`` exact for the final drain.

**Membership / barrier**: the runtime registers a worker with the
server when its HELLO arrives (:attr:`SocketTransport.on_worker_ready`)
and deregisters it when its connection dies
(:attr:`~SocketTransport.on_worker_gone`) — a child that is still
importing JAX must not stall a sync barrier it cannot contribute to.
``hold_params``/``release_params`` implement the fleet-ready barrier's
starting gun: until release, connected workers idle in
``fetch_params`` instead of banking gradients before the clock starts.

**Serving plane**: a peer whose first frame is SERVE (instead of
HELLO/JOIN) becomes a *read-only* subscriber to the params broadcast.
Serve connections never claim a ``worker_id``, so every membership
surface — the fleet barrier, ``live_workers``, ``received_counts`` and
with it the conservation ledger — excludes them for free, and a SERVE
peer that tries to send a GRAD frame is rejected like any
unidentified sender.  The publish path is already slow-reader-safe for
them: ``publish_params`` only swaps a frame pointer under a lock
(never writes a socket), each connection has its own writer thread,
and coalescing means a stalled reader costs the hub exactly one wedged
writer — never a torn or delayed flush.  ``serve_every`` down-samples
the push stream per serve connection (every Nth version), trading
client-visible staleness for broadcast bandwidth; ``serve_stats``
reports per-client push/version/skip counters.

**Stats plane**: a peer whose first frame is STATS becomes a read-only
subscriber to the hub's *telemetry* push (``python -m repro top``):
small JSON payloads — ledger counters, staleness percentiles, queue
depth — on a fixed cadence, produced by :attr:`SocketTransport.
stats_provider`.  Like serve peers, stats connections never hold a
``worker_id``, never enter the barrier or the conservation ledger, and
``quiesce`` skips them; unlike serve peers they are *not* sent the
params broadcast at all (a stats reader costs the run a few hundred
bytes of JSON per tick, never a slab) — which is why a sync run stays
bitwise-identical with a stats reader attached (regression-tested).
The hub keeps a small ring of recent cells (fed by the cadence thread,
subscribers or not); a newly-admitted stats reader is sent the ring as
one ``{"history": [...]}`` backfill frame before live pushes begin, so
a late-attaching ``repro top`` starts with rates instead of starting
blind — while the live pushes themselves stay coalesced latest-only.
Old peers ignore unknown frame types, so STATS rides protocol v1
without a version bump.

**Join authentication**: a hub constructed with a shared join secret
(the multi-host leader's ``--join-secret``) answers JOIN with a
CHALLENGE frame carrying a fresh random nonce instead of a WELCOME.
The peer proves possession of the secret by replying AUTH with
``HMAC-SHA256(secret, nonce)``; a correct digest completes the pending
lease (WELCOME), a wrong one is rejected readably, and a peer that
HELLOs directly — skipping the challenge — is rejected too.  Old peers
ignore unknown frame types, so CHALLENGE/AUTH ride protocol v1 exactly
like STATS did.  Read-only SERVE/STATS subscribers are deliberately
*not* challenged: they can observe, never contribute.

**Liveness**: with ``heartbeat_s > 0`` the hub PINGs every
authenticated connection on that cadence (never a silent stray — the
model-withholding rule extends to control frames).  Clients reply PONG
(ignored beyond updating receive timestamps) and treat *any* frame as
proof of life, so a worker or serve client can distinguish a hung
leader — process alive, event loop wedged — from a merely quiet one,
and exit with a readable error instead of waiting forever.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import hmac
import json
import logging
import os
import queue
import socket
import struct
import sys
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Set, Tuple

import ml_dtypes
import numpy as np

from repro.cluster.transport import GradientMsg, ParamsMsg
from repro.obs.telemetry import NULL

_log = logging.getLogger("repro.cluster.transport")

# protocol identity: the first frame of every connection must carry both
# (HELLO or JOIN), or the peer is rejected before it can touch the fleet
_MAGIC = 0x534C4142                  # "SLAB"
_PROTO_VERSION = 1

_HDR = struct.Struct("!BI")          # frame type, payload length
_HELLO = struct.Struct("!IHIi")      # magic, proto, worker_id, generation
_HELLO_DT = struct.Struct("!IHIiB")  # ... + slab dtype code (non-f32 only)
_JOIN = struct.Struct("!IHi")        # magic, proto, requested id (-1=auto)
_CTRL = struct.Struct("!IH")         # magic, proto (WELCOME/REJECT prefix)
_GRAD = struct.Struct("!IiQ")        # worker_id, version, seq
_PARAMS = struct.Struct("!ii")       # version, restore epoch

_F_HELLO, _F_GRAD, _F_PARAMS, _F_JOIN, _F_WELCOME, _F_REJECT = \
    1, 2, 3, 4, 5, 6
_F_SERVE, _F_PING, _F_PONG = 7, 8, 9
_F_STATS = 10
_F_CHALLENGE, _F_AUTH = 11, 12

# HMAC-SHA256 over the challenge nonce: both sides fixed-size
_AUTH_NONCE_LEN = 32
_AUTH_DIGEST_LEN = 32

# leader-side ring of recent stats cells: enough for a late-attaching
# `repro top` to backfill rates (~2 minutes at the default 0.5s cadence)
_STATS_HISTORY_LEN = 240

# one frame must fit in memory several times over; anything bigger is a
# corrupted header (e.g. a reader that lost frame sync), not a real slab
_MAX_FRAME = 1 << 30

# the pinned slab byte order: little-endian on the wire, always.  On a
# little-endian host (every CI/dev machine) this is the native layout
# and costs nothing; a big-endian host byteswaps at the boundary.  f32
# is the default (and the only layout protocol v1 ever shipped); bf16
# is negotiated per connection via the extended HELLO and travels as
# raw little-endian bf16 bit patterns (<u2 on the wire, viewed back as
# ml_dtypes.bfloat16 — numpy has no native bf16 — at the boundary)
_SLAB_DTYPE = np.dtype("<f4")
_BF16 = np.dtype(ml_dtypes.bfloat16)
_DT_F32, _DT_BF16 = 0, 1             # HELLO' slab dtype codes
_DT_NAMES = {_DT_F32: "f32", _DT_BF16: "bf16"}
_DT_CODES = {name: code for code, name in _DT_NAMES.items()}
_SLAB_ITEMSIZE = {"f32": 4, "bf16": 2}


class WireProtocolError(RuntimeError):
    """A peer violated the slab wire protocol (bad magic, version
    mismatch, malformed handshake, rejected join)."""


def _recv_exact(sock: socket.socket, n: int
                ) -> "tuple[Optional[bytes], bool]":
    """Read exactly ``n`` bytes.  Returns ``(data, partial)``: data is
    ``None`` on EOF / error, and ``partial`` is True when the peer died
    after delivering *some* of the bytes — a torn read, as opposed to a
    clean EOF on a frame boundary.  (Mattering for accounting: a
    SIGKILL can cut a frame mid-header, not just mid-payload.)"""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        try:
            k = sock.recv_into(view[got:], n - got)
        except (OSError, ValueError):
            return None, got > 0
        if k == 0:
            return None, got > 0
        got += k
    return bytes(buf), False


def _slab_to_bytes(arr, dtype_name: str = "f32") -> bytes:
    """The slab's wire image: contiguous little-endian bytes — the
    pinned byte order, regardless of the producing host's own.  f32
    travels as ``<f4``; a bf16 connection ships the raw bf16 bit
    patterns (``<u2``), half the bytes per element."""
    if dtype_name == "bf16":
        a = np.ascontiguousarray(np.asarray(arr))
        if a.dtype != _BF16:
            a = a.astype(_BF16)
        return a.view(np.uint16).astype("<u2", copy=False).tobytes()
    a = np.ascontiguousarray(np.asarray(arr, dtype=np.float32))
    return a.astype(_SLAB_DTYPE, copy=False).tobytes()


def _slab_from_payload(payload: bytes, offset: int,
                       dtype_name: str = "f32") -> np.ndarray:
    """Decode a wire slab: explicit little-endian, normalized to the
    native byte order so downstream jnp/staging code never sees a
    swapped view.  bf16 payloads come back as ``ml_dtypes.bfloat16``
    arrays (jnp adopts them as ``jnp.bfloat16`` with no conversion)."""
    if dtype_name == "bf16":
        u = np.frombuffer(payload, np.dtype("<u2"), offset=offset)
        if u.dtype != np.uint16:        # big-endian host: byteswap once
            u = u.astype(np.uint16)
        return u.view(_BF16)
    slab = np.frombuffer(payload, _SLAB_DTYPE, offset=offset)
    if slab.dtype != np.float32:        # big-endian host: byteswap once
        slab = slab.astype(np.float32)
    return slab


def _grad_frame(msg: GradientMsg, dtype_name: str = "f32") -> bytes:
    slab = _slab_to_bytes(msg.grad, dtype_name)
    return (_HDR.pack(_F_GRAD, _GRAD.size + len(slab))
            + _GRAD.pack(msg.worker_id, msg.version, msg.seq) + slab)


def _params_frame(msg: ParamsMsg, dtype_name: str = "f32") -> bytes:
    slab = _slab_to_bytes(msg.params, dtype_name)
    return (_HDR.pack(_F_PARAMS, _PARAMS.size + len(slab))
            + _PARAMS.pack(msg.version, msg.epoch) + slab)


def _hello_frame(worker_id: int, generation: int,
                 slab_dtype: str = "f32") -> bytes:
    """An f32 peer sends the original 14-byte HELLO — bit for bit the
    pinned v1 frame; only a non-f32 peer appends the dtype byte."""
    if slab_dtype == "f32":
        return (_HDR.pack(_F_HELLO, _HELLO.size)
                + _HELLO.pack(_MAGIC, _PROTO_VERSION, worker_id,
                              generation))
    return (_HDR.pack(_F_HELLO, _HELLO_DT.size)
            + _HELLO_DT.pack(_MAGIC, _PROTO_VERSION, worker_id,
                             generation, _DT_CODES[slab_dtype]))


def _join_frame(requested_id: int) -> bytes:
    return (_HDR.pack(_F_JOIN, _JOIN.size)
            + _JOIN.pack(_MAGIC, _PROTO_VERSION, requested_id))


def _ctrl_frame(ftype: int, body: bytes) -> bytes:
    return (_HDR.pack(ftype, _CTRL.size + len(body))
            + _CTRL.pack(_MAGIC, _PROTO_VERSION) + body)


def _welcome_frame(cfg: Dict[str, Any]) -> bytes:
    return _ctrl_frame(_F_WELCOME, json.dumps(cfg).encode("utf-8"))


def _reject_frame(reason: str) -> bytes:
    return _ctrl_frame(_F_REJECT, reason.encode("utf-8"))


def _serve_frame() -> bytes:
    """Read-only subscribe request (client -> hub, first frame)."""
    return _ctrl_frame(_F_SERVE, b"")


def _stats_frame(payload: bytes = b"") -> bytes:
    """Empty body: a read-only stats subscribe request (client ->,
    first frame).  JSON body: one stats payload push (hub ->)."""
    return _ctrl_frame(_F_STATS, payload)


def _challenge_frame(nonce: bytes) -> bytes:
    """Authenticated-JOIN challenge (hub ->): prove you hold the shared
    join secret before the lease is granted."""
    return _ctrl_frame(_F_CHALLENGE, nonce)


def _auth_frame(digest: bytes) -> bytes:
    """Challenge response (client ->): HMAC-SHA256(secret, nonce)."""
    return _ctrl_frame(_F_AUTH, digest)


def _auth_digest(secret: str, nonce: bytes) -> bytes:
    return hmac.new(secret.encode("utf-8"), nonce,
                    hashlib.sha256).digest()


def _ping_frame() -> bytes:
    return _ctrl_frame(_F_PING, b"")


def _pong_frame() -> bytes:
    return _ctrl_frame(_F_PONG, b"")


def _peer_error(magic: int, proto: int) -> Optional[str]:
    """Reject reason for a bad protocol identity, or None when valid."""
    if magic != _MAGIC:
        return (f"bad magic 0x{magic:08X} (expected 0x{_MAGIC:08X}) — "
                "peer is not a repro slab endpoint")
    if proto != _PROTO_VERSION:
        return (f"protocol version mismatch: peer speaks v{proto}, this "
                f"hub speaks v{_PROTO_VERSION}")
    return None


def _configure(sock: socket.socket) -> None:
    if sock.family == socket.AF_INET:
        # grad/params frames are latency-critical; never Nagle-delay them
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)


# ======================================================== server side


class _Conn:
    """One accepted worker connection: a reader thread (gradients in)
    and a writer thread (coalesced params broadcast out)."""

    def __init__(self, hub: "SocketTransport", sock: socket.socket):
        self.hub = hub
        self.sock = sock
        self.worker_id: Optional[int] = None
        self.generation = 0
        # the negotiated slab dtype for THIS connection: f32 unless the
        # peer's HELLO carried a dtype byte (serve conns inherit the
        # run's dtype at admission).  Controls GRAD decode, GRAD length
        # validation, and which encoded PARAMS frame the writer pushes
        self.slab_dtype = "f32"
        self.authenticated = False          # valid HELLO/JOIN/SERVE seen
        self.leased_wid: Optional[int] = None   # set by a JOIN lease
        # authenticated-JOIN state (hubs with a join secret): a JOIN is
        # parked as pending_join while the CHALLENGE round-trips; the
        # lease is only granted once the AUTH digest verifies
        self.awaiting_auth = False          # CHALLENGE sent, AUTH due
        self.auth_ok = False                # digest verified
        self.auth_nonce: Optional[bytes] = None
        self.pending_join: Optional[int] = None
        # serving plane: read-only params subscribers.  worker_id stays
        # None for them, which is what keeps every membership surface
        # (barrier, ledger, live_workers) worker-only with no new code
        self.is_serve = False
        self.serve_id: Optional[int] = None
        # stats plane: read-only telemetry subscribers (repro top).
        # Same worker_id=None trick as serve peers, and additionally
        # excluded from the params broadcast entirely
        self.is_stats = False
        self.stats_id: Optional[int] = None
        self.pushes = 0                     # params frames shipped
        self.last_pushed_version: Optional[int] = None
        self.skipped_pushes = 0             # down-sampled by serve_every
        self.closed = threading.Event()
        self._params_ev = threading.Event()
        self._last_sent: Optional[bytes] = None
        self._lock = threading.Lock()       # close() idempotence
        self._wlock = threading.Lock()      # whole frames only: the
        #                                     writer thread and control
        #                                     replies share one socket
        _configure(sock)
        self.reader = threading.Thread(target=self._read_loop,
                                       name="hub-reader", daemon=True)
        self.writer = threading.Thread(target=self._write_loop,
                                       name="hub-writer", daemon=True)
        self._params_ev.set()               # push current params on join
        self.reader.start()
        self.writer.start()

    # ------------------------------------------------------- gradients in
    def _frame_error(self, ftype: int, n: int) -> Optional[str]:
        """Header-level validation, BEFORE the payload is read — a
        garbage header must never commit the reader to a garbage-sized
        read."""
        if ftype == _F_HELLO:
            if self.worker_id is not None:
                return ("repeated HELLO on one connection — a peer "
                        "identifies itself exactly once (a re-HELLO "
                        "under another id would ghost-register the "
                        "first one in the sync barrier)")
            return None if n in (_HELLO.size, _HELLO_DT.size) else \
                (f"HELLO frame has length {n}, expected {_HELLO.size} " \
                 f"or {_HELLO_DT.size}")
        if ftype == _F_JOIN:
            if self.authenticated:
                return ("JOIN on an already-authenticated connection — "
                        "one connection holds at most one lease")
            return None if n == _JOIN.size else \
                f"JOIN frame has length {n}, expected {_JOIN.size}"
        if ftype == _F_SERVE:
            if self.authenticated:
                return ("SERVE on an already-authenticated connection "
                        "— a trainer cannot demote itself to a reader "
                        "mid-stream")
            return None if n == _CTRL.size else \
                f"SERVE frame has length {n}, expected {_CTRL.size}"
        if ftype == _F_STATS:
            if self.authenticated:
                return ("STATS on an already-authenticated connection "
                        "— a trainer cannot demote itself to a stats "
                        "reader mid-stream")
            return None if n == _CTRL.size else \
                f"STATS subscribe frame has length {n}, expected " \
                f"{_CTRL.size}"
        if ftype == _F_AUTH:
            if self.authenticated:
                return ("AUTH on an already-authenticated connection — "
                        "the challenge round-trips exactly once")
            if not self.awaiting_auth:
                return ("unexpected AUTH frame — this connection has "
                        "no challenge outstanding")
            return None if n == _CTRL.size + _AUTH_DIGEST_LEN else \
                f"AUTH frame has length {n}, expected " \
                f"{_CTRL.size + _AUTH_DIGEST_LEN}"
        if not self.authenticated:
            return (f"first frame has type {ftype}, not "
                    "HELLO/JOIN/SERVE/STATS — peer is not speaking the "
                    "repro slab protocol")
        if n > _MAX_FRAME:
            return (f"frame length {n} exceeds the {_MAX_FRAME}-byte "
                    "maximum — peer lost frame sync")
        if ftype == _F_GRAD and (n < _GRAD.size or
                                 (n - _GRAD.size)
                                 % _SLAB_ITEMSIZE[self.slab_dtype]):
            return (f"malformed GRAD frame: payload length {n} is not "
                    f"header + whole {self.slab_dtype} slab elements — "
                    "peer lost frame sync")
        return None

    def _read_loop(self) -> None:
        try:
            while not self.closed.is_set():
                hdr, partial = _recv_exact(self.sock, _HDR.size)
                if hdr is None:
                    if partial:
                        self.hub._note_torn()   # died mid-header
                    break                       # else: clean EOF
                ftype, n = _HDR.unpack(hdr)
                err = self._frame_error(ftype, n)
                if err is not None:
                    self.hub._reject(self, err)
                    break
                payload, _ = _recv_exact(self.sock, n)
                if payload is None:
                    self.hub._note_torn()       # died mid-frame: discard
                    break
                self.hub.obs.count("wire.rx_bytes", _HDR.size + n)
                if ftype == _F_HELLO:
                    if n == _HELLO_DT.size:
                        magic, proto, wid, gen, dtc = \
                            _HELLO_DT.unpack(payload)
                    else:
                        magic, proto, wid, gen = _HELLO.unpack(payload)
                        dtc = _DT_F32   # bare v1 HELLO: pinned f32
                    err = _peer_error(magic, proto)
                    if err is None and dtc not in _DT_NAMES:
                        err = (f"unknown slab dtype code {dtc} in "
                               "HELLO — peer is from a newer build "
                               "negotiating a dtype this hub does not "
                               "speak")
                    if err is None:
                        # before admission: the first params push must
                        # already use the negotiated encoding
                        self.slab_dtype = _DT_NAMES[dtc]
                    # _admit_hello claims conn.worker_id inside the
                    # hub's admission lock — concurrent admissions for
                    # one id must see each other (duplicate fencing)
                    err = err \
                        or self.hub._admit_hello(self, wid, gen)
                    if err is not None:
                        self.hub._reject(self, err)
                        break
                    self.authenticated = True
                    self.hub._on_hello(self)
                elif ftype == _F_JOIN:
                    magic, proto, req = _JOIN.unpack(payload)
                    err = _peer_error(magic, proto) \
                        or self.hub._on_join(self, req)
                    if err is not None:
                        self.hub._reject(self, err)
                        break
                    # a secret-bearing hub parks the JOIN behind a
                    # CHALLENGE: the connection stays unauthenticated
                    # (no params broadcast, no lease) until AUTH lands
                    self.authenticated = not self.awaiting_auth
                elif ftype == _F_AUTH:
                    magic, proto = _CTRL.unpack(payload[:_CTRL.size])
                    err = _peer_error(magic, proto) \
                        or self.hub._on_auth(self,
                                             payload[_CTRL.size:])
                    if err is not None:
                        self.hub._reject(self, err)
                        break
                    self.authenticated = True
                elif ftype == _F_SERVE:
                    magic, proto = _CTRL.unpack(payload)
                    err = _peer_error(magic, proto) \
                        or self.hub._on_serve(self)
                    if err is not None:
                        self.hub._reject(self, err)
                        break
                    self.authenticated = True
                    self.hub._on_serve_ready(self)
                elif ftype == _F_STATS:
                    magic, proto = _CTRL.unpack(payload[:_CTRL.size])
                    err = _peer_error(magic, proto) \
                        or self.hub._on_stats(self)
                    if err is not None:
                        self.hub._reject(self, err)
                        break
                    self.authenticated = True
                    self.hub._on_stats_ready(self)
                elif ftype == _F_PONG:
                    pass                    # liveness reply; receipt
                    #                         alone is the signal
                elif ftype == _F_GRAD:
                    if self.worker_id is None:
                        reason = "GRAD frame before HELLO — the peer " \
                                 "never identified itself"
                        if self.is_serve:
                            reason = ("GRAD frame from a read-only "
                                      "serve client")
                        elif self.is_stats:
                            reason = ("GRAD frame from a read-only "
                                      "stats client")
                        self.hub._reject(self, reason)
                        break
                    wid, version, seq = _GRAD.unpack(
                        payload[:_GRAD.size])
                    grad = _slab_from_payload(payload, _GRAD.size,
                                              self.slab_dtype)
                    msg = GradientMsg(wid, grad, version, seq)
                    # the span brackets the bounded put: its duration IS
                    # the backpressure wait when the hub queue is full
                    with self.hub.obs.span(f"worker/{wid}/wire",
                                           "grad_rx", version=version,
                                           seq=seq,
                                           bytes=_HDR.size + n):
                        ok = self.hub._enqueue(msg)
                    if ok:                      # blocks: backpressure
                        self.hub._count_received(wid)
                # other frame types are ignored (forward compat)
        finally:
            self.close()
            self.hub._conn_closed(self)

    # ----------------------------------------------------- params out
    def notify_params(self) -> None:
        self._params_ev.set()

    def send_frame(self, frame: bytes,
                   lock_timeout: Optional[float] = None) -> bool:
        """Write one whole frame (serialized against the params writer
        thread).  False when the connection is gone — or, with
        ``lock_timeout``, when the write lock stayed contended that
        long (a writer wedged in ``sendall`` against a stalled peer
        must not be able to wedge the *reader* too)."""
        if lock_timeout is None:
            acquired = self._wlock.acquire()
        else:
            acquired = self._wlock.acquire(timeout=lock_timeout)
        if not acquired:
            return False
        try:
            self.sock.sendall(frame)
            self.hub.obs.count("wire.tx_bytes", len(frame))
            return True
        except OSError:
            return False
        finally:
            self._wlock.release()

    def _write_loop(self) -> None:
        while not self.closed.is_set():
            if not self._params_ev.wait(0.2):
                continue
            self._params_ev.clear()
            # latest only (coalesced), in this connection's negotiated
            # dtype — same frame object per (version, dtype), so the
            # identity-based _last_sent dedup below still holds
            frame = self.hub._pub_frame_for(self.slab_dtype)
            # never broadcast parameters to a connection that has not
            # authenticated: a silent stray peer must not receive the
            # model (the HELLO handler re-arms the push on admission)
            if frame is None or frame is self._last_sent \
                    or not self.authenticated:
                continue
            if self.is_stats:
                # stats readers are never sent the params broadcast —
                # a few hundred bytes of JSON per tick (pushed by the
                # stats thread via send_frame), never a slab.  This is
                # what keeps a sync run bitwise-identical with a stats
                # reader attached
                self._last_sent = frame
                continue
            if self.is_serve:
                version, = _PARAMS.unpack_from(frame, _HDR.size)[:1]
                every = max(1, self.hub.serve_every)
                if every > 1 and version % every and version != 0:
                    # the staleness-vs-throughput knob: serve clients
                    # only get every Nth version (version 0 — the
                    # initial model — always ships), so a reader can
                    # run up to N-1 versions stale in exchange for
                    # 1/N of the broadcast bandwidth
                    self._last_sent = frame
                    self.skipped_pushes += 1
                    continue
            if not self.send_frame(frame):
                break
            self._last_sent = frame
            if self.is_serve:
                self.pushes += 1
                self.last_pushed_version = version

    # ------------------------------------------------------------- misc
    def half_close(self) -> None:
        """Stop the params direction (worker sees EOF and shuts down)
        while still reading its in-flight gradient frames to the end."""
        try:
            self.sock.shutdown(socket.SHUT_WR)
        except OSError:
            pass

    def close(self) -> None:
        with self._lock:
            if self.closed.is_set():
                return
            self.closed.set()
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class SocketTransport:
    """The server-side hub: a full :class:`Transport` over real sockets.

    ``recv_gradient`` / ``publish_params`` / ``pending_gradients`` /
    ``quiesce`` are the parameter server's half and run in the hub
    process.  Workers use :class:`SocketWorkerClient` endpoints —
    :meth:`connect` builds one in-process (thread workers), and child
    processes connect to :attr:`address` themselves.  The hub's own
    ``send_gradient`` / ``fetch_params`` are local loopbacks (no
    socket), kept so the hub satisfies the whole protocol.

    ``grad_capacity`` bounds the hub gradient queue exactly like
    :class:`InProcTransport` (0 = unbounded); the bound propagates to
    workers through socket flow control (see module docstring).

    TCP mode binds ``(host, port)`` — ``port=0`` (the default) picks an
    ephemeral port, an explicit port makes the address advertisable
    ahead of time (the multi-host leader's requirement); either way the
    *resolved* address is :attr:`address`.  ``SO_REUSEADDR`` is set so a
    fast restart can rebind the same port while the previous hub's
    connections sit in TIME_WAIT.
    """

    # the telemetry bus; the runtime swaps in its live bus before the
    # run starts.  Class attribute (not per-instance state in __init__)
    # so directly-constructed hubs in tests/benchmarks get the no-op
    # bus with zero setup
    obs = NULL

    def __init__(self, grad_capacity: int = 0, *, family: str = "unix",
                 host: str = "127.0.0.1", port: int = 0,
                 heartbeat_s: float = 0.0, serve_every: int = 1,
                 slab_dtype: str = "f32"):
        assert family in ("unix", "tcp"), family
        assert slab_dtype in _DT_CODES, slab_dtype
        self.family = family
        # the RUN's declared slab dtype: what publish_params encodes
        # eagerly, what connect() hands in-process worker endpoints,
        # and what serve subscribers inherit.  Individual connections
        # may still negotiate their own via HELLO'
        self.slab_dtype = slab_dtype
        self.heartbeat_s = float(heartbeat_s)   # 0 = no PINGs
        self.serve_every = max(1, int(serve_every))
        self._sockdir: Optional[str] = None
        if family == "unix":
            self._sockdir = tempfile.mkdtemp(prefix="repro-slab-hub-")
            self.address: Any = os.path.join(self._sockdir, "hub.sock")
            lsock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            lsock.bind(self.address)
        else:
            lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            lsock.bind((host, port))
            self.address = lsock.getsockname()
        lsock.listen(128)
        lsock.settimeout(0.2)               # close() unblocks accept
        self._lsock = lsock
        self._grads: "queue.Queue[GradientMsg]" = \
            queue.Queue(maxsize=grad_capacity)
        self._closed = threading.Event()
        self._conns: List[_Conn] = []
        self._conns_cond = threading.Condition()
        self._received: Dict[int, int] = {}
        self._recv_lock = threading.Lock()
        self._torn = 0
        self._rejected = 0
        self._pub_frame: Optional[bytes] = None
        self._pub_msg: Optional[ParamsMsg] = None
        # per-dtype encodings of the CURRENT publication, keyed by
        # dtype name; reset on every publish, filled lazily for
        # dtypes other than the run's own (see _pub_frame_for)
        self._pub_frames: Dict[str, bytes] = {}
        self._pub_cond = threading.Condition()
        self._held_frame: Optional[bytes] = None
        self._hold = False          # hold_params(): see fleet barrier
        self._draining = False      # half_close_workers() was called
        # membership hooks (set by the runtime before spawning): called
        # from hub reader threads with (worker_id, generation) when a
        # worker finishes connecting / when its connection dies.  The
        # proc runtime registers workers with the server on HELLO — a
        # worker that is still importing JAX must not hold up a sync
        # barrier it cannot yet contribute to
        self.on_worker_ready: Optional[Any] = None
        self.on_worker_gone: Optional[Any] = None
        # serving-plane hook + admission counter (see _on_serve)
        self.on_serve_ready: Optional[Any] = None
        self._serve_seq = 0
        self._serve_conns: List[_Conn] = []     # every admitted, ever
        # stats plane: a zero-arg callable returning a JSON-encodable
        # dict (the runtime installs one once the server exists); the
        # push thread starts when the provider is installed (the
        # stats_provider property setter) and ticks every stats_every_s
        # even with no subscribers, feeding the history ring a
        # late-attaching `repro top` backfills from
        self.stats_every_s = 0.5
        self._stats_seq = 0
        self._stats_conns: List[_Conn] = []     # every admitted, ever
        self._stats_thread: Optional[threading.Thread] = None
        self._stats_history: Any = \
            collections.deque(maxlen=_STATS_HISTORY_LEN)
        self._stats_provider: Optional[Any] = None
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="hub-accept", daemon=True)
        self._accept_thread.start()
        self._hb_thread: Optional[threading.Thread] = None
        if self.heartbeat_s > 0:
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop, name="hub-heartbeat",
                daemon=True)
            self._hb_thread.start()

    # ------------------------------------------------------- accept side
    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                sock, _ = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            with self._conns_cond:
                conn = _Conn(self, sock)
                self._conns.append(conn)
            if self._draining:
                # shutdown already began: a late joiner (e.g. a respawn
                # that was still compiling) gets its EOF immediately,
                # so it stops instead of training against a dead run
                conn.half_close()

    def _admit_hello(self, conn: _Conn, worker_id: int,
                     generation: int) -> Optional[str]:
        """Membership policy hook: a reject reason, or None to admit.
        On admit the hook MUST claim ``conn.worker_id``/``generation``
        inside its own critical section, so concurrent admissions for
        the same id observe each other.  The base hub admits every
        well-formed HELLO; the multi-host :class:`~repro.cluster.
        hostlink.HostTransport` fences stale generations and duplicate
        worker ids here."""
        with self._conns_cond:
            conn.worker_id, conn.generation = worker_id, generation
        return None

    def _on_join(self, conn: _Conn, requested_id: int) -> Optional[str]:
        """JOIN (lease negotiation) hook — only the multi-host hub
        implements it; anything else tells the peer to HELLO directly."""
        return ("this hub does not negotiate worker-id leases (not a "
                "host transport) — connect with HELLO")

    def _on_auth(self, conn: _Conn, digest: bytes) -> Optional[str]:
        """AUTH (challenge response) hook — only a hub that issued a
        CHALLENGE (a secret-bearing :class:`~repro.cluster.hostlink.
        HostTransport`) can verify one."""
        return ("unexpected AUTH frame — this hub issued no challenge")

    def _on_serve(self, conn: _Conn) -> Optional[str]:
        """SERVE (read-only subscribe) hook — only the multi-host hub
        admits serve clients; the plain hub has no spec to hand them
        and no serving story."""
        return ("this hub does not admit serve clients (not a host "
                "transport) — point `repro infer` at a training leader")

    def _on_serve_ready(self, conn: _Conn) -> None:
        """An admitted serve connection just authenticated: arm its
        params push (same re-arm as HELLO — a negotiated handshake may
        have consumed the pre-auth push client-side) and surface it."""
        with self._conns_cond:
            self._serve_conns.append(conn)
        conn._last_sent = None
        conn.notify_params()
        if self.on_serve_ready is not None:
            self.on_serve_ready(conn.serve_id)

    def _on_stats(self, conn: _Conn) -> Optional[str]:
        """STATS (read-only telemetry subscribe) hook — only the
        multi-host hub admits stats clients; the plain hub has no live
        run to report on from outside its own process."""
        return ("this hub does not admit stats clients (not a host "
                "transport) — point `repro top` at a training leader")

    @property
    def stats_provider(self) -> Optional[Any]:
        return self._stats_provider

    @stats_provider.setter
    def stats_provider(self, provider: Optional[Any]) -> None:
        """Installing a provider starts the push/history thread at once
        (not lazily with the first subscriber): the history ring must
        already hold cells when a late `repro top` attaches."""
        self._stats_provider = provider
        if provider is not None and not self._closed.is_set():
            self._ensure_stats_thread()

    def stats_history(self) -> List[Dict[str, Any]]:
        """Recent stats cells, oldest first (the backfill payload)."""
        return list(self._stats_history)

    def _on_stats_ready(self, conn: _Conn) -> None:
        """An admitted stats connection just authenticated: send the
        history-ring backfill (so a late-attaching `repro top` can
        compute rates over cells it never saw pushed), then one current
        payload (so it paints before the first cadence tick).  Both go
        out *before* the connection joins the push list — a cadence
        tick must not overtake its own backfill on the wire."""
        history = self.stats_history()
        if history:
            conn.send_frame(
                _stats_frame(json.dumps({"history": history})
                             .encode("utf-8")), lock_timeout=1.0)
        conn.send_frame(self._stats_frame_now(), lock_timeout=1.0)
        with self._conns_cond:
            self._stats_conns.append(conn)
        self._ensure_stats_thread()

    def _stats_frame_now(self, record: bool = False) -> bytes:
        """One STATS push frame from the current provider snapshot.
        A hub whose runtime has not installed a provider yet (or whose
        provider raises mid-teardown) reports a ``waiting`` state
        instead of wedging the push thread.  ``record=True`` (the
        cadence thread) appends real cells to the history ring —
        placeholder ``waiting`` states are never recorded."""
        provider = self._stats_provider
        payload = None
        if provider is not None:
            try:
                payload = provider()
            except Exception:
                payload = None
        if payload is None:
            payload = {"state": "waiting"}
        elif record:
            self._stats_history.append(payload)
        return _stats_frame(json.dumps(payload).encode("utf-8"))

    def _ensure_stats_thread(self) -> None:
        with self._conns_cond:
            if self._stats_thread is not None:
                return
            self._stats_thread = threading.Thread(
                target=self._stats_loop, name="hub-stats", daemon=True)
            self._stats_thread.start()

    def _stats_loop(self) -> None:
        """On every cadence tick: record the current cell in the
        history ring (subscribers or not — that is what a late reader
        backfills from), then push it to every live stats reader.
        Short lock timeout for the same reason as heartbeats: one
        stalled reader must not delay the others' ticks."""
        while not self._closed.wait(self.stats_every_s):
            frame = self._stats_frame_now(record=True)
            with self._conns_cond:
                conns = [c for c in self._stats_conns
                         if not c.closed.is_set()]
            for conn in conns:
                conn.send_frame(frame, lock_timeout=0.2)

    def _heartbeat_loop(self) -> None:
        """PING every authenticated connection on the heartbeat cadence.
        A short lock timeout keeps a writer wedged against one stalled
        peer from delaying liveness for everyone else."""
        frame = _ping_frame()
        while not self._closed.wait(self.heartbeat_s):
            with self._conns_cond:
                conns = [c for c in self._conns
                         if c.authenticated and not c.closed.is_set()]
            for conn in conns:
                conn.send_frame(frame, lock_timeout=0.2)

    def serve_stats(self) -> Dict[str, Any]:
        """Per-serve-client push accounting (the serving-plane half of
        the run report): how many params versions each client was sent,
        the last version it got, and how many pushes the ``serve_every``
        down-sampling skipped."""
        with self._conns_cond:
            conns = list(self._serve_conns)
        with self._conns_cond:
            stats_clients = len(self._stats_conns)
        return {
            "clients": len(conns),
            "rejected_peers": self.rejected_peers,
            "serve_every": self.serve_every,
            "stats_clients": stats_clients,
            "per_client": [
                {"serve_id": c.serve_id,
                 "pushes": c.pushes,
                 "last_version": c.last_pushed_version,
                 "skipped_pushes": c.skipped_pushes,
                 "connected": not c.closed.is_set()}
                for c in conns],
        }

    def _reject(self, conn: _Conn, reason: str) -> None:
        """Turn away a peer with a readable error: logged, counted,
        best-effort REJECT frame (a stray client that can't parse it
        just sees the connection close).  The caller breaks its read
        loop, so the conn closes without ever entering the barrier."""
        try:
            peer = conn.sock.getpeername()
        except OSError:
            peer = "?"
        _log.warning("rejecting peer %s: %s", peer, reason)
        with self._recv_lock:
            self._rejected += 1
        # best-effort only, and never at the cost of the reader: if the
        # write lock is held by a writer wedged against a stalled peer,
        # skip the frame — the close right after this unblocks everyone
        conn.send_frame(_reject_frame(reason), lock_timeout=1.0)

    def _on_hello(self, conn: _Conn) -> None:
        with self._conns_cond:
            self._conns_cond.notify_all()
        # re-arm the params push for this connection: a JOIN handshake
        # may have consumed the pre-HELLO push on the client side (the
        # negotiator reads frames until WELCOME), and a coalesced writer
        # would otherwise never resend the current version
        conn._last_sent = None
        conn.notify_params()
        if self.on_worker_ready is not None:
            self.on_worker_ready(conn.worker_id, conn.generation)

    def _conn_closed(self, conn: _Conn) -> None:
        with self._conns_cond:
            self._conns_cond.notify_all()
        if self.on_worker_gone is not None and conn.worker_id is not None:
            self.on_worker_gone(conn.worker_id, conn.generation)

    def _enqueue(self, msg: GradientMsg) -> bool:
        # bounded put that stays interruptible by close(): the reader
        # blocking here is what turns a full hub queue into socket
        # backpressure all the way to the worker
        while not self._closed.is_set():
            try:
                self._grads.put(msg, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _count_received(self, worker_id: int) -> None:
        with self._recv_lock:
            self._received[worker_id] = \
                self._received.get(worker_id, 0) + 1

    def _note_torn(self) -> None:
        with self._recv_lock:
            self._torn += 1

    # ----------------------------------------------- Transport (server)
    def recv_gradient(self, timeout: Optional[float] = None
                      ) -> Optional[GradientMsg]:
        try:
            if timeout is not None and timeout <= 0:
                return self._grads.get_nowait()
            return self._grads.get(timeout=timeout)
        except queue.Empty:
            return None

    def publish_params(self, msg: ParamsMsg) -> None:
        frame = _params_frame(msg, self.slab_dtype)
        with self._pub_cond:
            # unconditional replace — a restore publishes an OLDER
            # version and workers must resync to it (see Transport)
            self._pub_msg = ParamsMsg(
                msg.version,
                _slab_from_payload(frame, _HDR.size + _PARAMS.size,
                                   self.slab_dtype),
                epoch=msg.epoch)
            self._pub_frames = {self.slab_dtype: frame}
            if self._hold:
                self._held_frame = frame
                self._pub_cond.notify_all()
                return                  # workers see it on release
            self._pub_frame = frame
            self._pub_cond.notify_all()
        self._notify_all_conns()

    def _pub_frame_for(self, dtype_name: str) -> Optional[bytes]:
        """The current publication, encoded for one connection's
        negotiated dtype.  Frames are cached per (publication, dtype):
        the common case — every connection speaks the run's dtype — is
        a dict hit on the frame publish_params already built, and a
        mixed fleet pays one re-encode per foreign dtype per version,
        not per connection.  Returns None while hold_params() is
        withholding the broadcast (the fleet-ready barrier)."""
        with self._pub_cond:
            if self._pub_frame is None:
                return None
            frame = self._pub_frames.get(dtype_name)
            if frame is None and self._pub_msg is not None:
                frame = _params_frame(self._pub_msg, dtype_name)
                self._pub_frames[dtype_name] = frame
            return frame

    def _notify_all_conns(self) -> None:
        with self._conns_cond:
            conns = list(self._conns)
        for conn in conns:
            conn.notify_params()

    def hold_params(self) -> None:
        """Withhold the params broadcast from workers (the hub-local
        cell still updates).  Workers that connect meanwhile block in
        ``fetch_params`` instead of free-running — the fleet-ready
        barrier uses this so no gradient work predates the serving
        clock (which would flatter the multi-process benchmark)."""
        with self._pub_cond:
            self._hold = True
            if self._pub_frame is not None:
                self._held_frame = self._pub_frame
                self._pub_frame = None

    def release_params(self) -> None:
        """Release a :meth:`hold_params` hold: push the latest params
        to every connected worker (the starting gun)."""
        with self._pub_cond:
            self._hold = False
            if self._held_frame is not None:
                self._pub_frame = self._held_frame
                self._held_frame = None
        self._notify_all_conns()

    def pending_gradients(self) -> int:
        return self._grads.qsize()

    # --------------------------------------------- Transport (loopback)
    def send_gradient(self, msg: GradientMsg,
                      timeout: Optional[float] = None) -> bool:
        try:
            if timeout is not None and timeout <= 0:
                self._grads.put_nowait(msg)
            else:
                self._grads.put(msg, timeout=timeout)
        except queue.Full:
            return False
        self._count_received(msg.worker_id)
        return True

    def fetch_params(self, min_version: int = 0,
                     timeout: Optional[float] = None
                     ) -> Optional[ParamsMsg]:
        with self._pub_cond:
            ok = self._pub_cond.wait_for(
                lambda: self._pub_msg is not None
                and self._pub_msg.version >= min_version,
                0 if (timeout is not None and timeout <= 0) else timeout)
            return self._pub_msg if ok else None

    # ------------------------------------------------------- lifecycle
    def connect(self, worker_id: int, generation: int = 0,
                send_capacity: int = 2) -> "SocketWorkerClient":
        """A worker-side endpoint in this process (thread workers) —
        speaking the run's slab dtype."""
        return SocketWorkerClient(self.address, worker_id,
                                  generation=generation,
                                  family=self.family,
                                  send_capacity=send_capacity,
                                  slab_dtype=self.slab_dtype)

    def wait_for_workers(self, n: int,
                         timeout: Optional[float] = None) -> bool:
        """Block until ``n`` distinct workers have said HELLO and are
        still connected (process workers connect only after their JAX
        runtime is warm, so this is the fleet-ready barrier)."""
        def ready() -> bool:
            live = {c.worker_id for c in self._conns
                    if c.worker_id is not None and not c.closed.is_set()}
            return len(live) >= n
        with self._conns_cond:
            return self._conns_cond.wait_for(ready, timeout)

    def live_workers(self) -> Set[int]:
        with self._conns_cond:
            return {c.worker_id for c in self._conns
                    if c.worker_id is not None and not c.closed.is_set()}

    def connected_workers(self) -> Dict[int, int]:
        """{worker_id: generation} of every live, HELLO'd connection —
        the runtime sweeps this after installing its membership hooks,
        catching externally-joined workers whose HELLO landed first."""
        with self._conns_cond:
            return {c.worker_id: c.generation for c in self._conns
                    if c.worker_id is not None
                    and not c.closed.is_set()}

    def received_counts(self) -> Dict[int, int]:
        """Complete gradient frames received, per worker id — the exact
        "computed" ledger column for process workers.  Read only after
        :meth:`quiesce` returned ``True``."""
        with self._recv_lock:
            return dict(self._received)

    @property
    def torn_frames(self) -> int:
        """Frames discarded because the sender died mid-write."""
        with self._recv_lock:
            return self._torn

    @property
    def rejected_peers(self) -> int:
        """Connections turned away for violating the wire protocol
        (bad magic, version mismatch, malformed first frame)."""
        with self._recv_lock:
            return self._rejected

    def half_close_workers(self) -> None:
        """Send EOF to every worker (params direction) while still
        draining their in-flight gradient frames — the clean-shutdown
        signal for process workers.  Workers that connect *after* this
        call are half-closed on arrival (see the accept loop), so a
        late-starting respawn can never outlive the run."""
        self._draining = True
        with self._conns_cond:
            conns = list(self._conns)
        for conn in conns:
            conn.half_close()

    def quiesce(self, timeout: Optional[float] = None) -> bool:
        """True once every connection reader has drained to EOF (all
        producers must already be stopped/closed).  Interleave with
        ``recv_gradient(timeout=0)`` drains: a reader blocked on the
        bounded queue needs the caller to make room.  Serve and stats
        connections are skipped: they produce no gradients, so the
        conservation ledger owes them nothing — and a lingering
        read-only subscriber must never hold up training shutdown."""
        deadline = None if timeout is None else \
            time.monotonic() + max(0.0, timeout)
        with self._conns_cond:
            conns = [c for c in self._conns
                     if not c.is_serve and not c.is_stats]
        for conn in conns:
            remain = None if deadline is None else \
                max(0.0, deadline - time.monotonic())
            conn.reader.join(timeout=remain)
            if conn.reader.is_alive():
                return False
        return True

    def close(self) -> None:
        self._closed.set()
        try:
            self._lsock.close()
        except OSError:
            pass
        with self._conns_cond:
            conns = list(self._conns)
        for conn in conns:
            conn.close()
        self._accept_thread.join(timeout=2.0)
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=2.0)
        if self._stats_thread is not None:
            self._stats_thread.join(timeout=2.0)
        if self.family == "unix":
            for path in (self.address,):
                try:
                    os.unlink(path)
                except OSError:
                    pass
            if self._sockdir:
                try:
                    os.rmdir(self._sockdir)
                except OSError:
                    pass


# ======================================================== worker side


class SocketWorkerClient:
    """The worker half of the protocol over one socket connection.

    ``send_gradient`` enqueues into a small bounded outbound queue
    drained by a sender thread (so a timed-out send never leaves a torn
    frame on the wire — the frame is sent whole or not at all), and
    ``fetch_params`` waits on a local versioned cell kept current by a
    reader thread — the same broadcast-cell semantics as
    :class:`InProcTransport`.

    :attr:`closed` is set when the connection dies (server shutdown,
    kill, network error); runtimes wire it up as the worker's stop
    event so a dead server can never leave a live worker spinning.

    ``heartbeat_timeout_s > 0`` arms a liveness watchdog: if *no* frame
    (params, PING, anything) arrives for that long, the leader is
    declared hung — a state EOF detection can never see, because a
    wedged process holds its sockets open — :attr:`stall_reason` is set
    with a readable error and the connection closes, which stops the
    worker through the usual dead-server path.
    """

    def __init__(self, address: Any, worker_id: int, *,
                 generation: int = 0, family: str = "unix",
                 send_capacity: int = 2, connect_timeout: float = 10.0,
                 heartbeat_timeout_s: float = 0.0,
                 sock: Optional[socket.socket] = None,
                 slab_dtype: str = "f32"):
        if slab_dtype not in _DT_CODES:
            raise ValueError(f"slab_dtype must be one of "
                             f"{sorted(_DT_CODES)}, got {slab_dtype!r}")
        self.worker_id = worker_id
        self.generation = generation
        self.slab_dtype = slab_dtype
        self.reject_reason: Optional[str] = None
        self.stall_reason: Optional[str] = None
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        self._last_rx = time.monotonic()
        if sock is None:
            if family == "unix":
                sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                sock.settimeout(connect_timeout)
                sock.connect(address)
            else:
                sock = socket.create_connection(tuple(address),
                                                timeout=connect_timeout)
        # else: adopt an already-connected socket (e.g. the one a JOIN
        # handshake negotiated the worker-id lease on — see hostlink)
        sock.settimeout(None)
        _configure(sock)
        self.sock = sock
        self.closed = threading.Event()
        self._cell: Optional[ParamsMsg] = None
        self._cond = threading.Condition()
        self._sendq: "queue.Queue[GradientMsg]" = \
            queue.Queue(maxsize=max(1, send_capacity))
        self._close_lock = threading.Lock()
        self._closed_once = False
        self._wlock = threading.Lock()      # whole frames only: the
        #                                     sender thread and PONG
        #                                     replies share one socket
        self.sock.sendall(_hello_frame(worker_id, generation,
                                       slab_dtype))
        self._reader = threading.Thread(
            target=self._read_loop, name=f"client-reader-{worker_id}",
            daemon=True)
        self._sender = threading.Thread(
            target=self._send_loop, name=f"client-sender-{worker_id}",
            daemon=True)
        self._reader.start()
        self._sender.start()
        if self.heartbeat_timeout_s > 0:
            threading.Thread(target=self._watchdog_loop,
                             name=f"client-watchdog-{worker_id}",
                             daemon=True).start()

    # ------------------------------------------------------ wire threads
    def _read_loop(self) -> None:
        try:
            while not self.closed.is_set():
                hdr, _ = _recv_exact(self.sock, _HDR.size)
                if hdr is None:
                    break
                ftype, n = _HDR.unpack(hdr)
                if n > _MAX_FRAME:
                    break
                payload, _ = _recv_exact(self.sock, n)
                if payload is None:
                    break
                self._last_rx = time.monotonic()
                if ftype == _F_PING:
                    # reply best-effort; the hub only cares that bytes
                    # flow back, and a send error surfaces on the next
                    # gradient anyway
                    with self._wlock:
                        try:
                            self.sock.sendall(_pong_frame())
                        except OSError:
                            break
                elif ftype == _F_PARAMS and n >= _PARAMS.size \
                        and (n - _PARAMS.size) \
                        % _SLAB_ITEMSIZE[self.slab_dtype] == 0:
                    version, epoch = _PARAMS.unpack(
                        payload[:_PARAMS.size])
                    slab = _slab_from_payload(payload, _PARAMS.size,
                                              self.slab_dtype)
                    with self._cond:
                        self._cell = ParamsMsg(version, slab,
                                               epoch=epoch)
                        self._cond.notify_all()
                elif ftype == _F_REJECT:
                    reason = payload[_CTRL.size:].decode(
                        "utf-8", "replace") if n >= _CTRL.size else ""
                    self.reject_reason = reason or "rejected by hub"
                    _log.warning("hub rejected worker %d.%d: %s",
                                 self.worker_id, self.generation,
                                 self.reject_reason)
                    break
        finally:
            self._mark_closed()

    def _send_loop(self) -> None:
        while True:
            try:
                msg = self._sendq.get(timeout=0.1)
            except queue.Empty:
                if self.closed.is_set():
                    return
                continue
            try:
                with self._wlock:
                    self.sock.sendall(_grad_frame(msg,
                                                  self.slab_dtype))
            except OSError:
                # the frame was accepted but never shipped: do NOT
                # task_done() it — flush() must not claim it landed
                self._mark_closed()
                return
            self._sendq.task_done()

    def _watchdog_loop(self) -> None:
        """Declare the leader hung when no frame of any kind arrives
        within ``heartbeat_timeout_s`` — then close, so every blocked
        path (fetch_params, the worker loop) unwinds promptly."""
        timeout = self.heartbeat_timeout_s
        while not self.closed.wait(min(timeout / 4.0, 1.0)):
            idle = time.monotonic() - self._last_rx
            if idle > timeout:
                self.stall_reason = (
                    f"no frames from the hub for {idle:.1f}s (liveness "
                    f"timeout {timeout:.1f}s) — the leader looks hung; "
                    "giving up on this connection")
                _log.warning("worker %d.%d: %s", self.worker_id,
                             self.generation, self.stall_reason)
                self.close()
                return

    def _mark_closed(self) -> None:
        self.closed.set()
        with self._cond:
            self._cond.notify_all()         # wake blocked fetch_params

    # ------------------------------------------- Transport (worker half)
    def send_gradient(self, msg: GradientMsg,
                      timeout: Optional[float] = None) -> bool:
        if timeout is not None and timeout <= 0:
            if self.closed.is_set():
                return False
            try:
                self._sendq.put_nowait(msg)
                return True
            except queue.Full:
                return False
        deadline = None if timeout is None else \
            time.monotonic() + timeout
        while not self.closed.is_set():
            remain = None if deadline is None else \
                deadline - time.monotonic()
            if remain is not None and remain <= 0:
                return False
            try:
                self._sendq.put(msg, timeout=0.05 if remain is None
                                else min(0.05, remain))
                return True
            except queue.Full:
                continue
        return False

    def fetch_params(self, min_version: int = 0,
                     timeout: Optional[float] = None
                     ) -> Optional[ParamsMsg]:
        def ok() -> bool:
            return (self._cell is not None
                    and self._cell.version >= min_version)
        with self._cond:
            if timeout is not None and timeout <= 0:
                return self._cell if ok() else None
            deadline = None if timeout is None else \
                time.monotonic() + timeout
            while not ok():
                if self.closed.is_set():
                    return None
                remain = None if deadline is None else \
                    deadline - time.monotonic()
                if remain is not None and remain <= 0:
                    return None
                self._cond.wait(0.1 if remain is None
                                else min(0.1, remain))
            return self._cell

    def pending_gradients(self) -> int:
        return self._sendq.qsize()

    # the worker half never receives gradients or publishes params
    def recv_gradient(self, timeout: Optional[float] = None):
        raise NotImplementedError("worker-side endpoint")

    def publish_params(self, msg: ParamsMsg) -> None:
        raise NotImplementedError("worker-side endpoint")

    # ------------------------------------------------------- lifecycle
    def flush(self, timeout: float = 5.0) -> bool:
        """Block until every accepted gradient is on the wire — clean
        shutdown must not strand sent-but-unshipped gradients (the
        ledger counts them as computed).  Note this waits on the sender
        *thread*, not on :attr:`closed`: a hub half-close (EOF on the
        params direction) sets ``closed`` while the gradient direction
        is still perfectly writable, and bailing there would tear the
        final frames."""
        deadline = time.monotonic() + timeout
        while self._sendq.unfinished_tasks:
            if not self._sender.is_alive() \
                    or time.monotonic() > deadline:
                return self._sendq.unfinished_tasks == 0
            time.sleep(0.01)
        return True

    def can_flush(self) -> bool:
        """Whether unshipped frames can still make progress — the
        sender thread is alive.  A dead sender means the connection is
        gone and the remaining frames are lost; waiting on them is
        pointless."""
        return self._sender.is_alive()

    def quiesce(self, timeout: Optional[float] = None) -> bool:
        return self.flush(timeout if timeout is not None else 5.0)

    def close(self) -> None:
        with self._close_lock:
            if self._closed_once:
                return
            self._closed_once = True
        self._mark_closed()
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


# ================================================== process launcher


@dataclasses.dataclass
class ProcWorkerConfig:
    """Everything a worker process needs to rebuild its world: the
    experiment spec (to rebuild the workload via the ``SIM_WORKLOADS``
    registry — code does not cross the process boundary, only this
    picklable description does), its identity/shard, and the hub
    address.  ``platform`` forces ``JAX_PLATFORMS`` in the child (set
    to ``"cpu"`` when the parent holds an exclusive accelerator — two
    processes cannot share one TPU)."""
    spec: Dict[str, Any]
    worker_id: int
    generation: int
    num_workers: int
    mode: str
    straggle_s: float
    seed: int
    batch: int
    address: Any = None
    family: str = "unix"
    platform: Optional[str] = None


def _proc_worker_main(cfg: ProcWorkerConfig) -> None:
    """Child entry point: rebuild the workload, compile the slab
    gradient executable, and only then connect (HELLO == ready), so the
    parent's wall-clock budget measures contention — not XLA."""
    if cfg.platform:
        os.environ["JAX_PLATFORMS"] = cfg.platform
    try:
        from repro.api.spec import ExperimentSpec
        from repro.cluster.hostlink import build_slab_worker_fn
        from repro.cluster.worker import Worker

        spec = ExperimentSpec.from_dict(cfg.spec)
        grad, fresh_batches = build_slab_worker_fn(
            spec, cfg.worker_id, cfg.num_workers, cfg.generation,
            batch=cfg.batch, seed=cfg.seed)
        client = SocketWorkerClient(cfg.address, cfg.worker_id,
                                    generation=cfg.generation,
                                    family=cfg.family,
                                    slab_dtype=spec.slab_dtype)
    except Exception:
        import traceback
        traceback.print_exc()
        sys.stderr.flush()
        os._exit(2)

    worker = Worker(cfg.worker_id, grad_fn=grad,
                    batches=fresh_batches(), transport=client,
                    mode=cfg.mode, straggle_s=cfg.straggle_s,
                    generation=cfg.generation)
    # server shutdown/death closes the connection -> closed is set ->
    # the loop exits: a dead server can never leave this process alive
    worker.stop_event = client.closed
    worker.run()                            # inline, not as a thread
    client.flush(5.0)
    client.close()
    code = 0
    if worker.error:
        print(worker.error, file=sys.stderr, flush=True)
        code = 3
    sys.stdout.flush()
    sys.stderr.flush()
    # skip interpreter finalization: tearing down a JAX runtime's C++
    # thread pools from a fast-exiting spawned child intermittently
    # aborts (std::terminate) after all real work is already flushed
    os._exit(code)


class ProcTransport(SocketTransport):
    """The multi-process transport: a Unix-domain (or TCP) socket hub
    plus a ``multiprocessing`` *spawn* launcher — each worker is a
    fresh OS process with its own JAX runtime that connects back to the
    hub once compiled.  ``FaultPlan`` kills are **SIGKILL**: worker
    death is an OS fact, and the hub's torn-frame handling plus
    received-side accounting keep the conservation ledger exact through
    it.  Spawn (not fork) because forking a process with a live JAX
    runtime is undefined behaviour."""

    def __init__(self, grad_capacity: int = 0, *, family: str = "unix",
                 host: str = "127.0.0.1", slab_dtype: str = "f32"):
        super().__init__(grad_capacity, family=family, host=host,
                         slab_dtype=slab_dtype)
        import multiprocessing
        self._ctx = multiprocessing.get_context("spawn")
        self._procs: Dict[int, Any] = {}            # live, by worker id
        self._all_procs: List[Tuple[int, int, Any]] = []
        self._killed: Set[int] = set()              # pids we SIGKILLed

    # -------------------------------------------------------- processes
    def spawn_worker(self, cfg: ProcWorkerConfig):
        cfg = dataclasses.replace(cfg, address=self.address,
                                  family=self.family)
        p = self._ctx.Process(
            target=_proc_worker_main, args=(cfg,),
            name=f"worker-{cfg.worker_id}.{cfg.generation}", daemon=True)
        p.start()
        self._procs[cfg.worker_id] = p
        self._all_procs.append((cfg.worker_id, cfg.generation, p))
        return p

    def kill_worker(self, worker_id: int) -> bool:
        """SIGKILL the worker's current process (no cooperation, no
        cleanup — the fault the paper's cluster baseline worries
        about).  Returns True if a live process was signalled."""
        p = self._procs.get(worker_id)
        if p is None or not p.is_alive():
            return False
        self._killed.add(p.pid)
        p.kill()
        return True

    def procs_alive(self) -> bool:
        """Any spawned worker process still running?"""
        return any(p.is_alive() for _, _, p in self._all_procs)

    def kill_unconnected(self) -> None:
        """SIGKILL worker processes that never finished connecting —
        e.g. a respawned worker still importing JAX / compiling when
        the run ends.  They have sent nothing, so there is nothing to
        flush or account; the EOF-based shutdown can't reach them (no
        connection), and waiting out their startup would stall
        teardown.  Planned kills, not errors."""
        with self._conns_cond:
            connected = {(c.worker_id, c.generation)
                         for c in self._conns
                         if c.worker_id is not None}
        for wid, gen, p in self._all_procs:
            if p.is_alive() and (wid, gen) not in connected:
                self._killed.add(p.pid)
                p.kill()

    def dead_workers(self) -> List[str]:
        """Processes that already exited abnormally (no planned SIGKILL)
        — lets the fleet-ready barrier fail fast instead of waiting out
        its timeout on a child that crashed during startup."""
        out = []
        for wid, gen, p in self._all_procs:
            code = p.exitcode
            if code is None or code == 0:
                continue
            if code < 0 and p.pid in self._killed:
                continue
            out.append(f"worker process {wid}.{gen} exited with code "
                       f"{code} (see its stderr above)")
        return out

    def join_workers(self, timeout: float = 10.0) -> List[str]:
        """Join every spawned process, escalating to SIGKILL past the
        deadline.  Returns human-readable errors for processes that
        failed (crashed with a traceback) rather than exited cleanly or
        by a planned SIGKILL."""
        errors: List[str] = []
        deadline = time.monotonic() + timeout
        for wid, gen, p in self._all_procs:
            p.join(timeout=max(0.0, deadline - time.monotonic()))
            if p.is_alive():
                self._killed.add(p.pid)
                p.kill()
                p.join(timeout=2.0)
                errors.append(f"worker process {wid}.{gen} did not stop "
                              "within the join timeout (SIGKILLed)")
                continue
            code = p.exitcode
            planned_kill = (code is not None and code < 0
                            and p.pid in self._killed)
            if code not in (0, None) and not planned_kill:
                errors.append(f"worker process {wid}.{gen} exited with "
                              f"code {code} (see its stderr above)")
        return errors

    def close(self) -> None:
        for _, _, p in self._all_procs:
            if p.is_alive():
                self._killed.add(p.pid)
                p.kill()
        for _, _, p in self._all_procs:
            if p.is_alive():
                p.join(timeout=2.0)
        super().close()
