"""The wall-clock cluster runtime: server + workers + faults + metrics.

:class:`ClusterRuntime` wires one :class:`~repro.cluster.server.
ParameterServer`, a pool of :class:`~repro.cluster.worker.Worker`
threads, a :class:`~repro.cluster.transport.InProcTransport`, and the
:class:`~repro.cluster.faults.FaultPlan` injector, then runs until a
wall-clock budget elapses or an applied-gradient budget is hit.

Pieces that run concurrently with training:

  * **metric sampler** — snapshots the live params on a fixed wall-clock
    grid.  It holds the *published* params slab — by the donation
    contract a fresh, never-donated executable output, so the reference
    costs nothing and stays valid — and all decoding plus loss/accuracy
    evaluation happens *after* the run, so measurement never perturbs
    the contention being measured;
  * **fault injector** — kills workers at their planned times (and
    deregisters them so a sync barrier cannot deadlock on the dead),
    respawning them after ``respawn_after_s`` with a fresh data-stream
    generation;
  * **checkpointer** — saves the server state via :mod:`repro.checkpoint`
    on a cadence, and optionally restores the latest checkpoint mid-run
    (``restore_at_s``, simulated server recovery).

Everything blocking takes a timeout and every thread watches a stop
event, so a wedged run degrades to "budget elapses, run ends" rather
than a hang.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.cluster.faults import FaultPlan
from repro.cluster.server import ParameterServer
from repro.cluster.transport import InProcTransport, Transport
from repro.cluster.worker import Worker
from repro.core.schedule import ThresholdSchedule, constant_schedule
from repro.core.slab import slab_codec
from repro.data.pipeline import shard_iterator


@dataclasses.dataclass
class ClusterResult:
    """What one cluster run produced (adapted into ``RunResult`` by
    :class:`repro.cluster.trainer.ClusterTrainer`)."""
    times: np.ndarray            # wall-clock metric grid (seconds)
    train_loss: np.ndarray
    test_loss: np.ndarray
    test_acc: np.ndarray
    num_updates: int             # parameter updates applied this run
    num_gradients: int           # == the server's applied counter, exactly
    mode: str
    start_version: int           # >0 when resumed from a checkpoint
    accounting: Dict[str, int]   # applied/dropped/buffered/... + computed
    events: List[Dict[str, Any]]   # kills, respawns, checkpoints, restores
    final_params: Any
    wall_s: float


class ClusterRuntime:
    """One wall-clock parameter-server training run."""

    def __init__(self, loss_fn: Callable, init_params, data, *,
                 mode: str, lr: float = 0.01, batch: int = 32,
                 num_workers: int = 4, wall_budget_s: float = 5.0,
                 sample_every_s: float = 0.25,
                 schedule: Optional[ThresholdSchedule] = None,
                 flush_mode: str = "sum", staleness_decay: float = 1.0,
                 max_gradients: Optional[int] = None, seed: int = 0,
                 faults: FaultPlan = FaultPlan(),
                 accuracy_fn: Optional[Callable] = None,
                 transport: Optional[Transport] = None,
                 ckpt_dir: Optional[str] = None,
                 resume_from: Optional[str] = None,
                 verbose: bool = False):
        assert mode in ("sync", "async", "hybrid")
        if mode == "async":
            schedule = constant_schedule(num_workers, 1)
        if mode == "hybrid":
            assert schedule is not None, "hybrid mode needs a schedule"
        bad_ids = sorted({wid for wid, _ in (*faults.stragglers,
                                             *faults.kill)
                          if wid >= num_workers})
        if bad_ids:
            raise ValueError(
                f"FaultPlan names worker ids {bad_ids} but the fleet "
                f"has only {num_workers} workers (ids 0.."
                f"{num_workers - 1})")
        if (faults.checkpoint_every_s > 0 or faults.restore_at_s > 0) \
                and not ckpt_dir:
            raise ValueError(
                "FaultPlan requests checkpointing "
                f"(checkpoint_every_s={faults.checkpoint_every_s}, "
                f"restore_at_s={faults.restore_at_s}) but no ckpt_dir "
                "was given — pass --ckpt-dir / ClusterTrainer(ckpt_dir=)")
        # every metric snapshot holds a full parameter pytree until the
        # post-run evaluation; bound the count so a long budget with a
        # fine grid fails loudly instead of exhausting host memory
        if wall_budget_s / sample_every_s > 4096:
            raise ValueError(
                f"wall_budget_s/sample_every_s = "
                f"{wall_budget_s / sample_every_s:.0f} metric snapshots "
                "(> 4096), each retaining a full parameter copy — "
                "increase sample_every_s")
        self.loss_fn = loss_fn
        self.init_params = init_params
        self.x_tr, self.y_tr, self.x_te, self.y_te = data
        self.mode = mode
        self.lr = lr
        self.batch = batch
        self.num_workers = num_workers
        self.wall_budget_s = wall_budget_s
        self.sample_every_s = sample_every_s
        self.schedule = schedule
        self.flush_mode = flush_mode
        self.staleness_decay = staleness_decay
        self.max_gradients = max_gradients
        self.seed = seed
        self.faults = faults
        # bounded queue = backpressure: a worker whose gradient the
        # server can't take yet blocks, as on a real wire
        self.transport = transport or InProcTransport(
            grad_capacity=max(4, 2 * num_workers))
        self.ckpt_dir = ckpt_dir
        self.resume_from = resume_from
        self.verbose = verbose

        # the slab wire format: workers fetch a params *slab*, decode,
        # differentiate, and re-encode the gradient — all in one jitted
        # executable, so each gradient ships as a single contiguous
        # (P,) array and is flattened exactly once, on the worker
        self.codec = slab_codec(init_params)
        grad_fn = jax.grad(loss_fn)

        def _grad_slab(p_slab, x, y):
            return self.codec.encode(
                grad_fn(self.codec.decode(p_slab), x, y))

        self._grad = jax.jit(_grad_slab)
        self._loss = jax.jit(loss_fn)
        self._acc = accuracy_fn

        self._stop = threading.Event()
        self._workers: Dict[int, Worker] = {}
        self._all_workers: List[Worker] = []
        self._generation: Dict[int, int] = {}
        self.events: List[Dict[str, Any]] = []
        self._control_errors: List[str] = []
        self._t0 = 0.0

    def _guarded(self, fn: Callable, name: str) -> threading.Thread:
        """Control thread whose failure is captured and re-raised by
        ``run()`` — a dead checkpointer/injector means the fault plan
        was not executed, which must not look like a clean run."""
        def body():
            try:
                fn()
            except Exception:
                import traceback
                self._control_errors.append(
                    f"{name}:\n{traceback.format_exc()}")
        return threading.Thread(target=body, name=name, daemon=True)

    # ------------------------------------------------------------ hooks
    def _elapsed(self) -> float:
        return time.monotonic() - self._t0

    def _log_event(self, kind: str, **kw) -> None:
        ev = {"t": round(self._elapsed(), 3), "event": kind, **kw}
        self.events.append(ev)
        if self.verbose:
            print(f"[cluster +{ev['t']:6.2f}s] {kind} "
                  f"{ {k: v for k, v in kw.items()} }", flush=True)

    def _spawn(self, wid: int) -> None:
        gen = self._generation.get(wid, -1) + 1
        self._generation[wid] = gen
        batches = shard_iterator(self.x_tr, self.y_tr, wid,
                                 self.num_workers, self.batch,
                                 seed=self.seed, generation=gen)
        w = Worker(wid, grad_fn=self._grad, batches=batches,
                   transport=self.transport, mode=self.mode,
                   straggle_s=self.faults.straggle_s(wid), generation=gen)
        self._workers[wid] = w
        self._all_workers.append(w)
        self.server.register(wid)
        w.start()

    def _kill(self, wid: int) -> None:
        w = self._workers.get(wid)
        if w is not None:
            w.stop_event.set()
        self.server.deregister(wid)
        self._log_event("kill", worker=wid)

    # ------------------------------------------------- background loops
    def _injector(self) -> None:
        # one merged timeline: a pending respawn must not delay (or
        # starve) later kill events, so kills and respawns interleave
        # in wall-clock order ("kill" sorts before "spawn" on ties —
        # a kill and a respawn at the same instant kill first)
        events = [(t, "kill", wid) for t, wid in self.faults.kill_events()]
        if self.faults.respawn_after_s > 0:
            events += [(t + self.faults.respawn_after_s, "spawn", wid)
                       for t, wid in self.faults.kill_events()]
        for t, kind, wid in sorted(events):
            if self._stop.wait(max(0.0, t - self._elapsed())):
                return
            if kind == "kill":
                self._kill(wid)
            else:
                self._spawn(wid)
                self._log_event("respawn", worker=wid,
                                generation=self._generation[wid])

    def _checkpointer(self) -> None:
        while not self._stop.wait(self.faults.checkpoint_every_s):
            version, params, applied = self.server.snapshot()
            path = os.path.join(self.ckpt_dir, f"step_{version}")
            save_checkpoint(path, params, version,
                            extra={"mode": self.mode, "applied": applied,
                                   "backend": "cluster"})
            self._log_event("checkpoint", step=version)

    def _restorer(self) -> None:
        if self._stop.wait(self.faults.restore_at_s):
            return
        step = latest_step(self.ckpt_dir)
        if step is None:
            self._log_event("restore_skipped", reason="no checkpoint yet")
            return
        path = os.path.join(self.ckpt_dir, f"step_{step}")
        params, step = restore_checkpoint(path, like=self.init_params)
        self.server.restore(params, step)
        self._log_event("restore", step=step)

    def _sampler(self, snaps: List) -> None:
        # snapshot_slab is zero work (a reference to the published,
        # never-donated params slab): sampling must not steal decode /
        # host-copy time from the serial resource it is measuring —
        # the slabs are decoded after the run, with the metrics
        i = 0
        while True:
            target = i * self.sample_every_s
            wait = target - self._elapsed()
            if wait > 0 and self._stop.wait(wait):
                return
            if self._stop.is_set():
                return
            version, slab, _ = self.server.snapshot_slab()
            snaps.append((target, version, slab))
            i += 1

    # -------------------------------------------------------------- run
    def run(self) -> ClusterResult:
        start_version = 0
        start_params = self.init_params
        if self.resume_from:
            start_params, start_version = restore_checkpoint(
                self.resume_from, like=self.init_params)

        # compile the worker gradient before the clock starts, so the
        # budget measures contention, not XLA (the metric fns are only
        # evaluated after the run, so they need no warm-up)
        wx, wy = next(shard_iterator(self.x_tr, self.y_tr, 0,
                                     self.num_workers, self.batch,
                                     seed=self.seed))
        jax.block_until_ready(
            self._grad(self.codec.encode(start_params), wx, wy))

        self.server = ParameterServer(
            start_params, lr=self.lr, mode=self.mode,
            transport=self.transport, num_workers=self.num_workers,
            schedule=self.schedule, flush_mode=self.flush_mode,
            staleness_decay=self.staleness_decay,
            max_gradients=self.max_gradients, start_version=start_version)

        self._t0 = time.monotonic()
        if start_version:
            self._log_event("resume", step=start_version,
                            path=self.resume_from)
        snaps: List = []
        threads = [self._guarded(lambda: self._sampler(snaps), "sampler")]
        if self.faults.kill:
            threads.append(self._guarded(self._injector, "injector"))
        if self.ckpt_dir and self.faults.checkpoint_every_s > 0:
            threads.append(self._guarded(self._checkpointer, "ckpt"))
        if self.ckpt_dir and self.faults.restore_at_s > 0:
            threads.append(self._guarded(self._restorer, "restore"))
        for t in threads:
            t.start()
        for wid in range(self.num_workers):
            self._spawn(wid)

        deadline = self._t0 + self.wall_budget_s
        while time.monotonic() < deadline and not self.server.done.is_set():
            msg = self.transport.recv_gradient(
                timeout=min(0.02, max(1e-3, deadline - time.monotonic())))
            if msg is not None:
                self.server.ingest(msg)
        wall_s = self._elapsed()

        # ------------------------------------------------------ shutdown
        # control threads first: the injector must be fully stopped
        # before worker stop events are set, or a respawn racing the
        # shutdown would start a worker nobody stops (all its waits
        # watch self._stop, so these joins return promptly)
        self._stop.set()
        for t in threads:
            t.join(timeout=10.0)
        for w in self._all_workers:
            w.stop_event.set()
        for w in self._all_workers:
            w.join(timeout=10.0)
        errors = [f"worker {w.worker_id}.{w.generation}:\n{w.error}"
                  for w in self._all_workers if w.error]
        errors += self._control_errors
        # a thread that outlived its join would keep mutating transport/
        # server state under the accounting we are about to report
        errors += [f"{t.name} did not stop within the join timeout"
                   for t in (*self._all_workers, *threads)
                   if t.is_alive()]
        if errors:
            raise RuntimeError("cluster thread(s) crashed or hung:\n"
                               + "\n".join(errors))

        in_flight = 0
        while self.transport.recv_gradient(timeout=0) is not None:
            in_flight += 1
        accounting = self.server.accounting()
        accounting["in_flight"] = in_flight
        accounting["computed"] = sum(w.sent for w in self._all_workers)
        per_worker: Dict[str, int] = {}
        for w in self._all_workers:     # all generations of each id
            key = str(w.worker_id)
            per_worker[key] = per_worker.get(key, 0) + w.sent
        accounting["computed_per_worker"] = per_worker

        # ---------------------------------- evaluate the metric snapshots
        times, tr, te, acc = [], [], [], []
        for target, _, slab in snaps:
            params = self.codec.decode(slab)
            times.append(target)
            tr.append(float(self._loss(params, self.x_tr[:2048],
                                       self.y_tr[:2048])))
            te.append(float(self._loss(params, self.x_te, self.y_te)))
            acc.append(float(self._acc(params, self.x_te, self.y_te))
                       if self._acc is not None else 0.0)

        # snapshot() already returns a host copy (the donation rule:
        # nothing escaping the server may alias the donated slab)
        _, final_params, applied = self.server.snapshot()
        return ClusterResult(
            times=np.asarray(times), train_loss=np.asarray(tr),
            test_loss=np.asarray(te), test_acc=np.asarray(acc),
            num_updates=accounting["updates"], num_gradients=applied,
            mode=self.mode, start_version=start_version,
            accounting=accounting, events=list(self.events),
            final_params=final_params, wall_s=wall_s)
