"""The wall-clock cluster runtime: server + workers + faults + metrics.

:class:`ClusterRuntime` wires one :class:`~repro.cluster.server.
ParameterServer`, a worker fleet, a transport, and the
:class:`~repro.cluster.faults.FaultPlan` injector, then runs until a
wall-clock budget elapses or an applied-gradient budget is hit.

Four transports (``transport_kind``, = ``ExperimentSpec.transport``):

  * ``inproc`` — worker *threads* + an in-process queue (default; the
    parity baseline).  Gradient compute shares one GIL/JAX runtime;
  * ``socket`` — worker threads, but every message crosses a real TCP
    socket as a length-prefixed slab frame (the wire format is
    physical; the address space is still shared);
  * ``proc``   — one OS *process* per worker over Unix-domain sockets
    (:mod:`repro.cluster.mptransport`): each worker has its own JAX
    runtime, FaultPlan kills are SIGKILL, and the fleet-ready barrier
    starts the clock only after every child has compiled and connected
    (so the budget measures contention, not XLA).  Requires
    ``spec_dict`` — worker processes rebuild the workload from the
    experiment spec via the ``SIM_WORKLOADS`` registry;
  * ``host``   — the multi-host mode (:mod:`repro.cluster.hostlink`):
    the server binds ``listen`` (``HOST:PORT``) and *waits* for remote
    workers to join via ``python -m repro join HOST:PORT`` — the spec
    travels to them in the leader handshake, worker ids are leased
    (with generation fencing), and the fleet-ready barrier is "every
    expected worker has joined".  Kill faults cut the worker's
    connection (the leader cannot SIGKILL a remote process); respawns
    are rejected — replacement capacity rejoins from its own host.

Pieces that run concurrently with training:

  * **metric sampler** — snapshots the live params on a fixed wall-clock
    grid.  It holds the *published* params slab — by the donation
    contract a fresh, never-donated executable output, so the reference
    costs nothing and stays valid — and all decoding plus loss/accuracy
    evaluation happens *after* the run, so measurement never perturbs
    the contention being measured;
  * **fault injector** — kills workers at their planned times (and
    deregisters them so a sync barrier cannot deadlock on the dead),
    respawning them after ``respawn_after_s`` with a fresh data-stream
    generation;
  * **checkpointer** — saves the server state via :mod:`repro.checkpoint`
    on a cadence, and optionally restores the latest checkpoint mid-run
    (``restore_at_s``, simulated server recovery).

Everything blocking takes a timeout and every thread watches a stop
event, so a wedged run degrades to "budget elapses, run ends" rather
than a hang.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import (latest_step, load_opt_state,
                              restore_checkpoint, save_checkpoint)
from repro.cluster.faults import FaultPlan
from repro.cluster.mptransport import (ProcTransport, ProcWorkerConfig,
                                       SocketTransport)
from repro.cluster.server import ParameterServer
from repro.cluster.transport import TRANSPORTS, InProcTransport, Transport
from repro.cluster.worker import Worker
from repro.core.schedule import ThresholdSchedule, constant_schedule
from repro.core.slab import slab_codec
from repro.data.pipeline import shard_iterator
from repro.obs.telemetry import Telemetry
from repro.optim.slab_form import SlabOptimizer

_log = logging.getLogger("repro.cluster.runtime")


@dataclasses.dataclass
class ClusterResult:
    """What one cluster run produced (adapted into ``RunResult`` by
    :class:`repro.cluster.trainer.ClusterTrainer`)."""
    times: np.ndarray            # wall-clock metric grid (seconds)
    train_loss: np.ndarray
    test_loss: np.ndarray
    test_acc: np.ndarray
    num_updates: int             # parameter updates applied this run
    num_gradients: int           # == the server's applied counter, exactly
    mode: str
    start_version: int           # >0 when resumed from a checkpoint
    accounting: Dict[str, int]   # applied/dropped/buffered/... + computed
    events: List[Dict[str, Any]]   # kills, respawns, checkpoints, restores
    final_params: Any
    wall_s: float
    # serving plane: per-serve-client push stats.  Always a dict on the
    # cluster backend (empty-shaped when the transport has no serving
    # plane), so consumers key on content, not key presence
    serving: Optional[Dict[str, Any]] = None
    # telemetry plane: the obs summary (counters / gauges / histograms)
    # plus a ledger_check block cross-checking the telemetry counters
    # against the conservation ledger
    telemetry: Optional[Dict[str, Any]] = None


class ClusterRuntime:
    """One wall-clock parameter-server training run."""

    def __init__(self, loss_fn: Callable, init_params, data, *,
                 mode: str, lr: float = 0.01, batch: int = 32,
                 num_workers: int = 4, wall_budget_s: float = 5.0,
                 sample_every_s: float = 0.25,
                 schedule: Optional[ThresholdSchedule] = None,
                 flush_mode: str = "sum", staleness_decay: float = 1.0,
                 max_gradients: Optional[int] = None, seed: int = 0,
                 faults: FaultPlan = FaultPlan(),
                 accuracy_fn: Optional[Callable] = None,
                 transport: Optional[Transport] = None,
                 transport_kind: str = "inproc",
                 spec_dict: Optional[Dict[str, Any]] = None,
                 listen: Optional[str] = None,
                 heartbeat_s: float = 2.0, serve_every: int = 1,
                 max_workers: Optional[int] = None,
                 join_secret: Optional[str] = None,
                 lease_grace_s: float = 2.0,
                 slab_dtype: str = "f32",
                 optimizer: Optional[SlabOptimizer] = None,
                 proc_ready_timeout_s: float = 180.0,
                 verbose: bool = False,
                 ckpt_dir: Optional[str] = None,
                 resume_from: Optional[str] = None,
                 trace: Optional[str] = None,
                 prom_port: Optional[int] = None):
        assert mode in ("sync", "async", "hybrid")
        if transport_kind not in TRANSPORTS:
            raise ValueError(f"transport_kind must be one of {TRANSPORTS},"
                             f" got {transport_kind!r}")
        if transport_kind == "proc" and spec_dict is None:
            raise ValueError(
                'transport_kind="proc" needs spec_dict (an ExperimentSpec'
                " dict): worker processes rebuild the workload from it "
                "via the SIM_WORKLOADS registry — run through "
                'ClusterTrainer / repro.api.run(spec) with '
                'spec.transport="proc"')
        if transport_kind == "host" and spec_dict is None \
                and transport is None:
            raise ValueError(
                'transport_kind="host" needs spec_dict (an ExperimentSpec'
                " dict): it is what joining hosts receive in the leader "
                "handshake and rebuild their workload from — run through "
                'ClusterTrainer / repro.api.run(spec) with '
                'spec.transport="host"')
        if transport_kind == "host" and faults.respawn_after_s > 0:
            raise ValueError(
                "the host transport cannot respawn remote workers (the "
                "leader does not own the remote machine) — drop "
                "respawn_after_s and rejoin replacement capacity with "
                "`python -m repro join` instead")
        if mode == "async":
            schedule = constant_schedule(num_workers, 1)
        if mode == "hybrid":
            assert schedule is not None, "hybrid mode needs a schedule"
        # elastic admission is a host-transport feature: the other
        # transports own their whole fleet at construction time
        if max_workers is not None and transport_kind != "host":
            raise ValueError(
                "max_workers (elastic admission) requires "
                'transport_kind="host" — the other transports spawn '
                "their entire fleet up front")
        self.max_workers = max(num_workers, int(max_workers
                                                or num_workers))
        # faults may target any admissible worker id, including elastic
        # ones that have not joined yet (a kill aimed at an absent
        # worker just finds nobody)
        faults.validate_worker_ids(self.max_workers)
        if (faults.checkpoint_every_s > 0 or faults.restore_at_s > 0) \
                and not ckpt_dir:
            raise ValueError(
                "FaultPlan requests checkpointing "
                f"(checkpoint_every_s={faults.checkpoint_every_s}, "
                f"restore_at_s={faults.restore_at_s}) but no ckpt_dir "
                "was given — pass --ckpt-dir / ClusterTrainer(ckpt_dir=)")
        # every metric snapshot holds a full parameter pytree until the
        # post-run evaluation; bound the count so a long budget with a
        # fine grid fails loudly instead of exhausting host memory
        if wall_budget_s / sample_every_s > 4096:
            raise ValueError(
                f"wall_budget_s/sample_every_s = "
                f"{wall_budget_s / sample_every_s:.0f} metric snapshots "
                "(> 4096), each retaining a full parameter copy — "
                "increase sample_every_s")
        self.loss_fn = loss_fn
        self.init_params = init_params
        self.x_tr, self.y_tr, self.x_te, self.y_te = data
        self.mode = mode
        self.lr = lr
        self.batch = batch
        self.num_workers = num_workers
        # the *current* fleet size: seeded at num_workers, grown by
        # online admission up to max_workers (host transport only).
        # K(t) schedules and the staging buffer re-derive from it
        self.fleet_size = num_workers
        self._fleet_lock = threading.Lock()
        self.wall_budget_s = wall_budget_s
        self.sample_every_s = sample_every_s
        self.schedule = schedule
        self.flush_mode = flush_mode
        self.staleness_decay = staleness_decay
        self.max_gradients = max_gradients
        self.seed = seed
        self.faults = faults
        self.transport_kind = transport_kind
        self.spec_dict = spec_dict
        self.proc_ready_timeout_s = proc_ready_timeout_s
        self.ckpt_dir = ckpt_dir
        self.resume_from = resume_from
        self.verbose = verbose
        # the telemetry bus: metrics always on (lock-cheap counters /
        # histograms), timeline spans only when a trace file was asked
        # for.  trace is the output path (written by the trainer after
        # the run), not a spec field — tracing is a run artifact, like
        # --out, and must not perturb spec round-trips over the wire
        self.trace_path = trace
        self.obs = Telemetry(trace=bool(trace))
        # --prom-port: a Prometheus /metrics endpoint over the live
        # stats payload — an invocation artifact like trace/ckpt_dir,
        # never a spec field (started in _run, closed with the run)
        self.prom_port = prom_port
        self.prom_server = None

        # the slab wire format: workers fetch a params *slab*, decode,
        # differentiate, and re-encode the gradient — all in one jitted
        # executable, so each gradient ships as a single contiguous
        # (P,) array and is flattened exactly once, on the worker.
        # slab_dtype declares the staging/wire precision (f32 | bf16);
        # the server's master params and flush reduction stay f32
        self.slab_dtype = str(slab_dtype)
        # the server-side optimizer: moments live as f32 slab buffers
        # inside the aggregator's fused flush executable (see
        # repro.core.slab); "sgd" is the historical flush, bit for bit
        self.optimizer = optimizer or SlabOptimizer("sgd")
        self.codec = slab_codec(init_params, self.slab_dtype)
        grad_fn = jax.grad(loss_fn)

        def _grad_slab(p_slab, x, y):
            return self.codec.encode(
                grad_fn(self.codec.decode(p_slab), x, y))

        self._grad = jax.jit(_grad_slab)
        self._loss = jax.jit(loss_fn)
        self._acc = accuracy_fn

        # bounded gradient channel = backpressure: a worker whose
        # gradient the server can't take yet blocks — on a queue for
        # thread workers, on real socket flow control otherwise.
        # Constructed LAST: everything above can raise (e.g. the codec
        # rejecting a leaf dtype), and a socket transport created
        # before a failed validation would leak its listener/threads
        cap = max(4, 2 * num_workers)
        self._own_transport = transport is None
        if transport is not None:
            self.transport = transport
        elif transport_kind == "socket":
            self.transport = SocketTransport(cap, family="tcp",
                                             slab_dtype=self.slab_dtype)
        elif transport_kind == "proc":
            self.transport = ProcTransport(cap, family="unix",
                                           slab_dtype=self.slab_dtype)
        elif transport_kind == "host":
            from repro.cluster.hostlink import (HostTransport,
                                                parse_hostport)
            bind_host, bind_port = parse_hostport(listen
                                                  or "127.0.0.1:0")
            self.transport = HostTransport(
                cap, host=bind_host, port=bind_port,
                num_workers=num_workers,
                welcome_config={"spec": spec_dict},
                heartbeat_s=heartbeat_s, serve_every=serve_every,
                max_workers=self.max_workers,
                join_secret=join_secret,
                lease_grace_s=lease_grace_s,
                slab_dtype=self.slab_dtype)
        else:
            self.transport = InProcTransport(grad_capacity=cap)
        # hand the socket hubs the live bus (wire byte counters,
        # grad_rx spans, the STATS push plane); InProcTransport carries
        # no instrumentation of its own and just ignores the attribute
        self.transport.obs = self.obs
        # the resolved bind address (host transport): port 0 in `listen`
        # has been replaced by the real ephemeral port by now
        self.listen_address: Optional[Any] = \
            tuple(self.transport.address) \
            if transport_kind == "host" else None

        self._stop = threading.Event()
        self._workers: Dict[int, Worker] = {}
        self._all_workers: List[Worker] = []
        self._generation: Dict[int, int] = {}
        self.events: List[Dict[str, Any]] = []
        self._control_errors: List[str] = []
        self._t0 = 0.0

    def _guarded(self, fn: Callable, name: str) -> threading.Thread:
        """Control thread whose failure is captured and re-raised by
        ``run()`` — a dead checkpointer/injector means the fault plan
        was not executed, which must not look like a clean run."""
        def body():
            try:
                fn()
            except Exception:
                import traceback
                self._control_errors.append(
                    f"{name}:\n{traceback.format_exc()}")
        return threading.Thread(target=body, name=name, daemon=True)

    # ------------------------------------------------------------ hooks
    def _elapsed(self) -> float:
        return time.monotonic() - self._t0

    def _log_event(self, kind: str, **kw) -> None:
        ev = {"t": round(self._elapsed(), 3), "event": kind, **kw}
        self.events.append(ev)
        # every fault/lifecycle event is also a timeline instant (the
        # trace shows kills/restores against the spans they perturb)
        # and a structured log record
        self.obs.instant("server", kind, **kw)
        self.obs.count(f"events.{kind}")
        _log.info("+%.2fs %s %s", ev["t"], kind, kw)
        if self.verbose:
            print(f"[cluster +{ev['t']:6.2f}s] {kind} "
                  f"{ {k: v for k, v in kw.items()} }", flush=True)

    def _spawn(self, wid: int) -> None:
        gen = self._generation.get(wid, -1) + 1
        self._generation[wid] = gen
        if self.transport_kind == "proc":
            # membership is driven by the connection, not the spawn:
            # the hub's on_worker_ready hook registers this worker when
            # its HELLO arrives (after its JAX runtime is warm).  A
            # sync barrier must not wait ~seconds of child startup for
            # a worker that cannot yet contribute — an inproc respawn
            # is instant, and a real cluster's barrier also only counts
            # nodes that have joined
            self.transport.spawn_worker(ProcWorkerConfig(
                spec=self.spec_dict, worker_id=wid, generation=gen,
                num_workers=self.num_workers, mode=self.mode,
                straggle_s=self.faults.straggle_s(wid), seed=self.seed,
                batch=self.batch,
                # two processes can't share one accelerator: children
                # fall back to CPU unless the parent is CPU already
                platform=None if jax.default_backend() == "cpu"
                else "cpu"))
            return
        batches = shard_iterator(self.x_tr, self.y_tr, wid,
                                 self.num_workers, self.batch,
                                 seed=self.seed, generation=gen)
        wtrans: Any = self.transport
        if self.transport_kind == "socket":
            wtrans = self.transport.connect(wid, gen)
        w = Worker(wid, grad_fn=self._grad, batches=batches,
                   transport=wtrans, mode=self.mode,
                   straggle_s=self.faults.straggle_s(wid),
                   generation=gen, obs=self.obs)
        if wtrans is not self.transport:
            w.endpoint = wtrans     # flushed + closed at shutdown
            # a dead connection must stop the worker (not leave it
            # spinning on instant-False sends); conversely kill/
            # shutdown setting the stop event wakes the endpoint waits
            w.stop_event = wtrans.closed
        self._workers[wid] = w
        self._all_workers.append(w)
        self.server.register(wid)
        w.start()

    def _grow_fleet_to(self, n: int) -> None:
        """Online admission: a joiner beyond the current fleet size
        grows the server's staging buffer and re-derives the K(t)
        schedule for the new effective fleet — *before* the worker
        registers, so a sync barrier that fills immediately already has
        a staging row for every live member.  The conservation ledger
        is untouched (the resize preserves staged rows and the host-
        side counters never move)."""
        with self._fleet_lock:
            if n <= self.fleet_size:
                return
            old = self.fleet_size
            schedule = None
            if self.mode == "async":
                schedule = constant_schedule(n, 1)
            elif self.mode == "hybrid" and self.spec_dict \
                    and self.spec_dict.get("schedule"):
                from repro.api.schedules import parse_schedule
                schedule = parse_schedule(self.spec_dict["schedule"], n)
            self.server.grow_fleet(n, schedule)
            self.fleet_size = n
        self.obs.gauge("fleet_size", n)
        self.obs.count("members.admitted_beyond_seed", n - old)
        self._log_event("fleet_grow", from_workers=old, to_workers=n)

    def _on_remote_ready(self, wid: int, gen: int) -> None:
        # hub reader thread: a worker finished connecting.  For spawned
        # (proc) workers, guard on the exact generation so an orphan
        # HELLO from a superseded process cannot re-register a worker
        # the injector killed.  For joined (host) workers the transport
        # leases generations itself — any *newer* generation is the
        # legitimate holder of the worker id's shard
        if self.transport_kind == "host":
            if gen >= self._generation.get(wid, -1):
                # grow BEFORE register: the staging buffer must cover
                # the live fleet when this worker's first sync round
                # fills
                self._grow_fleet_to(wid + 1)
                self._generation[wid] = gen
                self.server.register(wid)
                self.obs.count("members.joined")
                self.obs.gauge("live_workers", len(self.server.live))
                self._log_event("member_join", worker=wid,
                                generation=gen)
            return
        if self._generation.get(wid) == gen:
            self.server.register(wid)

    def _on_remote_gone(self, wid: int, gen: int) -> None:
        # hub reader thread: a worker's connection died (kill, crash,
        # shutdown).  Deregistering here (idempotent) closes the race
        # where a HELLO lands between the injector's kill and the
        # process actually dying — a registered-but-dead worker would
        # stall every later sync round
        if self._generation.get(wid) == gen:
            self.server.deregister(wid)
            if self.transport_kind == "host":
                self.obs.count("members.departed")
                self.obs.gauge("live_workers", len(self.server.live))
                self._log_event("member_gone", worker=wid,
                                generation=gen)

    def _kill(self, wid: int) -> None:
        if self.transport_kind == "proc":
            sigkilled = self.transport.kill_worker(wid)   # SIGKILL
            self.server.deregister(wid)
            self._log_event("kill", worker=wid, sigkill=sigkilled)
            return
        if self.transport_kind == "host":
            # the one fault a leader can inflict on a remote host: cut
            # the connection (the worker exits cleanly on EOF)
            cut = self.transport.kill_worker(wid)
            self.server.deregister(wid)
            self._log_event("kill", worker=wid, connection_cut=cut)
            return
        w = self._workers.get(wid)
        if w is not None:
            w.stop_event.set()
        self.server.deregister(wid)
        self._log_event("kill", worker=wid)

    # ------------------------------------------------- background loops
    def _injector(self) -> None:
        # one merged timeline: a pending respawn must not delay (or
        # starve) later kill events, so kills and respawns interleave
        # in wall-clock order ("kill" sorts before "spawn" on ties —
        # a kill and a respawn at the same instant kill first)
        events = [(t, "kill", wid) for t, wid in self.faults.kill_events()]
        if self.faults.respawn_after_s > 0:
            events += [(t + self.faults.respawn_after_s, "spawn", wid)
                       for t, wid in self.faults.kill_events()]
        for t, kind, wid in sorted(events):
            if self._stop.wait(max(0.0, t - self._elapsed())):
                return
            if kind == "kill":
                self._kill(wid)
            else:
                self._spawn(wid)
                self._log_event("respawn", worker=wid,
                                generation=self._generation[wid])

    def _checkpointer(self) -> None:
        while not self._stop.wait(self.faults.checkpoint_every_s):
            # params + optimizer moments captured atomically (one lock
            # acquisition): a checkpoint whose moments ran one flush
            # ahead of its params would resume subtly wrong
            version, params, applied, opt_state = \
                self.server.snapshot_for_checkpoint()
            path = os.path.join(self.ckpt_dir, f"step_{version}")
            save_checkpoint(path, params, version,
                            extra={"mode": self.mode, "applied": applied,
                                   "backend": "cluster",
                                   "optimizer": self.optimizer.name},
                            opt_state=opt_state)
            self._log_event("checkpoint", step=version)

    def _restorer(self) -> None:
        if self._stop.wait(self.faults.restore_at_s):
            return
        step = latest_step(self.ckpt_dir)
        if step is None:
            self._log_event("restore_skipped", reason="no checkpoint yet")
            return
        path = os.path.join(self.ckpt_dir, f"step_{step}")
        params, step = restore_checkpoint(path, like=self.init_params)
        # moment slabs + update count ride the same checkpoint; an old
        # (or sgd-written) checkpoint has none and the moments restart
        # from zero with the same epoch bump
        self.server.restore(params, step,
                            opt_state=load_opt_state(path))
        self._log_event("restore", step=step)

    def _stats_payload(self) -> Dict[str, Any]:
        """One `repro top` tick: the live ledger columns, staleness
        percentiles, and fleet state.  Runs on the hub's stats-push
        thread; everything it reads is lock-protected or a snapshot."""
        a = self.server.accounting()
        st = self.obs.hist_stats("staleness") or {}
        serve_clients = 0
        if hasattr(self.transport, "serve_stats"):
            serve_clients = self.transport.serve_stats()["clients"]
        counters = self.obs.counters()
        return {
            "t": round(self._elapsed(), 3),
            "version": self.server.version,
            "mode": self.mode,
            "optimizer": self.optimizer.name,
            "optimizer_steps": counters.get("optimizer_steps", 0),
            "applied": a["applied"],
            "dropped": a["dropped"],
            "buffered": a["buffered"],
            "pending_round": a["pending_round"],
            "updates": a["updates"],
            "staleness": {"p50": st.get("p50"), "p99": st.get("p99")},
            "queue_depth": self.transport.pending_gradients(),
            "live_workers": len(self.server.live),
            "num_workers": self.num_workers,
            "fleet_size": self.fleet_size,
            "max_workers": self.max_workers,
            "serve_clients": serve_clients,
        }

    def _sampler(self, snaps: List) -> None:
        # snapshot_slab is zero work (a reference to the published,
        # never-donated params slab): sampling must not steal decode /
        # host-copy time from the serial resource it is measuring —
        # the slabs are decoded after the run, with the metrics
        i = 0
        while True:
            target = i * self.sample_every_s
            wait = target - self._elapsed()
            if wait > 0 and self._stop.wait(wait):
                return
            if self._stop.is_set():
                return
            version, slab, _ = self.server.snapshot_slab()
            snaps.append((target, version, slab))
            i += 1

    def _wind_down(self) -> "tuple[int, List[str]]":
        """Fleet teardown with the gradient channel kept flowing.

        Joins workers, flushes socket endpoints, joins worker
        processes, and quiesces the transport — all while continuously
        draining the gradient channel into the ``in_flight`` counter: a
        backpressured sender can only finish its final frame if the
        server side keeps making room (stalling here is what used to
        tear the last frames of a clean shutdown).  After this returns,
        every complete frame has been received and counted, so
        ``pending_gradients()`` is exact (0) and the conservation
        ledger can be asserted to the gradient.  Returns
        ``(in_flight, proc_errors)``."""
        in_flight = 0
        deadline = time.monotonic() + 15.0

        def drain() -> None:
            nonlocal in_flight
            while self.transport.recv_gradient(timeout=0) is not None:
                in_flight += 1

        for w in self._all_workers:     # prompt: all waits see stop
            w.join(timeout=10.0)
        if self.transport_kind == "proc":
            while self.transport.procs_alive():
                drain()
                # a child still starting up (e.g. a respawn racing the
                # end of the budget) has no connection to receive the
                # shutdown EOF on — SIGKILL it; it has sent nothing
                self.transport.kill_unconnected()
                if time.monotonic() > deadline:
                    break
                time.sleep(0.02)
        proc_errors: List[str] = []
        if self.transport_kind == "proc":
            proc_errors = self.transport.join_workers(timeout=5.0)
        # socket endpoints: push out accepted-but-unshipped gradients
        # (they are already counted as computed), then hang up so the
        # hub reader sees EOF and can quiesce
        endpoints = [ep for ep in (getattr(w, "endpoint", None)
                                   for w in self._all_workers)
                     if ep is not None]
        unflushed = list(endpoints)
        while unflushed and time.monotonic() < deadline:
            drain()
            # an endpoint whose sender thread died (connection error)
            # can never flush its remainder — waiting out the deadline
            # on it would stall every such teardown by ~15s
            unflushed = [ep for ep in unflushed
                         if not ep.flush(0.05) and ep.can_flush()]
        for ep in endpoints:
            ep.close()
        while True:
            drain()
            if self.transport.quiesce(timeout=0.1):
                break
            if time.monotonic() > deadline:
                raise RuntimeError(
                    "transport failed to quiesce within 15s — the "
                    "conservation ledger would be approximate")
        drain()
        return in_flight, proc_errors

    # -------------------------------------------------------------- run
    def run(self) -> ClusterResult:
        try:
            return self._run()
        finally:
            if self.prom_server is not None:
                self.prom_server.close()
            if self._own_transport:
                self.transport.close()

    def _run(self) -> ClusterResult:
        self._t0 = time.monotonic()     # provisional: pre-barrier events
        #                                 (listening, ...) get small ts;
        #                                 reset when the clock starts
        start_version = 0
        start_params = self.init_params
        resume_opt_state = None
        if self.resume_from:
            start_params, start_version = restore_checkpoint(
                self.resume_from, like=self.init_params)
            # optimizer moments + update count resume with the params;
            # None (old / sgd-written checkpoint) keeps them at zero
            resume_opt_state = load_opt_state(self.resume_from)

        if self.transport_kind not in ("proc", "host"):
            # compile the worker gradient before the clock starts, so
            # the budget measures contention, not XLA (process workers
            # and joined hosts compile in their own runtime and connect
            # once warm; the metric fns are only evaluated after the run)
            wx, wy = next(shard_iterator(self.x_tr, self.y_tr, 0,
                                         self.num_workers, self.batch,
                                         seed=self.seed))
            jax.block_until_ready(
                self._grad(self.codec.encode(start_params), wx, wy))

        if self.transport_kind in ("proc", "host"):
            # hold BEFORE the server's construction-time publish: a
            # remote worker that joined while the leader was still
            # setting up must idle in fetch_params, not bank gradients
            # before the serving clock starts
            self.transport.hold_params()
        self.server = ParameterServer(
            start_params, lr=self.lr, mode=self.mode,
            transport=self.transport, num_workers=self.num_workers,
            schedule=self.schedule, flush_mode=self.flush_mode,
            staleness_decay=self.staleness_decay,
            max_gradients=self.max_gradients,
            start_version=start_version,
            slab_dtype=self.slab_dtype, optimizer=self.optimizer,
            obs=self.obs)
        if resume_opt_state is not None:
            # after construction (warmup rewound the count to 0) and
            # before any worker can flush: load the checkpointed
            # moments so the resumed run continues bias correction
            # from the saved step, not from step 0
            self.server.agg.reset_opt_state(resume_opt_state)
        if hasattr(self.transport, "stats_provider"):
            # the STATS push plane (`repro top`): now that the server
            # exists, the hub can answer stats subscribers with live
            # ledger + staleness numbers
            self.transport.stats_provider = self._stats_payload
        if self.prom_port is not None:
            # Prometheus scrape surface over the same payload (plus the
            # raw telemetry counters, e.g. repro_wire_tx_bytes_total);
            # started only once the server exists so every scrape sees
            # a coherent ledger
            from repro.obs.prom import PromServer
            self.prom_server = PromServer(
                lambda: (self._stats_payload(), self.obs.counters()),
                self.prom_port)
            self._log_event("prom_listening",
                            port=int(self.prom_server.port))
            if self.verbose:
                print(f"[cluster] prometheus metrics at "
                      f"{self.prom_server.url}", file=sys.stderr,
                      flush=True)

        snaps: List = []
        threads: List[threading.Thread] = []
        try:
            if self.transport_kind in ("proc", "host"):
                # assemble the fleet (spawn it, or advertise and wait
                # for joins), then hold the clock until every worker
                # has compiled and connected (HELLO == ready); fail
                # fast on a spawned child that crashed during startup.
                # The params broadcast is withheld until the barrier
                # passes, so early workers idle in fetch_params
                # instead of banking gradients before the clock starts
                # (which would flatter the multi-process benchmark)
                self.transport.on_worker_ready = self._on_remote_ready
                self.transport.on_worker_gone = self._on_remote_gone
                if self.transport_kind == "host":
                    self.transport.on_serve_ready = \
                        lambda sid: self._log_event("serve_client",
                                                    serve_id=sid)
                if self.transport_kind == "proc":
                    for wid in range(self.num_workers):
                        self._spawn(wid)
                else:
                    # externally-joined workers may have said HELLO
                    # before the hooks existed — register them now
                    for wid, gen in \
                            self.transport.connected_workers().items():
                        self._on_remote_ready(wid, gen)
                    bind_host, bind_port = self.listen_address
                    self._log_event("listening", host=bind_host,
                                    port=int(bind_port),
                                    expected_workers=self.num_workers)
                    # a wildcard bind is not a dialable address — the
                    # copy-paste hint must name a host the workers can
                    # actually reach
                    adv_host = bind_host if bind_host not in \
                        ("0.0.0.0", "::", "") else "<LEADER_HOST>"
                    print(f"[cluster] leader listening on {bind_host}:"
                          f"{bind_port} — waiting for "
                          f"{self.num_workers} worker(s) to join "
                          f"(python -m repro join "
                          f"{adv_host}:{bind_port})",
                          file=sys.stderr, flush=True)
                ready_deadline = (time.monotonic()
                                  + self.proc_ready_timeout_s)
                while not self.transport.wait_for_workers(
                        self.num_workers, timeout=1.0):
                    if self.transport_kind == "proc":
                        dead = self.transport.dead_workers()
                        if dead:
                            raise RuntimeError(
                                "worker process(es) died before the "
                                "fleet was ready:\n" + "\n".join(dead))
                    if time.monotonic() > ready_deadline:
                        raise RuntimeError(
                            f"only "
                            f"{sorted(self.transport.live_workers())} "
                            f"of {self.num_workers} workers "
                            "connected within "
                            f"{self.proc_ready_timeout_s}s")

            self._t0 = time.monotonic()
            if self.transport_kind in ("proc", "host"):
                self.transport.release_params()     # the starting gun
            if start_version:
                self._log_event("resume", step=start_version,
                                path=self.resume_from)
            threads.append(self._guarded(lambda: self._sampler(snaps),
                                         "sampler"))
            if self.faults.kill:
                threads.append(self._guarded(self._injector, "injector"))
            if self.ckpt_dir and self.faults.checkpoint_every_s > 0:
                threads.append(self._guarded(self._checkpointer, "ckpt"))
            if self.ckpt_dir and self.faults.restore_at_s > 0:
                threads.append(self._guarded(self._restorer, "restore"))
            for t in threads:
                t.start()
            if self.transport_kind not in ("proc", "host"):
                # local thread workers; proc spawned its fleet at the
                # barrier, and host workers joined from outside
                for wid in range(self.num_workers):
                    self._spawn(wid)

            deadline = self._t0 + self.wall_budget_s
            next_q = 0.0            # queue-depth sampling grid (~5 Hz)
            while time.monotonic() < deadline \
                    and not self.server.done.is_set():
                msg = self.transport.recv_gradient(timeout=min(
                    0.02, max(1e-3, deadline - time.monotonic())))
                if msg is not None:
                    self.server.ingest(msg)
                now = time.monotonic() - self._t0
                if now >= next_q:
                    self.obs.observe(
                        "queue_depth",
                        self.transport.pending_gradients())
                    next_q = now + 0.2
            wall_s = self._elapsed()
        finally:
            # ---------------------------------------------- shutdown
            # ALWAYS propagate shutdown to the workers — including when
            # the server loop above died mid-run: a worker blocked on a
            # bounded send retries until its stop event is set, so a
            # crashed server must not strand a live worker (regression-
            # tested).  Control threads stop first: the injector must
            # not respawn a worker nobody stops (all its waits watch
            # self._stop, so these joins return promptly).
            self._stop.set()
            for t in threads:
                t.join(timeout=10.0)
            if self.transport_kind in ("proc", "host"):
                # EOF on the params direction tells each worker process
                # (spawned or remotely joined) to stop; its in-flight
                # gradient frames are still drained
                self.transport.half_close_workers()
            for w in self._all_workers:
                w.stop_event.set()

        in_flight, proc_errors = self._wind_down()
        errors = [f"worker {w.worker_id}.{w.generation}:\n{w.error}"
                  for w in self._all_workers if w.error]
        errors += proc_errors
        errors += self._control_errors
        # a thread that outlived its join would keep mutating transport/
        # server state under the accounting we are about to report
        errors += [f"{t.name} did not stop within the join timeout"
                   for t in (*self._all_workers, *threads)
                   if t.is_alive()]
        if errors:
            raise RuntimeError("cluster thread(s)/process(es) crashed "
                               "or hung:\n" + "\n".join(errors))

        leftover = self.transport.pending_gradients()
        if leftover:
            raise RuntimeError(
                f"{leftover} gradients appeared after the post-quiesce "
                "drain — a producer outlived shutdown")

        accounting = self.server.accounting()
        accounting["in_flight"] = in_flight
        if self.transport_kind in ("proc", "socket", "host"):
            # "computed" on the socket transports = complete frames
            # that physically reached the hub (exact under every
            # failure mode: whatever a killed worker or dying
            # connection had not finished sending died with it, like a
            # thread worker killed before send; the conformance suite
            # separately asserts nothing is lost on a healthy wire)
            received = self.transport.received_counts()
            accounting["computed"] = sum(received.values())
            # an elastic fleet may have grown past the seed: report a
            # column for every member that ever existed
            fleet_ids = set(range(self.fleet_size)) | set(received)
            accounting["computed_per_worker"] = {
                str(wid): received.get(wid, 0)
                for wid in sorted(fleet_ids)}
            accounting["torn_frames"] = self.transport.torn_frames
        else:
            accounting["computed"] = sum(w.sent
                                         for w in self._all_workers)
            per_worker: Dict[str, int] = {}
            for w in self._all_workers:     # all generations of each id
                key = str(w.worker_id)
                per_worker[key] = per_worker.get(key, 0) + w.sent
            accounting["computed_per_worker"] = per_worker

        # ---------------------------------- evaluate the metric snapshots
        times, tr, te, acc = [], [], [], []
        for target, _, slab in snaps:
            params = self.codec.decode(slab)
            times.append(target)
            tr.append(float(self._loss(params, self.x_tr[:2048],
                                       self.y_tr[:2048])))
            te.append(float(self._loss(params, self.x_te, self.y_te)))
            acc.append(float(self._acc(params, self.x_te, self.y_te))
                       if self._acc is not None else 0.0)

        # snapshot() already returns a host copy (the donation rule:
        # nothing escaping the server may alias the donated slab)
        _, final_params, applied = self.server.snapshot()
        # the serving report is shape-stable across transports: a hub
        # transport reports its real serve-plane state, and a transport
        # with no serving plane (inproc) reports the same keys, empty —
        # consumers key on content, never on key presence
        if hasattr(self.transport, "serve_stats"):
            serving = self.transport.serve_stats()
        else:
            serving = {"clients": 0, "rejected_peers": 0,
                       "serve_every": 1, "stats_clients": 0,
                       "per_client": []}
        # telemetry summary + the ledger cross-check: every gradient
        # the server ingested is exactly accounted (applied + dropped +
        # buffered + pending), and everything computed that was never
        # ingested is the post-loop in_flight drain
        telemetry = self.obs.summary()
        c = telemetry["counters"]
        ingested = c.get("grads_ingested", 0)
        ledger_sum = (accounting["applied"] + accounting["dropped"]
                      + accounting["buffered"]
                      + accounting["pending_round"])
        telemetry["ledger_check"] = {
            "grads_ingested": ingested,
            "ledger_sum": ledger_sum,
            "computed": accounting["computed"],
            "in_flight": accounting["in_flight"],
            "consistent": (ingested == ledger_sum
                           and accounting["computed"]
                           == ingested + accounting["in_flight"]),
        }
        return ClusterResult(
            times=np.asarray(times), train_loss=np.asarray(tr),
            test_loss=np.asarray(te), test_acc=np.asarray(acc),
            num_updates=accounting["updates"], num_gradients=applied,
            mode=self.mode, start_version=start_version,
            accounting=accounting, events=list(self.events),
            final_params=final_params, wall_s=wall_s, serving=serving,
            telemetry=telemetry)
