"""Wall-clock parameter-server runtime (``backend="cluster"``).

Where :mod:`repro.core.simulator` runs the paper's parameter server in
*virtual* time and :mod:`repro.core.spmd_hybrid` runs its SPMD analogue
in lockstep, this package runs it for real: worker threads computing
jitted gradients concurrently against one live server, with stale reads,
server contention, stragglers, worker kill/respawn, and server
checkpoint/restore — the failure modes the sync/async tradeoff is
actually about.

Pieces:
  * :class:`~repro.cluster.transport.Transport` — the wire, carrying
    gradient/params *slabs* (:mod:`repro.core.slab`) as single
    contiguous arrays.  Three implementations:
    :class:`~repro.cluster.transport.InProcTransport` (threads +
    queue, the default and parity baseline),
    :class:`~repro.cluster.mptransport.SocketTransport` (TCP /
    Unix-domain length-prefixed slab frames), and
    :class:`~repro.cluster.mptransport.ProcTransport` (one OS process
    per worker with its own JAX runtime; kills are SIGKILL);
  * :class:`~repro.cluster.server.ParameterServer` — live params + the
    slab aggregation path (one donated fused flush executable) driven
    by the K(t) schedule, under a lock;
  * :class:`~repro.cluster.worker.Worker` — one thread per worker, real
    gradients on a deterministic data shard;
  * :class:`~repro.cluster.faults.FaultPlan` — declarative fault
    injection (stragglers, kills, respawns, checkpoint cadence);
  * :class:`~repro.cluster.runtime.ClusterRuntime` — wiring + wall-clock
    metric sampling;
  * :class:`~repro.cluster.trainer.ClusterTrainer` — the
    :mod:`repro.api` adapter.
"""
# Only the jax-free pieces load eagerly: repro.api.spec imports
# FaultPlan from here, and that must not drag the runtime (jax,
# repro.checkpoint, the worker machinery) into every spec round-trip.
# The heavy classes resolve lazily on first attribute access (PEP 562).
from repro.cluster.faults import FaultPlan, parse_fault_pairs  # noqa: F401
from repro.cluster.transport import (TRANSPORTS, GradientMsg,  # noqa: F401
                                     InProcTransport, ParamsMsg, Transport)

_LAZY = {
    "ParameterServer": "repro.cluster.server",
    "Worker": "repro.cluster.worker",
    "ClusterRuntime": "repro.cluster.runtime",
    "ClusterResult": "repro.cluster.runtime",
    "ClusterTrainer": "repro.cluster.trainer",
    # numpy/socket only (jax-free), but lazy keeps spec round-trips lean
    "SocketTransport": "repro.cluster.mptransport",
    "SocketWorkerClient": "repro.cluster.mptransport",
    "ProcTransport": "repro.cluster.mptransport",
    "ProcWorkerConfig": "repro.cluster.mptransport",
}

__all__ = [
    "FaultPlan", "parse_fault_pairs", "Transport", "TRANSPORTS",
    "InProcTransport", "SocketTransport", "SocketWorkerClient",
    "ProcTransport", "ProcWorkerConfig", "GradientMsg", "ParamsMsg",
    "ParameterServer", "Worker", "ClusterRuntime", "ClusterResult",
    "ClusterTrainer",
]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
