"""Multi-host slab transport: host:port addressing + leader discovery.

:class:`HostTransport` is the multi-host mode of the slab hub
(:class:`~repro.cluster.mptransport.SocketTransport`): the server binds
a user-chosen ``HOST:PORT`` (``--listen``), and remote workers
*self-launch* — ``python -m repro join HOST:PORT`` from any machine
that has the ``repro`` package — instead of being spawned by the
leader.  Code never crosses the machine boundary: the experiment spec
travels over the wire in the leader handshake, and the join side
rebuilds the workload from that JSON via the ``SIM_WORKLOADS``
registry, exactly like a ``proc`` worker process does.

**Leader handshake** (one extra round-trip before the normal
HELLO/GRAD/PARAMS protocol; frames defined in :mod:`repro.cluster.
mptransport`)::

    joiner                          leader (hub)
      | -- JOIN(magic, v, want_id) -->|   lease a worker id
      | <-- WELCOME{spec, worker_id,  |   (or REJECT + readable reason)
      |      generation, num_workers}-|
      |   ... rebuild workload, compile the slab gradient ...
      | -- HELLO(magic, v, id, gen) ->|   ready: joins the fleet barrier
      | <==== PARAMS / GRAD ... =====>|   normal training protocol

**Worker-id leases with generation fencing** — the worker id IS the
deterministic data-shard assignment (``shard_iterator`` is keyed on
it), so the leader negotiates ids centrally: ``JOIN(-1)`` leases the
lowest free id, ``JOIN(w)`` requests a specific one, and a *rejoining*
host is re-leased its old id with the generation bumped — it resumes
its shard with a fresh batch stream (like a ``proc`` respawn), never a
duplicate.  Every lease grant monotonically advances the id's
generation, and a HELLO carrying a generation older than the current
lease is fenced out: a superseded worker that limps back cannot
double-feed a shard the fleet already re-assigned.

**Elastic membership** — the fleet is only *seeded* at
``cluster_workers``; with ``max_workers > cluster_workers`` the leader
keeps admitting joiners mid-run up to that cap (the runtime grows the
staging buffer and re-derives the K(t) schedule online).  A departed
worker's id enters a short **re-lease grace window**: its own host can
resume it immediately (``JOIN(w)`` — the reconnect path), while an
*auto* join (``JOIN(-1)``) only receives it after the window expires,
so a blip never permanently hands a shard to a stranger.  Auto joins
retry grace/full rejections within their deadline (``BUSY_MARKER``).

**Authenticated JOIN** — a leader started with a shared join secret
answers JOIN with CHALLENGE (random nonce); the joiner proves the
secret via AUTH = HMAC-SHA256(secret, nonce) and only then receives
WELCOME.  Wrong digests, and direct HELLOs that skip the challenge,
are rejected readably and never enter the barrier.  Read-only
SERVE/STATS peers are not challenged.

The leader cannot respawn a remote worker (it does not own the remote
machine) — a kill fault on this transport cuts the worker's connection
(a network fault; the remote process exits cleanly on EOF), and
replacement capacity rejoins from its own host — ``repro join``'s
reconnect-with-backoff does exactly that, resuming the old lease
through the generation fence.

**Serve handshake** — same shape, no lease::

    serve client                    leader (hub)
      | -- SERVE(magic, v) -------->|   admit read-only
      | <-- WELCOME{spec, serve_id, |   (or REJECT + readable reason)
      |      heartbeat_s, ...} -----|
      | <==== PARAMS ... PING ======|   coalesced params + liveness
      | ----- PONG ... ------------>|

``python -m repro infer HOST:PORT`` (see :mod:`repro.serve.client`)
drives this to run inference against live training params.
"""
from __future__ import annotations

import hmac
import json
import logging
import os
import random
import socket
import subprocess
import sys
import threading
import time
import traceback
from typing import Any, Dict, Iterator, Optional, Tuple

from repro.cluster.mptransport import (_AUTH_NONCE_LEN, _CTRL,
                                       _F_CHALLENGE, _F_PARAMS, _F_PING,
                                       _F_PONG, _F_REJECT, _F_WELCOME,
                                       _HDR, _MAX_FRAME, _auth_digest,
                                       _auth_frame, _challenge_frame,
                                       _join_frame,
                                       _peer_error, _recv_exact,
                                       _serve_frame, _stats_frame,
                                       _welcome_frame,
                                       SocketTransport, SocketWorkerClient,
                                       WireProtocolError)

_log = logging.getLogger("repro.cluster.hostlink")

# Machine-readable marker on lease rejections that resolve themselves
# as the fleet churns (a dead predecessor's connection the hub has not
# reaped yet, a slot about to free up).  The marker travels inside the
# REJECT frame's reason, and :func:`negotiate_join` retries exactly the
# marked rejections within its deadline — producer and consumer share
# this one constant, so rewording the prose can never flip the retry
# policy.  Protocol errors (bad magic / version / out-of-range id) are
# never marked: they cannot change and fail fast.
BUSY_MARKER = "[busy]"


def parse_hostport(s: str, default_host: str = "127.0.0.1"
                   ) -> Tuple[str, int]:
    """``"HOST:PORT"`` / ``":PORT"`` / ``"PORT"`` -> ``(host, port)``.
    Port 0 means "pick an ephemeral port" (the resolved one is on
    ``transport.address``)."""
    s = str(s).strip()
    host, sep, port_s = s.rpartition(":")
    if not sep:
        host, port_s = "", s
    host = host or default_host
    try:
        port = int(port_s)
    except ValueError:
        raise ValueError(f"invalid listen address {s!r}: expected "
                         "HOST:PORT (e.g. 0.0.0.0:5555, :0)") from None
    if not 0 <= port < 65536:
        raise ValueError(f"invalid port {port} in listen address {s!r}")
    return host, port


def _addr_str(address: Any) -> str:
    if isinstance(address, str):
        return address
    host, port = tuple(address)[:2]
    return f"{host}:{port}"


# ========================================================== leader side


class HostTransport(SocketTransport):
    """The multi-host hub: a TCP slab hub at a real ``host:port`` that
    *admits* remote workers instead of launching them.

    ``welcome_config`` (JSON-able; typically ``{"spec": spec.to_dict()}``)
    is what every joiner receives in WELCOME, extended per-join with its
    ``worker_id`` lease, ``generation``, and ``num_workers`` — the whole
    contract a remote host needs to rebuild the workload and claim its
    data shard.

    The host hub is also the one that admits **serve clients** (read-only
    SERVE peers — ``python -m repro infer``): they get a WELCOME carrying
    the spec (to rebuild the model for inference) plus a ``serve_id``,
    and then just receive the coalesced params broadcast.  They hold no
    worker-id lease and never enter the fleet barrier or the ledger.
    ``heartbeat_s`` is the leader-liveness PING cadence (workers and
    serve clients size their hung-leader watchdog from it via WELCOME);
    ``serve_every`` down-samples the serve-plane push stream.
    """

    def __init__(self, grad_capacity: int = 0, *,
                 host: str = "127.0.0.1", port: int = 0,
                 num_workers: int, welcome_config:
                 Optional[Dict[str, Any]] = None,
                 heartbeat_s: float = 2.0, serve_every: int = 1,
                 max_workers: Optional[int] = None,
                 join_secret: Optional[str] = None,
                 lease_grace_s: float = 2.0,
                 slab_dtype: str = "f32"):
        super().__init__(grad_capacity, family="tcp", host=host,
                         port=port, heartbeat_s=heartbeat_s,
                         serve_every=serve_every,
                         slab_dtype=slab_dtype)
        self.num_workers = int(num_workers)
        # the admission ceiling AND the data-shard space: every joiner
        # shards over max_workers for the whole run, so admitting a
        # late worker never re-partitions anyone else's data.  With no
        # elastic cap it equals num_workers — the pre-elastic contract,
        # bit for bit
        self.max_workers = max(self.num_workers,
                               int(max_workers or self.num_workers))
        self.join_secret = join_secret or None
        self.lease_grace_s = float(lease_grace_s)
        self.welcome_config = dict(welcome_config or {})
        self._leases: Dict[int, int] = {}       # worker_id -> generation
        self._departed: Dict[int, float] = {}   # worker_id -> close time
        self._lease_lock = threading.Lock()

    # ------------------------------------------------------------ leases
    def _taken_ids(self) -> set:
        """Worker ids currently held by a live connection — HELLO'd
        (serving) or leased-but-compiling (a JOIN whose HELLO is still
        pending)."""
        with self._conns_cond:
            taken = set()
            for c in self._conns:
                if c.closed.is_set():
                    continue
                if c.worker_id is not None:
                    taken.add(c.worker_id)
                elif c.leased_wid is not None:
                    taken.add(c.leased_wid)
        return taken

    def _on_join(self, conn, requested_id: int) -> Optional[str]:
        if self._draining:
            # permanent (no BUSY_MARKER): a worker whose reconnect
            # races the shutdown gets a fast, clean no instead of
            # retrying against a dying leader
            return ("the run is shutting down — no new workers are "
                    "being admitted")
        if self.join_secret and not conn.auth_ok:
            # park the JOIN behind a challenge; _on_auth grants the
            # lease once the digest verifies.  The nonce is per-attempt
            # random, so a captured AUTH frame cannot be replayed
            conn.pending_join = int(requested_id)
            conn.auth_nonce = os.urandom(_AUTH_NONCE_LEN)
            conn.awaiting_auth = True
            conn.send_frame(_challenge_frame(conn.auth_nonce))
            return None
        return self._grant_lease(conn, requested_id)

    def _on_auth(self, conn, digest: bytes) -> Optional[str]:
        secret, nonce = self.join_secret, conn.auth_nonce
        if not secret or nonce is None:
            return "unexpected AUTH frame — this hub issued no challenge"
        if not hmac.compare_digest(_auth_digest(secret, nonce),
                                   bytes(digest)):
            return ("join authentication failed: the AUTH digest does "
                    "not match this leader's join secret (check "
                    "--join-secret on both sides)")
        conn.awaiting_auth = False
        conn.auth_ok = True
        req, conn.pending_join = conn.pending_join, None
        return self._grant_lease(conn, -1 if req is None else req)

    def _grant_lease(self, conn, requested_id: int) -> Optional[str]:
        with self._lease_lock:
            taken = self._taken_ids()
            now = time.monotonic()
            if requested_id < 0:
                free = [w for w in range(self.max_workers)
                        if w not in taken]
                if not free:
                    return (f"{BUSY_MARKER} fleet is full: all "
                            f"{self.max_workers} worker ids are joined")
                # an auto join never receives a recently-departed id
                # inside its re-lease grace window — the departed host
                # may be mid-reconnect and would find its shard stolen
                open_now = [w for w in free
                            if now - self._departed.get(w, -1e18)
                            >= self.lease_grace_s]
                if not open_now:
                    return (f"{BUSY_MARKER} every free worker id is "
                            "inside the "
                            f"{self.lease_grace_s:.1f}s re-lease grace "
                            "window (its previous holder may rejoin)")
                wid = open_now[0]
            else:
                if requested_id >= self.max_workers:
                    return (f"worker id {requested_id} out of range "
                            f"(fleet size {self.max_workers})")
                if requested_id in taken:
                    return (f"{BUSY_MARKER} worker id {requested_id} "
                            "is already joined")
                # an explicit request skips the grace window: it IS the
                # departed holder resuming its shard (the reconnect
                # path), fenced by the generation bump either way
                wid = requested_id
            generation = self._leases.get(wid, -1) + 1
            self._leases[wid] = generation
            conn.leased_wid = wid
            self._departed.pop(wid, None)
        cfg = dict(self.welcome_config)
        cfg.update(worker_id=wid, generation=generation,
                   num_workers=self.max_workers,
                   heartbeat_s=self.heartbeat_s)
        conn.send_frame(_welcome_frame(cfg))
        _log.info("leased worker id %d (generation %d)", wid, generation)
        return None

    def _on_serve(self, conn) -> Optional[str]:
        """Admit a read-only serve client: no lease, no shard, no
        barrier seat — just a serve_id for the stats and a WELCOME
        carrying the spec so the client can rebuild the model."""
        with self._lease_lock:
            sid = self._serve_seq
            self._serve_seq += 1
        conn.is_serve = True
        conn.serve_id = sid
        # serve subscribers inherit the run's slab dtype (they learn it
        # from the spec in this WELCOME and decode the broadcast with
        # the matching codec)
        conn.slab_dtype = self.slab_dtype
        cfg = dict(self.welcome_config)
        cfg.update(role="serve", serve_id=sid,
                   heartbeat_s=self.heartbeat_s,
                   serve_every=self.serve_every)
        conn.send_frame(_welcome_frame(cfg))
        _log.info("admitted serve client %d (read-only)", sid)
        return None

    def _on_stats(self, conn) -> Optional[str]:
        """Admit a read-only stats client (``repro top``): no lease, no
        spec (it rebuilds nothing — it just renders JSON), just a
        stats_id and the push cadence.  WELCOME is sent here, before
        :meth:`_on_stats_ready` registers the connection for pushes, so
        the client always sees WELCOME before the first STATS frame."""
        with self._lease_lock:
            sid = self._stats_seq
            self._stats_seq += 1
        conn.is_stats = True
        conn.stats_id = sid
        cfg = {"role": "stats", "stats_id": sid,
               "heartbeat_s": self.heartbeat_s,
               "stats_every_s": self.stats_every_s}
        conn.send_frame(_welcome_frame(cfg))
        _log.info("admitted stats client %d (read-only)", sid)
        return None

    def _admit_hello(self, conn, worker_id: int,
                     generation: int) -> Optional[str]:
        if not 0 <= worker_id < self.max_workers:
            # an out-of-range id would count toward the fleet barrier
            # while its data shard doesn't exist — never admit it
            return (f"worker id {worker_id} out of range (fleet size "
                    f"{self.max_workers})")
        if self.join_secret and not conn.auth_ok:
            # the challenge lives on the JOIN leg; a bare HELLO would
            # bypass it, so on a secured leader only authenticated
            # joiners reach the barrier
            return ("this leader requires an authenticated JOIN "
                    "(shared --join-secret) — a direct HELLO is not "
                    "accepted")
        with self._lease_lock, self._conns_cond:
            for c in self._conns:
                # a leased-but-still-compiling joiner holds its id too
                # (worker_id is None until its HELLO): a direct HELLO
                # must not steal the shard out from under it
                if c is not conn and not c.closed.is_set() \
                        and worker_id in (c.worker_id, c.leased_wid):
                    return (f"worker id {worker_id} already has a "
                            "live connection")
            cur = self._leases.get(worker_id)
            if cur is not None and generation < cur:
                return (f"generation fence: worker {worker_id} HELLO "
                        f"carries generation {generation} but the "
                        f"current lease is {cur} (superseded peer)")
            if cur is None or generation > cur:
                # direct HELLO without a JOIN (e.g. a local endpoint):
                # record it so later joins/rejoins fence correctly
                self._leases[worker_id] = generation
            # claim the id INSIDE the admission critical section: a
            # racing admission or join for the same id must see this
            # connection as its holder (no duplicate-shard TOCTOU)
            conn.worker_id, conn.generation = worker_id, generation
            self._departed.pop(worker_id, None)
            return None

    def _conn_closed(self, conn) -> None:
        # record the departure time before the base class reaps the
        # connection: the re-lease grace window for auto joins is
        # measured from here
        wid = conn.worker_id if conn.worker_id is not None \
            else conn.leased_wid
        if wid is not None:
            with self._lease_lock:
                self._departed[wid] = time.monotonic()
        super()._conn_closed(conn)

    # ------------------------------------------------------------ faults
    def kill_worker(self, worker_id: int) -> bool:
        """Cut the worker's connection — the network fault a leader can
        actually inflict on a remote host.  The remote process sees EOF
        and exits cleanly; True if a live connection was cut."""
        with self._conns_cond:
            conns = [c for c in self._conns
                     if c.worker_id == worker_id
                     and not c.closed.is_set()]
        for c in conns:
            c.close()
        return bool(conns)


# =========================================================== join side


def _backoff_delays(base: float = 0.1, cap: float = 1.0
                    ) -> Iterator[float]:
    """Jittered exponential backoff: base, 2·base, … capped, each
    ±50% jittered so a fleet of joiners dialing a restarting leader
    never thunders in lockstep."""
    delay = base
    while True:
        yield delay * random.uniform(0.5, 1.5)
        delay = min(cap, delay * 2.0)


def _connect_retry(host: str, port: int,
                   timeout: float) -> socket.socket:
    """Dial the leader, retrying with jittered exponential backoff until
    it is up (the two-terminal quickstart and scripted smoke tests start
    both sides concurrently)."""
    deadline = time.monotonic() + max(0.0, timeout)
    delays = _backoff_delays()
    while True:
        try:
            return socket.create_connection((host, port), timeout=5.0)
        except OSError as e:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise WireProtocolError(
                    f"could not reach the leader at {host}:{port} "
                    f"within {timeout:.0f}s: {e}") from None
            time.sleep(min(next(delays), remaining))




def negotiate_join(address: Any, *, worker_id: Optional[int] = None,
                   connect_timeout: float = 30.0,
                   secret: Optional[str] = None
                   ) -> Tuple[socket.socket, Dict[str, Any]]:
    """The JOIN handshake: connect, request a worker-id lease, return
    ``(connected socket, welcome config)``.  ``connect_timeout`` covers
    the whole negotiation — an unreachable leader AND transient lease
    contention (e.g. a rejoin racing the teardown of its dead
    predecessor's connection) are retried with jittered backoff until
    the deadline.  ``secret`` answers a secured leader's CHALLENGE with
    the HMAC digest.  Raises :class:`WireProtocolError` with the
    leader's readable reason when the rejection is permanent or the
    deadline expires."""
    host, port = parse_hostport(address) if isinstance(address, str) \
        else tuple(address)[:2]
    deadline = time.monotonic() + max(0.0, connect_timeout)
    last_busy: Optional[WireProtocolError] = None
    delays = _backoff_delays()
    while True:
        sock = None
        try:
            sock = _connect_retry(host, int(port),
                                  max(0.0, deadline - time.monotonic()))
            frame = _join_frame(-1 if worker_id is None
                                else int(worker_id))
            return sock, _leader_handshake(sock, frame, deadline,
                                           what="join", secret=secret)
        except WireProtocolError as e:
            if sock is not None:
                sock.close()    # idempotent (handshake closes on fail)
            if BUSY_MARKER in str(e):
                last_busy = e
                if time.monotonic() > deadline:
                    raise
                time.sleep(min(next(delays),
                               max(0.0, deadline - time.monotonic())))
                continue
            if last_busy is not None \
                    and time.monotonic() > deadline:
                # the deadline ran out while retrying a busy lease —
                # the actionable error is the lease rejection, not the
                # generic timeout.  (A *permanent* rejection arriving
                # before the deadline — e.g. the leader restarted with
                # an incompatible build — wins over the stale busy.)
                raise last_busy
            raise


def negotiate_serve(address: Any, *, connect_timeout: float = 30.0
                    ) -> Tuple[socket.socket, Dict[str, Any]]:
    """The SERVE handshake: connect read-only, return ``(connected
    socket, welcome config)``.  No lease, so no busy-retry loop — a
    rejection is always permanent (wrong hub kind, incompatible
    build) and raises :class:`WireProtocolError` with the leader's
    readable reason."""
    host, port = parse_hostport(address) if isinstance(address, str) \
        else tuple(address)[:2]
    deadline = time.monotonic() + max(0.0, connect_timeout)
    sock = _connect_retry(host, int(port),
                          max(0.0, connect_timeout))
    return sock, _leader_handshake(sock, _serve_frame(), deadline,
                                   what="serve")


def negotiate_stats(address: Any, *, connect_timeout: float = 30.0
                    ) -> Tuple[socket.socket, Dict[str, Any]]:
    """The STATS handshake (``repro top``): connect as a read-only
    telemetry subscriber, return ``(connected socket, welcome config)``.
    Same shape as :func:`negotiate_serve`; rejections are permanent and
    raise :class:`WireProtocolError` with the leader's reason."""
    host, port = parse_hostport(address) if isinstance(address, str) \
        else tuple(address)[:2]
    deadline = time.monotonic() + max(0.0, connect_timeout)
    sock = _connect_retry(host, int(port),
                          max(0.0, connect_timeout))
    return sock, _leader_handshake(sock, _stats_frame(), deadline,
                                   what="stats")


def _leader_handshake(sock: socket.socket, request: bytes,
                      deadline: float, what: str = "join",
                      secret: Optional[str] = None) -> Dict[str, Any]:
    """Send one request frame (JOIN or SERVE) and read frames until the
    leader answers WELCOME (returned as the parsed config) or REJECT
    (raised with the leader's reason).  A CHALLENGE in between is
    answered with AUTH = HMAC-SHA256(``secret``, nonce); lacking a
    secret against a secured leader fails readably."""
    ok = False
    try:
        # re-armed per frame: the deadline covers the WHOLE negotiation
        # — a half-broken leader that keeps emitting frames (e.g.
        # PARAMS broadcasts) without ever sending WELCOME must not keep
        # the joiner looping past it (floor keeps a zero/negative
        # remainder from meaning "no timeout")
        sock.settimeout(max(0.1, deadline - time.monotonic()))
        sock.sendall(request)
        while True:
            if time.monotonic() > deadline:
                raise WireProtocolError(
                    f"leader did not complete the {what} handshake "
                    "within the deadline")
            sock.settimeout(max(0.1, deadline - time.monotonic()))
            hdr, _ = _recv_exact(sock, _HDR.size)
            if hdr is None:
                raise WireProtocolError(
                    f"leader hung up during the {what} handshake")
            ftype, n = _HDR.unpack(hdr)
            if n > _MAX_FRAME:
                raise WireProtocolError(
                    f"malformed handshake frame (type {ftype}, "
                    f"length {n})")
            payload, _ = _recv_exact(sock, n)
            if payload is None:
                raise WireProtocolError(
                    f"leader hung up mid-frame during the {what} "
                    "handshake")
            if ftype in (_F_PARAMS, _F_PING, _F_PONG):
                continue        # broadcasts/liveness racing the
                #                 handshake; the hub re-pushes current
                #                 params once the peer authenticates
            if n < _CTRL.size:
                raise WireProtocolError(
                    f"malformed handshake frame (type {ftype}, "
                    f"length {n})")
            magic, proto = _CTRL.unpack(payload[:_CTRL.size])
            err = _peer_error(magic, proto)
            if err is not None:
                raise WireProtocolError(f"leader handshake failed: {err}")
            body = payload[_CTRL.size:]
            if ftype == _F_CHALLENGE:
                if not secret:
                    raise WireProtocolError(
                        f"the leader requires an authenticated {what}: "
                        "pass the shared secret (--join-secret)")
                sock.sendall(_auth_frame(_auth_digest(secret, body)))
                continue
            if ftype == _F_REJECT:
                raise WireProtocolError(
                    f"leader rejected the {what}: "
                    + body.decode("utf-8", "replace"))
            if ftype != _F_WELCOME:
                raise WireProtocolError(
                    f"expected WELCOME, got frame type {ftype}")
            cfg = json.loads(body.decode("utf-8"))
            sock.settimeout(None)
            ok = True
            return cfg
    finally:
        if not ok:
            sock.close()


def build_slab_worker_fn(spec, worker_id: int, num_workers: int,
                         generation: int, *, batch: int, seed: int):
    """Rebuild one worker's world from an ``ExperimentSpec``: the
    jitted slab-in/slab-out gradient executable (compiled and warm) and
    a factory for its deterministic shard iterator.  Shared by ``proc``
    worker processes and ``host`` joiners — the spec is the whole
    cross-boundary contract."""
    import jax

    from repro.api.trainers import SIM_WORKLOADS
    from repro.core.slab import slab_codec
    from repro.data.pipeline import shard_iterator

    loss_fn, init_params, data, _ = SIM_WORKLOADS[spec.arch](spec)
    x_tr, y_tr = data[0], data[1]
    codec = slab_codec(init_params,
                       getattr(spec, "slab_dtype", "f32"))
    grad_fn = jax.grad(loss_fn)

    def _grad_slab(p_slab, x, y):
        return codec.encode(grad_fn(codec.decode(p_slab), x, y))

    grad = jax.jit(_grad_slab)

    def fresh_batches(gen: Optional[int] = None):
        # a rejoining worker reuses the compiled gradient and only
        # re-derives its stream for the new lease generation
        return shard_iterator(x_tr, y_tr, worker_id, num_workers,
                              batch, seed=seed,
                              generation=generation if gen is None
                              else int(gen))

    # warm up on a throwaway iterator: the training stream must start
    # at batch 0, exactly like an in-process worker's
    wx, wy = next(fresh_batches())
    jax.block_until_ready(grad(codec.encode(init_params), wx, wy))
    return grad, fresh_batches


def _rejoin(address: Any, wid: int, window_s: float, *,
            secret: Optional[str] = None, verbose: bool = True
            ) -> Optional[Tuple[socket.socket, Dict[str, Any]]]:
    """Reconnect after a mid-run drop: re-negotiate the *same* worker id
    (the explicit request skips the leader's grace window — we ARE the
    departed holder) for up to ``window_s``.  Returns the new
    ``(socket, welcome config)`` or ``None`` when the leader is gone,
    draining, or the window expired — all normal ends of a run."""
    if verbose:
        print(f"[join] worker {wid} lost the leader; reconnecting for "
              f"up to {window_s:.0f}s", flush=True)
    try:
        return negotiate_join(address, worker_id=wid,
                              connect_timeout=window_s, secret=secret)
    except WireProtocolError as e:
        if verbose:
            print(f"[join] worker {wid} will not rejoin: {e}",
                  flush=True)
        return None


def run_joined_worker(address: Any, *,
                      worker_id: Optional[int] = None,
                      connect_timeout: float = 30.0,
                      verbose: bool = True,
                      secret: Optional[str] = None,
                      reconnect_s: float = 0.0) -> int:
    """One joined worker, end to end: JOIN -> WELCOME -> rebuild the
    workload from the wire spec -> compile -> HELLO (ready) -> train
    until the leader hangs up (EOF) or the run ends.  With
    ``reconnect_s > 0`` a mid-run drop re-negotiates the same lease
    (bumped generation, fresh shard stream) for up to that window —
    a leader that is gone or draining ends the run cleanly instead.
    Returns a process exit code; raises :class:`WireProtocolError` when
    the *first* join is turned away (a failed rejoin after at least one
    completed session exits 0: the run is over or the shard is
    covered)."""
    sock, cfg = negotiate_join(address, worker_id=worker_id,
                               connect_timeout=connect_timeout,
                               secret=secret)
    from repro.api.spec import ExperimentSpec
    from repro.cluster.worker import Worker

    built = None            # ((wid, num_workers), (spec, grad, batches))
    total_sent = sessions = 0
    wid = generation = 0
    while True:
        wid, generation = int(cfg["worker_id"]), int(cfg["generation"])
        num_workers = int(cfg["num_workers"])
        if verbose:
            print(f"[join] leased worker {wid}.{generation} of "
                  f"{num_workers} from {_addr_str(address)}; rebuilding "
                  f"workload", flush=True)
        try:
            if built is None or built[0] != (wid, num_workers):
                spec = ExperimentSpec.from_dict(cfg["spec"])
                grad, fresh_batches = build_slab_worker_fn(
                    spec, wid, num_workers, generation,
                    batch=spec.batch, seed=spec.seed)
                built = ((wid, num_workers),
                         (spec, grad, fresh_batches))
            else:
                spec, grad, fresh_batches = built[1]
            # hung-leader watchdog, sized from the leader's own PING
            # cadence (announced in WELCOME): generous multiple, so a GC
            # pause or one slow flush never false-positives
            hb = float(cfg.get("heartbeat_s") or 0.0)
            stall_timeout = max(10.0, 5.0 * hb) if hb > 0 else 0.0
            # HELLO == ready: connect into the fleet barrier only now,
            # so the leader's serving clock never measures compile time
            client = SocketWorkerClient(None, wid, generation=generation,
                                        heartbeat_timeout_s=stall_timeout,
                                        sock=sock,
                                        slab_dtype=getattr(
                                            spec, "slab_dtype", "f32"))
        except Exception:
            traceback.print_exc()
            sys.stderr.flush()
            try:
                sock.close()
            except OSError:
                pass
            return 2

        worker = Worker(wid, grad_fn=grad,
                        batches=fresh_batches(generation),
                        transport=client, mode=spec.mode,
                        straggle_s=spec.faults.straggle_s(wid),
                        generation=generation)
        # leader shutdown/death closes the connection -> closed is set
        # -> the loop exits: a dead leader can never strand this worker
        worker.stop_event = client.closed
        if verbose:
            print(f"[join] worker {wid}.{generation} ready (compiled); "
                  "training", flush=True)
        worker.run()                        # inline, not as a thread
        client.flush(5.0)
        client.close()
        total_sent += worker.sent
        sessions += 1
        if worker.error:
            print(worker.error, file=sys.stderr, flush=True)
            return 3
        if client.reject_reason:
            print(f"[join] worker {wid}.{generation} was rejected: "
                  f"{client.reject_reason}", file=sys.stderr, flush=True)
            return 4
        if client.stall_reason:
            print(f"[join] worker {wid}.{generation} gave up: "
                  f"{client.stall_reason}", file=sys.stderr, flush=True)
            return 5
        if reconnect_s <= 0:
            break
        nxt = _rejoin(address, wid, reconnect_s, secret=secret,
                      verbose=verbose)
        if nxt is None:
            break
        sock, cfg = nxt
    if verbose:
        print(f"[join] worker {wid} done: {total_sent} gradients sent "
              f"over {sessions} session(s)", flush=True)
    return 0


def _join_child(address: str, connect_timeout: float, verbose: bool,
                secret: Optional[str] = None,
                reconnect_s: float = 0.0) -> None:
    """Child entry point for ``repro join --workers K`` (spawned, one
    JAX runtime each).  ``os._exit`` skips interpreter finalization —
    see ``mptransport._proc_worker_main`` for why."""
    code = 1
    try:
        code = run_joined_worker(address, connect_timeout=connect_timeout,
                                 verbose=verbose, secret=secret,
                                 reconnect_s=reconnect_s)
    except WireProtocolError as e:
        print(f"join failed: {e}", file=sys.stderr, flush=True)
        code = 4
    except Exception:
        traceback.print_exc()
        code = 2
    sys.stdout.flush()
    sys.stderr.flush()
    os._exit(code)


def join_main(address: str, *, worker_id: Optional[int] = None,
              workers: int = 1, connect_timeout: float = 60.0,
              verbose: bool = True, secret: Optional[str] = None,
              reconnect_s: float = 0.0) -> int:
    """``python -m repro join`` body.  ``workers > 1`` spawns one OS
    process per worker (each with its own JAX runtime), mirroring a
    multi-worker host joining the fleet."""
    if workers < 1:
        print("error: --workers must be >= 1", file=sys.stderr)
        return 2
    if workers > 1 and worker_id is not None:
        print("error: --worker-id and --workers > 1 are mutually "
              "exclusive (the leader assigns ids per worker)",
              file=sys.stderr)
        return 2
    if workers == 1:
        try:
            return run_joined_worker(address, worker_id=worker_id,
                                     connect_timeout=connect_timeout,
                                     verbose=verbose, secret=secret,
                                     reconnect_s=reconnect_s)
        except WireProtocolError as e:
            print(f"join failed: {e}", file=sys.stderr, flush=True)
            return 4
    import multiprocessing
    ctx = multiprocessing.get_context("spawn")
    procs = [ctx.Process(target=_join_child,
                         args=(address, connect_timeout, verbose,
                               secret, reconnect_s),
                         name=f"join-{i}") for i in range(workers)]
    for p in procs:
        p.start()
    code = 0
    for p in procs:
        p.join()
        if p.exitcode:
            code = max(code, abs(int(p.exitcode)))
    return code


def spawn_join_process(address: Any, *, workers: int = 1,
                       worker_id: Optional[int] = None,
                       connect_timeout: float = 120.0,
                       platform: Optional[str] = None,
                       secret: Optional[str] = None,
                       reconnect_s: Optional[float] = None
                       ) -> "subprocess.Popen":
    """Launch ``python -m repro join`` as a separate OS process group —
    the test/bench harness's stand-in for a second machine (distinct
    interpreter, distinct spec-JSON rebuild, TCP the only link).
    ``platform`` forces ``JAX_PLATFORMS`` in the group (pass ``"cpu"``
    when the caller holds an exclusive accelerator)."""
    cmd = [sys.executable, "-m", "repro", "join", _addr_str(address),
           "--workers", str(workers),
           "--connect-timeout", str(connect_timeout), "--quiet"]
    if worker_id is not None:
        cmd += ["--worker-id", str(worker_id)]
    if secret is not None:
        cmd += ["--join-secret", secret]
    if reconnect_s is not None:
        cmd += ["--reconnect", str(reconnect_s)]
    env = dict(os.environ)
    import repro
    pkg_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env["PYTHONPATH"] = pkg_root + os.pathsep + env.get("PYTHONPATH", "")
    if platform:
        env["JAX_PLATFORMS"] = platform
    return subprocess.Popen(cmd, env=env)
