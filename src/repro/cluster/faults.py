"""Fault plans for the wall-clock cluster runtime.

A :class:`FaultPlan` is the declarative description of everything that
goes wrong during a cluster run: stragglers (per-worker extra seconds per
gradient), worker kills at wall-clock times (with optional respawn after
a fixed delay), a server-side checkpoint cadence, and an optional
mid-run restore of the latest checkpoint (simulated server recovery).

Deliberately jax-free so :mod:`repro.api.spec` can embed a plan in an
``ExperimentSpec`` without pulling in the runtime; pair lists are stored
as tuples so the plan stays hashable and JSON round-trips (JSON lists
are coerced back on construction).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

Pairs = Tuple[Tuple[int, float], ...]


def _pairs(raw: Iterable, what: str) -> Pairs:
    out = []
    for item in raw:
        wid, val = item
        wid, val = int(wid), float(val)
        if wid < 0 or val < 0:
            raise ValueError(f"{what} entries must be (worker_id >= 0, "
                             f"seconds >= 0), got {item!r}")
        out.append((wid, val))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """What goes wrong, and when (wall-clock seconds from run start)."""
    stragglers: Pairs = ()        # (worker_id, extra seconds per gradient)
    kill: Pairs = ()              # (worker_id, kill at wall second t)
    respawn_after_s: float = 0.0  # respawn killed workers after this; 0=off
    checkpoint_every_s: float = 0.0   # server checkpoint cadence; 0=off
    restore_at_s: float = 0.0     # restore latest checkpoint mid-run; 0=off

    def __post_init__(self):
        object.__setattr__(self, "stragglers",
                           _pairs(self.stragglers, "stragglers"))
        object.__setattr__(self, "kill", _pairs(self.kill, "kill"))
        for f in ("respawn_after_s", "checkpoint_every_s", "restore_at_s"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0, "
                                 f"got {getattr(self, f)!r}")

    # ------------------------------------------------------------ queries
    def validate_worker_ids(self, limit: int) -> None:
        """Raise when the plan names a worker id the fleet can never
        hold.  ``limit`` is the *admission ceiling* (``max_workers`` on
        an elastic fleet, the fleet size otherwise): a fault aimed at a
        not-yet-joined elastic worker is legitimate — it simply finds
        nobody to kill if the worker never arrives."""
        bad_ids = sorted({wid for wid, _ in (*self.stragglers,
                                             *self.kill)
                          if wid >= limit})
        if bad_ids:
            raise ValueError(
                f"FaultPlan names worker ids {bad_ids} but the fleet "
                f"can hold at most {limit} workers (ids 0.."
                f"{limit - 1})")

    def straggle_s(self, worker_id: int) -> float:
        """Extra seconds this worker sleeps per gradient (0 = healthy)."""
        return dict(self.stragglers).get(worker_id, 0.0)

    def kill_events(self) -> List[Tuple[float, int]]:
        """[(t_s, worker_id)] sorted by kill time."""
        return sorted((t, wid) for wid, t in self.kill)

    @property
    def empty(self) -> bool:
        return not (self.stragglers or self.kill
                    or self.checkpoint_every_s or self.restore_at_s)


def parse_fault_pairs(s: str) -> Pairs:
    """CLI helper: ``"0:0.2,3:0.5"`` -> ``((0, 0.2), (3, 0.5))``."""
    out = []
    for part in s.split(","):
        part = part.strip()
        if not part:
            continue
        wid, sep, val = part.partition(":")
        if not sep:
            raise ValueError(f"expected WORKER:SECONDS, got {part!r}")
        out.append((int(wid), float(val)))
    return _pairs(out, "fault pairs")
