"""A cluster worker: one thread running real jitted gradient steps.

Each worker owns a deterministic minibatch iterator over its shard of
the training data (see :func:`repro.data.pipeline.shard_iterator`),
fetches the latest published parameter *slab* from the transport,
computes a real (jitted) gradient, and sends the gradient back as a
slab tagged with the parameter version it read — staleness in this
runtime is physical, not simulated.  ``grad_fn`` is slab-in/slab-out
(decode → grad → encode fused into one executable, built by the
runtime), so the worker flattens each gradient exactly once and the
transport carries single contiguous arrays in both directions.

Policy differences live entirely in *when* a worker blocks:

  * ``async`` / ``hybrid`` — fetch whatever version is current, never
    wait: a slow server means more stale gradients, exactly the
    contention the hybrid buffer amortises;
  * ``sync`` — after contributing to round v, block until the server
    publishes v+1 (the barrier's worker side).

Fault hooks: ``straggle_s`` adds a sleep per gradient (a slow node /
link); ``stop_event`` is the cooperative kill switch the fault injector
and the runtime's shutdown both use — the runtime *always* sets it on
the way out (even when the server died mid-run), and a worker process
wires it to its socket client's ``closed`` event, so neither a crashed
server nor a closed connection can leave a worker spinning in the
bounded-send retry loop.  A killed worker's in-flight gradient is lost
*before* send, so the accounting invariant
(sent == applied + dropped + buffered + pending + in-flight) holds.

Every transport wait here is a short *positive* timeout (never ``None``
= block forever, never ``<= 0`` = spin): each iteration re-checks
``stop_event``, which is what keeps the loop killable from outside.
"""
from __future__ import annotations

import threading
import time
import traceback
from typing import Callable, Iterator, Optional

import jax

from repro.cluster.transport import GradientMsg, Transport
from repro.obs.telemetry import NULL


class Worker(threading.Thread):
    def __init__(self, worker_id: int, *, grad_fn: Callable,
                 batches: Iterator, transport: Transport, mode: str,
                 straggle_s: float = 0.0, generation: int = 0,
                 name: Optional[str] = None, obs=None):
        super().__init__(name=name or f"worker-{worker_id}.{generation}",
                         daemon=True)
        self.worker_id = worker_id
        self.generation = generation
        self.grad_fn = grad_fn
        self.batches = batches
        self.transport = transport
        self.mode = mode
        self.straggle_s = straggle_s
        self.stop_event = threading.Event()
        self.sent = 0            # gradients actually handed to the server
        self.error: Optional[str] = None
        self.obs = obs if obs is not None else NULL

    def run(self) -> None:
        try:
            self._loop()
        except Exception:                       # surfaced by the runtime
            self.error = traceback.format_exc()

    def _loop(self) -> None:
        next_version = 0        # sync: the round we haven't contributed to
        epoch = 0               # restore epoch of the params last used
        while not self.stop_event.is_set():
            min_v = next_version if self.mode == "sync" else 0
            msg = self.transport.fetch_params(min_version=min_v,
                                              timeout=0.05)
            if msg is None:
                if self.mode == "sync" and min_v > 0:
                    # a checkpoint restore moves the server's version
                    # *backwards* (and wipes the in-progress round);
                    # waiting for the old round would stall the barrier
                    # until the budget expires — resync.  The restore
                    # EPOCH is the signal: a merely-lower version is
                    # indistinguishable from "my round has not finished
                    # yet" on a slow fleet, and re-contributing on that
                    # false positive would double-draw from the batch
                    # stream and break sync determinism
                    cur = self.transport.fetch_params(timeout=0)
                    if cur is not None \
                            and getattr(cur, "epoch", 0) != epoch:
                        msg = cur
                if msg is None:
                    continue
            epoch = getattr(msg, "epoch", 0)
            x, y = next(self.batches)
            t0 = time.monotonic()
            grad = self.grad_fn(msg.params, x, y)
            jax.block_until_ready(grad)
            dt = time.monotonic() - t0
            self.obs.observe("grad_s", dt)
            self.obs.observe(f"grad_s.w{self.worker_id}", dt)
            self.obs.span_at(f"worker/{self.worker_id}", "grad_compute",
                             t0, dt, version=msg.version)
            if self.straggle_s and self.stop_event.wait(self.straggle_s):
                break           # killed mid-straggle: gradient is lost
            out = GradientMsg(self.worker_id, grad, msg.version,
                              self.sent + 1)
            t0 = time.monotonic()
            ok = False          # bounded queue: block until the server
            while not ok and not self.stop_event.is_set():  # drains, or
                ok = self.transport.send_gradient(out, timeout=0.05)
            if not ok:
                break           # ...killed while blocked: gradient lost
            wait = time.monotonic() - t0
            self.obs.observe("send_wait_s", wait)
            self.obs.span_at(f"worker/{self.worker_id}", "send_wait",
                             t0, wait, version=msg.version)
            self.sent += 1
            if self.mode == "sync":
                next_version = msg.version + 1
