"""Adapter: ExperimentSpec -> wall-clock cluster runtime -> RunResult.

``backend="cluster"`` in :mod:`repro.api`.  ``spec.arch`` names the same
simulator workloads (``mlp``, ``cnn-mnist``, ``cnn-cifar``, anything
added via ``register_sim_workload``) — the point of the third backend is
that one spec re-targets simulator → SPMD → real concurrent cluster.

``spec.transport`` selects the wire (``inproc`` threads+queue,
``socket`` threads over TCP slab frames, ``proc`` one OS process per
worker over Unix-domain sockets — see :mod:`repro.cluster.mptransport` —
and ``host``, the multi-host leader that binds ``spec.listen`` and
admits `repro join` workers — see :mod:`repro.cluster.hostlink`).

The reported ``num_gradients`` is the server's applied-gradient counter,
exactly; ``extra["accounting"]`` carries the full conservation ledger
(computed == applied + dropped + buffered + pending + in-flight) and
``extra["events"]`` the fault/checkpoint timeline.  The server runs the
slab aggregation path (:mod:`repro.core.slab`): one flush executable
regardless of fleet size, donated in-place updates, slab wire format on
the transport.
"""
from __future__ import annotations

import time
from typing import TYPE_CHECKING, Optional

from repro.cluster.runtime import ClusterRuntime

if TYPE_CHECKING:   # real imports are lazy: repro.api.spec imports
    from repro.api.result import RunResult      # repro.cluster.faults,
    from repro.api.spec import ExperimentSpec   # so this must not be
    #                                             circular at module load


class ClusterTrainer:
    """Trainer protocol implementation for ``backend="cluster"``.

    ``ckpt_dir`` hosts the fault plan's checkpoint cadence / mid-run
    restore; when the plan needs one and none was given (e.g. the
    ``repro.api.run(spec)`` path, where only the spec is available), a
    temp directory is provisioned so a checkpointing spec stays
    runnable from its JSON alone (its path is logged as an event).
    ``resume_from`` starts the server from a saved checkpoint (K(t)
    continues from the restored step).  The trained parameters of the
    last run are kept on ``self.last_params``."""

    def __init__(self, ckpt_dir: Optional[str] = None,
                 resume_from: Optional[str] = None, verbose: bool = False,
                 trace: Optional[str] = None,
                 join_secret: Optional[str] = None,
                 prom_port: Optional[int] = None):
        self.ckpt_dir = ckpt_dir
        self.resume_from = resume_from
        self.verbose = verbose
        # the shared JOIN secret is an invocation credential, NOT a
        # spec field: the spec travels to every joiner in WELCOME, and
        # a secret embedded there would hand itself to whoever it is
        # meant to keep out
        self.join_secret = join_secret
        # Chrome trace-event output path (--trace): a run artifact like
        # --out, deliberately NOT an ExperimentSpec field — the spec
        # travels over the wire to proc/host workers and must describe
        # the experiment, not one invocation's local output files
        self.trace = trace
        # --prom-port: same reasoning — a scrape endpoint is bound on
        # this machine for this invocation, not part of the experiment
        self.prom_port = prom_port
        self.last_params = None

    def build_runtime(self, spec: "ExperimentSpec") -> ClusterRuntime:
        """Construct (but do not run) the runtime for ``spec``.  For
        the ``host`` transport the hub is bound by the time this
        returns, so ``runtime.listen_address`` carries the *resolved*
        ``(host, port)`` — callers that script both terminals of the
        multi-host quickstart (tests, benchmarks) read it here and
        launch their ``repro join`` groups before :meth:`finish`."""
        from repro.api.schedules import parse_schedule
        from repro.api.trainers import SIM_WORKLOADS

        builder = SIM_WORKLOADS.get(spec.arch)
        if builder is None:
            known = ", ".join(sorted(SIM_WORKLOADS))
            raise ValueError(f"unknown cluster workload {spec.arch!r} "
                             f"(known: {known}; register new ones via "
                             f"repro.api.register_sim_workload)")
        loss_fn, init_params, data, accuracy_fn = builder(spec)
        schedule = None
        if spec.mode == "hybrid":
            schedule = parse_schedule(spec.schedule, spec.cluster_workers)

        ckpt_dir = self.ckpt_dir
        if ckpt_dir is None and (spec.faults.checkpoint_every_s > 0
                                 or spec.faults.restore_at_s > 0):
            import tempfile
            ckpt_dir = tempfile.mkdtemp(prefix="repro-cluster-ckpt-")

        runtime = ClusterRuntime(
            loss_fn, init_params, data, mode=spec.mode, lr=spec.lr,
            batch=spec.batch, num_workers=spec.cluster_workers,
            wall_budget_s=spec.wall_budget_s,
            sample_every_s=spec.wall_sample_every_s, schedule=schedule,
            flush_mode=spec.flush_mode,
            staleness_decay=spec.staleness_decay,
            max_gradients=spec.max_gradients, seed=spec.seed,
            faults=spec.faults, accuracy_fn=accuracy_fn,
            transport_kind=spec.transport,
            # worker processes / joining hosts rebuild the workload from
            # the spec (the registry is the contract; code never crosses
            # the boundary)
            spec_dict=spec.to_dict() if spec.transport in ("proc",
                                                           "host")
            else None,
            listen=spec.listen,
            heartbeat_s=spec.heartbeat_s, serve_every=spec.serve_every,
            max_workers=spec.max_workers, join_secret=self.join_secret,
            slab_dtype=spec.slab_dtype,
            optimizer=spec.slab_optimizer(),
            # proc children connect as fast as JAX compiles (180s
            # default is plenty); host workers are started by a human
            # in another terminal, possibly on other machines — give
            # the documented two-terminal quickstart a 10-minute
            # window (scripted runs bound it with a hard timeout)
            proc_ready_timeout_s=600.0 if spec.transport == "host"
            else 180.0,
            ckpt_dir=ckpt_dir, resume_from=self.resume_from,
            verbose=self.verbose, trace=self.trace,
            prom_port=self.prom_port)
        if ckpt_dir is not None and self.ckpt_dir is None:
            runtime.events.append({"t": 0.0,
                                   "event": "ckpt_dir_provisioned",
                                   "path": ckpt_dir})
        return runtime

    def finish(self, runtime: ClusterRuntime,
               spec: "ExperimentSpec") -> "RunResult":
        """Run a runtime built by :meth:`build_runtime` and adapt the
        result."""
        from repro.api.result import RunResult
        t0 = time.time()
        cres = runtime.run()
        self.last_params = cres.final_params
        result = RunResult.from_cluster(cres, spec=spec,
                                        wall_s=time.time() - t0)
        if runtime.listen_address is not None:
            bind_host, bind_port = runtime.listen_address
            result.extra["listen"] = f"{bind_host}:{bind_port}"
        # serving-plane report: always present on the cluster backend
        # (empty-shaped when the transport has no serving plane), so
        # consumers never have to probe for the key — see api/result.py
        result.extra["serving"] = cres.serving if cres.serving \
            is not None else {"clients": 0, "rejected_peers": 0,
                              "serve_every": 1, "stats_clients": 0,
                              "per_client": []}
        # telemetry summary + ledger cross-check (see repro.obs)
        if cres.telemetry is not None:
            result.extra["telemetry"] = cres.telemetry
        if runtime.trace_path:
            from repro.obs import write_chrome_trace
            n = write_chrome_trace(runtime.obs, runtime.trace_path)
            result.extra["trace_path"] = runtime.trace_path
            if self.verbose:
                print(f"[cluster] wrote {n} trace events to "
                      f"{runtime.trace_path}", flush=True)
        return result

    def run(self, spec: "ExperimentSpec") -> "RunResult":
        return self.finish(self.build_runtime(spec), spec)
