"""The parameter server: live params + aggregation policy under a lock.

The server owns the one mutable copy of the parameters — as a flat
**gradient slab** (:mod:`repro.core.slab`) — and reuses the repo's
aggregation policies (a :class:`repro.core.schedule.ThresholdSchedule`
K(t)) against real concurrent workers:

  * ``async``  — K(t) ≡ 1: every ingested gradient is applied at once;
  * ``hybrid`` — gradients buffer until |buffer| >= K(version), then
    flush as one update (Smooth Switch);
  * ``sync``   — a barrier round: one gradient from every *live* worker
    at the current version, aggregated in worker-id order (which makes
    the policy bitwise-reproducible), applied as their mean.  Gradients
    from an older version (e.g. a worker that died mid-round and came
    back) are dropped and accounted.

The aggregation hot path is the slab path end-to-end: workers ship
``(P,)`` gradient slabs (see :class:`~repro.cluster.transport.
GradientMsg`), the server stages them into a preallocated
``(K_max, P)`` buffer, and **one** jitted, donated executable
(:class:`repro.core.slab.SlabAggregator`) applies every flush — any
buffer size K, any fleet size, one compile.  The pre-slab server
compiled ``num_workers`` separate executables at startup and copied the
full params pytree on every update; both costs are gone (the startup
probe in ``tests/test_slab.py`` pins the executable count to 1).

Donation rule: the params slab is updated *in place*, so nothing that
escapes the server may alias it.  Workers receive the published copy
the flush executable emits; :meth:`snapshot` decodes **and copies to
host** under the lock — a checkpoint that held a live reference would
be silently corrupted by the next flush.

Every mutation happens under ``self.lock``; membership changes
(kill/respawn) re-check the sync barrier so a shrinking fleet cannot
deadlock a round.  Exact accounting — ``applied`` / ``dropped``
gradients and ``version`` (= updates) — is what
``RunResult.num_gradients`` reports, to the gradient.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Optional, Set

import numpy as np

from repro.core.slab import SlabAggregator, SlabBuffer, slab_codec
from repro.core.schedule import ThresholdSchedule
from repro.cluster.transport import GradientMsg, ParamsMsg, Transport
from repro.obs.telemetry import NULL
from repro.optim.slab_form import SlabOptimizer


class ParameterServer:
    def __init__(self, params, *, lr: float, mode: str,
                 transport: Transport, num_workers: int,
                 schedule: Optional[ThresholdSchedule] = None,
                 flush_mode: str = "sum", staleness_decay: float = 1.0,
                 max_gradients: Optional[int] = None,
                 start_version: int = 0,
                 use_pallas: Optional[bool] = None,
                 interpret: bool = False,
                 slab_dtype: str = "f32",
                 optimizer: Optional[SlabOptimizer] = None,
                 obs=None):
        assert mode in ("sync", "async", "hybrid")
        assert flush_mode in ("sum", "mean")
        if mode in ("async", "hybrid"):
            assert schedule is not None, f"{mode} mode needs a K(t) schedule"
        self.lock = threading.RLock()
        self.obs = obs if obs is not None else NULL
        self._last_k: Optional[int] = None  # K(t) switch detection
        self.version = int(start_version)   # parameter updates applied
        self.start_version = int(start_version)
        self.mode = mode
        self.lr = lr
        self.schedule = schedule
        self.flush_mode = flush_mode
        self.staleness_decay = staleness_decay
        self.max_gradients = max_gradients
        self.transport = transport
        # a flush aggregates at most one gradient per worker — except
        # async, where the policy is K ≡ 1 *by definition* (the
        # schedule is ignored; see _ingest_buffered), pinning the
        # staging buffer to one row.  For hybrid, a schedule built for
        # a larger fleet can demand K > num_workers, so the staging
        # buffer covers the schedule's own ceiling too.
        if mode == "async":
            k_max = 1
        else:
            k_max = max(1, num_workers,
                        schedule.num_workers if schedule else 0)
        # slab_dtype is the declared aggregation/wire dtype: staging
        # rows, the published slab, and every frame on the transport
        # carry it, while the master params slab and the flush
        # reduction stay f32 (see repro.core.slab)
        self.codec = slab_codec(params, slab_dtype)
        # the optimizer lives on the slab: moments (if any) are f32
        # slab-shaped buffers inside the aggregator, applied by the same
        # fused executable as the aggregation itself
        self.optimizer = optimizer or SlabOptimizer("sgd")
        self.agg = SlabAggregator(self.codec, params, k_max,
                                  use_pallas=use_pallas,
                                  interpret=interpret,
                                  optimizer=self.optimizer)
        # compile the stage + flush executables before the clock starts
        # (compiling mid-run would stall the whole fleet under the
        # server lock) — one compile each, for any fleet size
        self.agg.warmup()
        self.buffer = SlabBuffer(self.agg, staleness_decay)
        self.applied = 0                    # gradients folded into updates
        self.dropped = 0                    # stale / discarded gradients
        self.updates_applied = 0            # _apply calls (never rolled
        #                                     back, unlike version)
        self.restore_epoch = 0              # bumped per restore(); rides
        #                                     on ParamsMsg so workers can
        #                                     tell a restore from a slow
        #                                     round (see ParamsMsg.epoch)
        # membership starts empty: workers register as they spawn
        # (num_workers is the fleet size = the staging buffer's K_max)
        self.live: Set[int] = set()
        self._round: Dict[int, Any] = {}    # sync: worker_id -> grad slab
        self.done = threading.Event()       # max_gradients budget reached
        transport.publish_params(ParamsMsg(self.version,
                                           self.agg.params_slab))

    # ------------------------------------------------------- membership
    def grow_fleet(self, num_workers: int,
                   schedule: Optional[ThresholdSchedule] = None) -> None:
        """Admit a fleet larger than construction time planned for
        (elastic membership): grow the staging buffer to cover
        ``num_workers`` simultaneous contributions and, when a
        re-derived K(t) ``schedule`` for the new fleet size is handed
        in, swap it in atomically with the resize.  Must run *before*
        :meth:`register` for any worker id beyond the old ceiling — a
        sync round stages one row per live worker, so staging must
        already cover the grown fleet when the barrier fills.  Exact
        accounting is untouched: staged rows are preserved by
        :meth:`repro.core.slab.SlabAggregator.grow` and the host-side
        version list never moves."""
        with self.lock:
            if schedule is not None:
                self.schedule = schedule
            if self.mode == "async":
                k_max = 1       # K ≡ 1 by definition: one row, any fleet
            else:
                k_max = max(1, int(num_workers),
                            self.schedule.num_workers
                            if self.schedule else 0)
            self.agg.grow(k_max)

    def register(self, worker_id: int) -> None:
        with self.lock:
            self.live.add(worker_id)

    def deregister(self, worker_id: int) -> None:
        with self.lock:
            self.live.discard(worker_id)
            if self.mode == "sync":
                # a shrinking fleet may complete the round it was blocking
                self._maybe_complete_round()

    # ---------------------------------------------------------- ingest
    def ingest(self, msg: GradientMsg) -> None:
        with self.lock:
            # telemetry: every gradient that reached the server, and
            # how stale it was on arrival (server version minus the
            # version it was computed against; negative after a restore
            # rolled the clock back).  The ledger cross-check is
            # grads_ingested == applied + dropped + buffered + pending
            self.obs.count("grads_ingested")
            self.obs.count(f"grads_ingested.w{msg.worker_id}")
            stale = self.version - msg.version
            self.obs.observe("staleness", stale)
            self.obs.observe(f"staleness.w{msg.worker_id}", stale)
            if self.done.is_set():
                self.dropped += 1
                self.obs.count("drops.budget")
                return
            if self.mode == "sync":
                self._ingest_sync(msg)
            else:
                self._ingest_buffered(msg)

    def _ingest_sync(self, msg: GradientMsg) -> None:
        if msg.version != self.version:
            self.dropped += 1       # late arrival from a previous round
            self.obs.count("drops.stale")
            return
        if msg.worker_id in self._round:
            # a worker re-contributing to an in-progress round (it can,
            # legitimately, after a restore rolled the version back
            # while it was waiting): latest wins, the overwritten
            # gradient is accounted as dropped
            self.dropped += 1
            self.obs.count("drops.duplicate")
        self._round[msg.worker_id] = msg.grad
        self._maybe_complete_round()

    def _maybe_complete_round(self) -> None:
        if not self.live or not set(self._round) >= self.live:
            return
        wids = sorted(self._round)          # deterministic fold order
        for slot, w in enumerate(wids):
            self.agg.stage(self._round[w], slot)
        k = len(wids)
        self._round = {}
        # sync: the plain mean of the round's gradients
        self._apply(np.ones((k,)), self.lr)

    def _ingest_buffered(self, msg: GradientMsg) -> None:
        self.buffer.add(msg.grad, msg.version)
        # async is K ≡ 1 by definition (its one-row staging buffer
        # depends on it); hybrid asks the K(t) schedule
        k_needed = 1 if self.mode == "async" else \
            self.schedule(self.version)
        if k_needed != self._last_k:
            # the paper's async→sync handoff, as a timeline event
            if self._last_k is not None:
                self.obs.count("k_switches")
                self.obs.instant("server", "k_switch", k=k_needed,
                                 version=self.version)
            self._last_k = k_needed
        if len(self.buffer) >= k_needed:
            weights = self.buffer.weights(self.version)
            k = len(self.buffer)
            self.buffer.clear()
            # "sum" applies every buffered gradient at full lr (the
            # paper's Algorithm 1; K=1 ≡ async exactly); "mean" is the
            # sync-style confident update — both are one fused scale
            scale = self.lr * k if self.flush_mode == "sum" else self.lr
            self._apply(weights, scale)

    def _apply(self, weights: np.ndarray, scale: float) -> None:
        t0 = time.monotonic()
        pub = self.agg.flush_apply(weights, scale)
        dt = time.monotonic() - t0
        self.version += 1
        self.updates_applied += 1
        self.applied += len(weights)
        self.obs.observe("flush_s", dt)
        # the optimizer step IS the fused flush — one histogram + one
        # counter at the seam, whatever the optimizer (sgd included),
        # so `repro top`/Prometheus can watch update latency per choice
        self.obs.observe("opt_update_s", dt)
        self.obs.count("optimizer_steps")
        self.obs.span_at("server", "flush", t0, dt, k=len(weights),
                         version=self.version)
        self.obs.count("grads_applied", len(weights))
        self.obs.count("updates")
        t1 = time.monotonic()
        self.transport.publish_params(
            ParamsMsg(self.version, pub, epoch=self.restore_epoch))
        dt1 = time.monotonic() - t1
        self.obs.observe("publish_s", dt1)
        self.obs.span_at("server", "publish", t1, dt1,
                         version=self.version)
        self.obs.count("params_published")
        if self.max_gradients and self.applied >= self.max_gradients:
            self.done.set()

    # ----------------------------------------------- snapshot / restore
    def snapshot(self):
        """(version, params, applied) — params is a **host copy** of the
        decoded tree: with a donated params slab a live reference to the
        server's internals would be invalidated by the next flush.  Only
        the published-slab grab needs the lock (it is a fresh,
        never-donated executable output); the decode + host copy happens
        outside it, so samplers/checkpointers never stall ingest on the
        serial resource they are measuring."""
        with self.lock:
            version, pub, applied = (self.version, self.agg.params_slab,
                                     self.applied)
        return version, self.codec.decode_host(pub), applied

    def snapshot_slab(self):
        """(version, params_slab, applied) — the *published* params
        slab, which by the donation contract is a fresh executable
        output that stays valid forever.  The zero-work snapshot for
        in-run samplers: decode after the run, off the hot path."""
        with self.lock:
            return self.version, self.agg.params_slab, self.applied

    def snapshot_for_checkpoint(self):
        """(version, params, applied, opt_state) with params and the
        optimizer moments captured under **one** lock acquisition — a
        flush landing between two separate snapshots would persist
        moments one step ahead of the params they belong to.  The
        moment copy runs under the lock (donation rule); the params
        decode + host copy happens outside it, like :meth:`snapshot`."""
        with self.lock:
            version, pub, applied = (self.version, self.agg.params_slab,
                                     self.applied)
            opt_state = self.agg.opt_state_host()
        return version, self.codec.decode_host(pub), applied, opt_state

    def snapshot_opt_state(self):
        """Host copies of the optimizer's moment slabs + update count
        (``None`` for sgd).  The whole copy runs **under the lock**, per
        the donation rule: the moments are donated buffers, and a
        concurrent flush would invalidate them mid-copy — unlike the
        published params slab, there is no fresh-output shortcut."""
        with self.lock:
            return self.agg.opt_state_host()

    def restore(self, params, step: int, opt_state=None) -> None:
        """Restore-into-running-server: replace the live params and
        version (so K(t) continues from ``step``), discarding any
        in-buffer or mid-round gradients (they were computed against a
        history that no longer exists — and are *wiped*, not just
        masked, because a diverged non-finite gradient would poison
        later flushes through ``0 · inf = nan``)."""
        with self.lock:
            lost = len(self.buffer) + len(self._round)
            self.dropped += lost
            self.obs.count("drops.restore", lost)
            self.obs.count("restores")
            self.obs.instant("server", "restore", step=int(step),
                             lost=lost)
            self.buffer.discard()
            self._round = {}
            self.agg.reset_params(params)
            # moments resync with the same epoch bump: either the
            # checkpointed slabs + count, or zeros — stale moments
            # against restored params would re-apply abandoned history
            self.agg.reset_opt_state(opt_state)
            self.version = int(step)
            # the epoch bump is what tells a sync worker "this is a
            # restore, recontribute" — the version alone can look like
            # an ordinary not-yet-finished round
            self.restore_epoch += 1
            self.transport.publish_params(
                ParamsMsg(self.version, self.agg.params_slab,
                          epoch=self.restore_epoch))

    def accounting(self) -> Dict[str, int]:
        with self.lock:
            # "updates" counts _apply calls: a mid-run restore rolls
            # version backwards but not the work actually done, so this
            # stays consistent with the applied-gradient counter
            return {"applied": self.applied, "dropped": self.dropped,
                    "buffered": len(self.buffer),
                    "pending_round": len(self._round),
                    "updates": self.updates_applied}
