"""The parameter server: live params + aggregation policy under a lock.

The server owns the one mutable copy of the parameters and reuses the
repo's existing aggregation machinery — :class:`repro.core.buffer.
GradientBuffer` and a :class:`repro.core.schedule.ThresholdSchedule`
K(t) — so the cluster runtime exercises *exactly* the same policies as
the virtual-time simulator, but against real concurrent workers:

  * ``async``  — K(t) ≡ 1: every ingested gradient is applied at once;
  * ``hybrid`` — gradients buffer until |buffer| >= K(version), then
    flush as one update (Smooth Switch);
  * ``sync``   — a barrier round: one gradient from every *live* worker
    at the current version, aggregated in worker-id order (which makes
    the policy bitwise-reproducible), applied as their mean.  Gradients
    from an older version (e.g. a worker that died mid-round and came
    back) are dropped and accounted.

Every mutation happens under ``self.lock``; membership changes
(kill/respawn) re-check the sync barrier so a shrinking fleet cannot
deadlock a round.  Exact accounting — ``applied`` / ``dropped``
gradients and ``version`` (= updates) — is what
``RunResult.num_gradients`` reports, to the gradient.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import GradientBuffer
from repro.core.schedule import ThresholdSchedule
from repro.cluster.transport import GradientMsg, ParamsMsg, Transport


class ParameterServer:
    def __init__(self, params, *, lr: float, mode: str,
                 transport: Transport, num_workers: int,
                 schedule: Optional[ThresholdSchedule] = None,
                 flush_mode: str = "sum", staleness_decay: float = 1.0,
                 max_gradients: Optional[int] = None,
                 start_version: int = 0):
        assert mode in ("sync", "async", "hybrid")
        assert flush_mode in ("sum", "mean")
        if mode in ("async", "hybrid"):
            assert schedule is not None, f"{mode} mode needs a K(t) schedule"
        self.lock = threading.RLock()
        self.params = params
        self.version = int(start_version)   # parameter updates applied
        self.start_version = int(start_version)
        self.mode = mode
        self.lr = lr
        self.schedule = schedule
        self.flush_mode = flush_mode
        self.staleness_decay = staleness_decay
        self.max_gradients = max_gradients
        self.transport = transport
        self.buffer = GradientBuffer(staleness_decay)
        # the whole flush — weighted aggregation of K gradients + the
        # parameter update — is one fused executable; the server is a
        # serial resource, so per-leaf eager dispatch here would
        # serialize the fleet.  jit caches one executable per buffer
        # size K (the argument tuple's structure), mirroring the SPMD
        # driver's one-executable-per-phase discipline.
        def _agg_apply(params, grads, weights, scale):
            wsum = jnp.sum(weights)

            def comb(p, *leaves):
                s = weights[0] * leaves[0]
                for w, leaf in zip(weights[1:], leaves[1:]):
                    s = s + w * leaf
                return p - scale * (s / wsum)

            return jax.tree.map(comb, params, *grads)

        self._agg_apply = jax.jit(_agg_apply)
        # compile every buffer size the run can reach (K ∈ 1..fleet)
        # before the clock starts: a flush only ever aggregates up to
        # one gradient per worker, and compiling mid-run would stall
        # the whole fleet under the server lock
        for k in range(1, max(1, num_workers) + 1):
            self._agg_apply(params, (params,) * k,
                            jnp.ones((k,), jnp.float32), 0.0)
        self.applied = 0                    # gradients folded into updates
        self.dropped = 0                    # stale / discarded gradients
        self.updates_applied = 0            # _apply calls (never rolled
        #                                     back, unlike version)
        # membership starts empty: workers register as they spawn
        # (num_workers is the fleet size, used to pre-compile above)
        self.live: Set[int] = set()
        self._round: Dict[int, Any] = {}    # sync: worker_id -> gradient
        self.done = threading.Event()       # max_gradients budget reached
        transport.publish_params(ParamsMsg(self.version, self.params))

    # ------------------------------------------------------- membership
    def register(self, worker_id: int) -> None:
        with self.lock:
            self.live.add(worker_id)

    def deregister(self, worker_id: int) -> None:
        with self.lock:
            self.live.discard(worker_id)
            if self.mode == "sync":
                # a shrinking fleet may complete the round it was blocking
                self._maybe_complete_round()

    # ---------------------------------------------------------- ingest
    def ingest(self, msg: GradientMsg) -> None:
        with self.lock:
            if self.done.is_set():
                self.dropped += 1
                return
            if self.mode == "sync":
                self._ingest_sync(msg)
            else:
                self._ingest_buffered(msg)

    def _ingest_sync(self, msg: GradientMsg) -> None:
        if msg.version != self.version:
            self.dropped += 1       # late arrival from a previous round
            return
        if msg.worker_id in self._round:
            # a worker re-contributing to an in-progress round (it can,
            # legitimately, after a restore rolled the version back
            # while it was waiting): latest wins, the overwritten
            # gradient is accounted as dropped
            self.dropped += 1
        self._round[msg.worker_id] = msg.grad
        self._maybe_complete_round()

    def _maybe_complete_round(self) -> None:
        if not self.live or not set(self._round) >= self.live:
            return
        wids = sorted(self._round)          # deterministic fold order
        grads = [self._round[w] for w in wids]
        self._round = {}
        # sync: the plain mean of the round's gradients
        self._apply(grads, np.ones(len(grads)), self.lr)

    def _ingest_buffered(self, msg: GradientMsg) -> None:
        self.buffer.add(msg.grad, msg.version)
        if len(self.buffer) >= self.schedule(self.version):
            grads, versions = self.buffer.drain()
            # clamp at 0: after a restore rolls the version back, an
            # in-flight gradient can be tagged with a *future* version,
            # and a negative exponent would upweight exactly the
            # abandoned-history gradients restore() discards
            stale = np.maximum(
                0.0, self.version - np.asarray(versions, np.float64))
            weights = self.staleness_decay ** stale
            # "sum" applies every buffered gradient at full lr (the
            # paper's Algorithm 1; K=1 ≡ async exactly); "mean" is the
            # sync-style confident update — both are one fused scale
            k = len(grads)
            scale = self.lr * k if self.flush_mode == "sum" else self.lr
            self._apply(grads, weights, scale)

    def _apply(self, grads, weights, scale: float) -> None:
        self.params = self._agg_apply(
            self.params, tuple(grads),
            jnp.asarray(weights, jnp.float32), scale)
        self.version += 1
        self.updates_applied += 1
        self.applied += len(grads)
        self.transport.publish_params(ParamsMsg(self.version, self.params))
        if self.max_gradients and self.applied >= self.max_gradients:
            self.done.set()

    # ----------------------------------------------- snapshot / restore
    def snapshot(self):
        """(version, params, applied) — params is an immutable pytree
        reference, so this is cheap and safe to evaluate later."""
        with self.lock:
            return self.version, self.params, self.applied

    def restore(self, params, step: int) -> None:
        """Restore-into-running-server: replace the live params and
        version (so K(t) continues from ``step``), discarding any
        in-buffer or mid-round gradients (they were computed against a
        history that no longer exists)."""
        with self.lock:
            lost = len(self.buffer) + len(self._round)
            self.dropped += lost
            self.buffer = GradientBuffer(self.staleness_decay)
            self._round = {}
            self.params = params
            self.version = int(step)
            self.transport.publish_params(
                ParamsMsg(self.version, self.params))

    def accounting(self) -> Dict[str, int]:
        with self.lock:
            # "updates" counts _apply calls: a mid-run restore rolls
            # version backwards but not the work actually done, so this
            # stays consistent with the applied-gradient counter
            return {"applied": self.applied, "dropped": self.dropped,
                    "buffered": len(self.buffer),
                    "pending_round": len(self._round),
                    "updates": self.updates_applied}
