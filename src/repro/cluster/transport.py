"""Message transport between cluster workers and the parameter server.

Two channels:

  * gradients, worker -> server (:class:`GradientMsg`): a multi-producer
    queue the server drains;
  * parameters, server -> workers (:class:`ParamsMsg`): a versioned
    broadcast cell — workers always read the latest published version,
    optionally blocking until a minimum version appears (the sync
    barrier's worker side).

**Wire format:** both payloads are gradient *slabs* (:mod:`repro.core.
slab`) — one contiguous, tile-aligned ``(P,)`` float32 array per
message, not a pytree of leaves.  Workers flatten a gradient exactly
once (inside their jitted gradient executable) and the server stages
the slab straight into its aggregation buffer.  A multi-process /
multi-host transport (sockets, shared memory, RPC) serializes each
message as one buffer with no per-leaf framing — the slab codec on both
ends is the (cached) schema.

:class:`Transport` is the interface; :class:`InProcTransport` is the
in-process (threads + queue) implementation; :mod:`repro.cluster.
mptransport` provides the socket / multi-process implementations.  All
blocking calls take timeouts, and nothing assumes the payloads share an
address space beyond the payload field itself.

**Timeout contract** (uniform across every method and implementation):

  * ``timeout=None`` — block until the call can complete;
  * ``timeout <= 0`` — never block (poll once and return);
  * ``timeout > 0``  — block at most that many seconds.

A call that gives up (timeout elapsed, nothing available) returns the
sentinel (``False`` for sends, ``None`` for receives) — it never raises.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Optional, Protocol

# the spec-facing transport names (ExperimentSpec.transport / --transport):
#   inproc — worker threads + queue: one address space, GIL-shared compute
#   socket — worker threads, but every message crosses a real TCP socket
#            (length-prefixed slab frames): the wire format is physical
#   proc   — one OS process per worker over Unix-domain sockets: stale
#            reads, stragglers, and SIGKILL worker death are physical
#   host   — the leader binds a routable --listen HOST:PORT and remote
#            workers join it themselves (`python -m repro join`): the
#            address, the discovery, and the machine boundary are real
TRANSPORTS = ("inproc", "socket", "proc", "host")


@dataclasses.dataclass
class GradientMsg:
    worker_id: int
    grad: Any          # gradient slab: (P,) f32 (repro.core.slab layout)
    version: int       # params version the gradient was computed against
    seq: int           # worker-local gradient counter (accounting)


@dataclasses.dataclass
class ParamsMsg:
    version: int
    params: Any        # params slab: (P,) f32 — the server's published
    #                    copy (never an alias of its donated buffer)
    epoch: int = 0     # restore epoch: bumped on every checkpoint
    #                    restore.  Version alone cannot signal a
    #                    restore — "version went backwards" is
    #                    indistinguishable from "my round has not
    #                    completed yet" on a slow fleet, and sync
    #                    workers must resync on the former but keep
    #                    waiting on the latter


class Transport(Protocol):
    """Wire between N workers and one parameter server.

    The timeout contract (module docstring) is part of the protocol:
    ``None`` blocks, ``<= 0`` polls, positive waits at most that long.
    """

    def send_gradient(self, msg: GradientMsg,
                      timeout: Optional[float] = None
                      ) -> bool:                             # worker side
        """Hand one gradient to the server.  ``True`` once the message
        is durably in the channel; ``False`` if the channel stayed full
        for the whole timeout (backpressure) — the caller retries with
        the *same* message."""
        ...

    def recv_gradient(self, timeout: Optional[float] = None
                      ) -> Optional[GradientMsg]:            # server side
        """Next gradient, or ``None`` if none arrived within the
        timeout (``timeout=None`` blocks until one does)."""
        ...

    def publish_params(self, msg: ParamsMsg) -> None:        # server side
        """Replace the broadcast cell — *unconditionally*, even when
        ``msg.version`` is lower than the current one: a checkpoint
        restore legitimately moves the published version backwards, and
        workers resync to whatever is current."""
        ...

    def fetch_params(self, min_version: int = 0,
                     timeout: Optional[float] = None
                     ) -> Optional[ParamsMsg]:               # worker side
        """Latest published params with ``version >= min_version``, or
        ``None`` on timeout (the sync barrier's worker side)."""
        ...

    def pending_gradients(self) -> int:
        """Gradients sent but not yet received.  **Approximate while
        producers are live** (it reads a concurrently-mutated queue
        size); exact only once every producer has stopped and, for
        multi-process transports, :meth:`quiesce` returned ``True`` —
        which is the only state in which the conservation ledger may
        read it."""
        ...

    def quiesce(self, timeout: Optional[float] = None) -> bool:
        """Block until no in-flight bytes remain between producers and
        :meth:`recv_gradient` (socket transports: every connection
        drained to EOF).  ``True`` when fully quiesced.  Callers must
        have stopped the producers first, and may need to interleave
        ``recv_gradient(timeout=0)`` drains with ``quiesce`` calls — a
        bounded channel can otherwise never empty."""
        ...

    def close(self) -> None:
        """Release transport resources (sockets, threads, processes).
        Idempotent."""
        ...


class InProcTransport:
    """Threads-in-one-process transport: queue + versioned broadcast cell.

    ``grad_capacity`` bounds the gradient queue (0 = unbounded): a full
    queue blocks the sending worker, which is the backpressure a real
    wire applies when the server is the bottleneck — without it an
    outpaced server accumulates an unbounded stale-gradient backlog."""

    def __init__(self, grad_capacity: int = 0):
        self._grads: "queue.Queue[GradientMsg]" = \
            queue.Queue(maxsize=grad_capacity)
        self._cell: Optional[ParamsMsg] = None
        self._cond = threading.Condition()

    # ------------------------------------------------- gradient channel
    def send_gradient(self, msg: GradientMsg,
                      timeout: Optional[float] = None) -> bool:
        try:
            if timeout is not None and timeout <= 0:
                self._grads.put_nowait(msg)
            else:                       # None blocks (the contract)
                self._grads.put(msg, timeout=timeout)
            return True
        except queue.Full:
            return False

    def recv_gradient(self, timeout: Optional[float] = None
                      ) -> Optional[GradientMsg]:
        # timeout=None must BLOCK, matching send_gradient — it used to
        # mean get_nowait(), the opposite of the send side's contract
        try:
            if timeout is not None and timeout <= 0:
                return self._grads.get_nowait()
            return self._grads.get(timeout=timeout)
        except queue.Empty:
            return None

    def pending_gradients(self) -> int:
        return self._grads.qsize()      # exact once producers stopped

    def quiesce(self, timeout: Optional[float] = None) -> bool:
        return True     # same address space: nothing is ever in flight

    def close(self) -> None:
        pass

    # ------------------------------------------------ parameter channel
    def publish_params(self, msg: ParamsMsg) -> None:
        with self._cond:
            self._cell = msg
            self._cond.notify_all()

    def fetch_params(self, min_version: int = 0,
                     timeout: Optional[float] = None
                     ) -> Optional[ParamsMsg]:
        with self._cond:
            ok = self._cond.wait_for(
                lambda: self._cell is not None
                and self._cell.version >= min_version, timeout)
            return self._cell if ok else None
