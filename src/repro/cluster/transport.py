"""Message transport between cluster workers and the parameter server.

Two channels:

  * gradients, worker -> server (:class:`GradientMsg`): a multi-producer
    queue the server drains;
  * parameters, server -> workers (:class:`ParamsMsg`): a versioned
    broadcast cell — workers always read the latest published version,
    optionally blocking until a minimum version appears (the sync
    barrier's worker side).

**Wire format:** both payloads are gradient *slabs* (:mod:`repro.core.
slab`) — one contiguous, tile-aligned ``(P,)`` float32 array per
message, not a pytree of leaves.  Workers flatten a gradient exactly
once (inside their jitted gradient executable) and the server stages
the slab straight into its aggregation buffer.  A multi-process /
multi-host transport (sockets, shared memory, RPC) serializes each
message as one buffer with no per-leaf framing — the slab codec on both
ends is the (cached) schema.

:class:`Transport` is the interface; :class:`InProcTransport` is the
in-process (threads + queue) implementation.  All blocking calls take
timeouts, and nothing assumes the payloads share an address space
beyond the payload field itself.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Optional, Protocol


@dataclasses.dataclass
class GradientMsg:
    worker_id: int
    grad: Any          # gradient slab: (P,) f32 (repro.core.slab layout)
    version: int       # params version the gradient was computed against
    seq: int           # worker-local gradient counter (accounting)


@dataclasses.dataclass
class ParamsMsg:
    version: int
    params: Any        # params slab: (P,) f32 — the server's published
    #                    copy (never an alias of its donated buffer)


class Transport(Protocol):
    """Wire between N workers and one parameter server."""

    def send_gradient(self, msg: GradientMsg,
                      timeout: Optional[float] = None
                      ) -> bool:                             # worker side
        ...

    def recv_gradient(self, timeout: Optional[float] = None
                      ) -> Optional[GradientMsg]:            # server side
        ...

    def publish_params(self, msg: ParamsMsg) -> None:        # server side
        ...

    def fetch_params(self, min_version: int = 0,
                     timeout: Optional[float] = None
                     ) -> Optional[ParamsMsg]:               # worker side
        ...

    def pending_gradients(self) -> int:
        ...


class InProcTransport:
    """Threads-in-one-process transport: queue + versioned broadcast cell.

    ``grad_capacity`` bounds the gradient queue (0 = unbounded): a full
    queue blocks the sending worker, which is the backpressure a real
    wire applies when the server is the bottleneck — without it an
    outpaced server accumulates an unbounded stale-gradient backlog."""

    def __init__(self, grad_capacity: int = 0):
        self._grads: "queue.Queue[GradientMsg]" = \
            queue.Queue(maxsize=grad_capacity)
        self._cell: Optional[ParamsMsg] = None
        self._cond = threading.Condition()

    # ------------------------------------------------- gradient channel
    def send_gradient(self, msg: GradientMsg,
                      timeout: Optional[float] = None) -> bool:
        try:
            self._grads.put(msg, timeout=timeout)
            return True
        except queue.Full:
            return False

    def recv_gradient(self, timeout: Optional[float] = None
                      ) -> Optional[GradientMsg]:
        try:
            if timeout is None or timeout <= 0:
                return self._grads.get_nowait()
            return self._grads.get(timeout=timeout)
        except queue.Empty:
            return None

    def pending_gradients(self) -> int:
        return self._grads.qsize()

    # ------------------------------------------------ parameter channel
    def publish_params(self, msg: ParamsMsg) -> None:
        with self._cond:
            self._cell = msg
            self._cond.notify_all()

    def fetch_params(self, min_version: int = 0,
                     timeout: Optional[float] = None
                     ) -> Optional[ParamsMsg]:
        with self._cond:
            ok = self._cond.wait_for(
                lambda: self._cell is not None
                and self._cell.version >= min_version, timeout)
            return self._cell if ok else None
