"""The in-process telemetry bus: counters, histograms, trace spans.

One :class:`Telemetry` instance rides a cluster run (created by the
runtime, shared with the server, the thread workers, and the socket
hub).  The design constraint is the hot path: ``ingest`` and the hub
reader threads call into this on *every gradient*, so every operation
is a dict update under one lock — no allocation beyond the first use
of a name, no formatting, no I/O.  Spans (for the Chrome trace export)
are only recorded when ``trace=True``; with tracing off, ``span()``
returns a shared no-op context manager and ``span_at``/``instant``
return immediately, so a tracing-disabled run does the same arithmetic
in the same order as one with the bus absent entirely — which is what
keeps sync runs bitwise-identical with tracing on or off
(regression-tested in ``tests/test_obs.py``).

Vocabulary:

  * ``count(name, n)`` — monotonic counters (``grads_ingested``,
    ``wire.rx_bytes``, ...);
  * ``gauge(name, v)`` — last-write-wins instantaneous values;
  * ``observe(name, v)`` — histogram samples (``staleness``,
    ``flush_s``, ``queue_depth``): running count/min/max/sum plus a
    capped sample buffer for percentiles;
  * ``span(track, name, **args)`` / ``span_at(...)`` /
    ``instant(...)`` — timeline events on a named track
    (``server``, ``worker/3``, ``worker/3/wire``), monotonic-clock
    relative to the bus's creation, exported by
    :mod:`repro.obs.trace`.

:data:`NULL` is the no-op singleton: components take ``obs=None`` and
fall back to it, so instrumentation is zero-cost for callers that
construct subsystems directly (tests, benchmarks, library use).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

# spans are ring-buffered: a long run keeps the most recent window
# rather than growing without bound (200k spans ~ tens of MB of JSON,
# about what a trace viewer stays responsive on)
SPAN_CAPACITY = 200_000
# histogram sample retention per name: percentiles are computed over a
# capped buffer; count/min/max/sum stay exact past the cap
HIST_CAPACITY = 65_536


class _Hist:
    __slots__ = ("count", "total", "vmin", "vmax", "samples")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None
        self.samples: List[float] = []

    def add(self, v: float) -> None:
        self.count += 1
        self.total += v
        if self.vmin is None or v < self.vmin:
            self.vmin = v
        if self.vmax is None or v > self.vmax:
            self.vmax = v
        if len(self.samples) < HIST_CAPACITY:
            self.samples.append(v)

    def stats(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        s = sorted(self.samples)

        def pct(q: float) -> float:
            if not s:
                return 0.0
            idx = min(len(s) - 1, max(0, round(q * (len(s) - 1))))
            return float(s[int(idx)])

        return {"count": self.count,
                "min": float(self.vmin), "max": float(self.vmax),
                "mean": self.total / self.count,
                "p50": pct(0.50), "p99": pct(0.99)}


class _SpanCtx:
    """Context manager recording one completed span on exit."""
    __slots__ = ("_tel", "_track", "_name", "_args", "_t0")

    def __init__(self, tel: "Telemetry", track: str, name: str,
                 args: Optional[Dict[str, Any]]):
        self._tel = tel
        self._track = track
        self._name = name
        self._args = args

    def __enter__(self) -> "_SpanCtx":
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.monotonic()
        self._tel._spans.append(
            ("X", self._track, self._name,
             self._t0 - self._tel.t0, t1 - self._t0, self._args))


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Telemetry:
    """The live bus.  Thread-safe; every mutation is O(1) under one
    lock (spans append to a lock-free deque)."""

    def __init__(self, trace: bool = False):
        self.trace = bool(trace)
        self.t0 = time.monotonic()      # span/instant time base
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Hist] = {}
        # (kind "X"|"I", track, name, t_rel_s, dur_s, args|None)
        self._spans: "collections.deque[Tuple]" = \
            collections.deque(maxlen=SPAN_CAPACITY)

    @property
    def enabled(self) -> bool:
        return True

    # ------------------------------------------------------------ metrics
    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _Hist()
            h.add(float(value))

    # ----------------------------------------------------------- timeline
    def span(self, track: str, name: str, **args) -> Any:
        """``with obs.span("worker/0", "grad_compute", version=v): ...``
        — records a complete span when tracing, a shared no-op
        otherwise."""
        if not self.trace:
            return _NULL_SPAN
        return _SpanCtx(self, track, name, args or None)

    def span_at(self, track: str, name: str, t_start: float,
                dur_s: float, **args) -> None:
        """Record an already-measured span (``t_start`` from
        ``time.monotonic()``) — for call sites that time the work
        anyway and feed the duration to a histogram too."""
        if self.trace:
            self._spans.append(("X", track, name, t_start - self.t0,
                                dur_s, args or None))

    def instant(self, track: str, name: str, **args) -> None:
        """A zero-duration timeline marker (K(t) switch, kill,
        restore, ...)."""
        if self.trace:
            self._spans.append(("I", track, name,
                                time.monotonic() - self.t0, 0.0,
                                args or None))

    # ------------------------------------------------------------ exports
    def counters(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def hist_stats(self, name: str) -> Optional[Dict[str, float]]:
        """Live percentile snapshot of one histogram (the STATS frame
        provider reads ``staleness`` here mid-run)."""
        with self._lock:
            h = self._hists.get(name)
            return h.stats() if h is not None else None

    def spans(self) -> List[Tuple]:
        return list(self._spans)

    def summary(self) -> Dict[str, Any]:
        """The structured metrics report that lands in
        ``RunResult.extra["telemetry"]``."""
        with self._lock:
            return {
                "trace": self.trace,
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.stats()
                               for k, h in sorted(self._hists.items())},
                "spans_recorded": len(self._spans),
            }


class NullTelemetry:
    """The disabled bus: every call is a no-op.  Components default to
    this when no ``obs`` is passed, so instrumentation costs nothing
    outside an observed run."""

    trace = False

    @property
    def enabled(self) -> bool:
        return False

    def count(self, name: str, n: int = 1) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def observe(self, name: str, value: float) -> None:
        pass

    def span(self, track: str, name: str, **args) -> Any:
        return _NULL_SPAN

    def span_at(self, track: str, name: str, t_start: float,
                dur_s: float, **args) -> None:
        pass

    def instant(self, track: str, name: str, **args) -> None:
        pass

    def counters(self) -> Dict[str, int]:
        return {}

    def hist_stats(self, name: str) -> Optional[Dict[str, float]]:
        return None

    def spans(self) -> List[Tuple]:
        return []

    def summary(self) -> Dict[str, Any]:
        return {"trace": False, "counters": {}, "gauges": {},
                "histograms": {}, "spans_recorded": 0}


NULL = NullTelemetry()
