"""Chrome trace-event export: a cluster run as a Perfetto waterfall.

Converts a :class:`~repro.obs.telemetry.Telemetry` span buffer into
the Chrome trace-event JSON format (the ``traceEvents`` array of
``ph: "X"`` complete events and ``ph: "i"`` instants, microsecond
timestamps) that ``chrome://tracing`` and https://ui.perfetto.dev load
directly.  Every telemetry *track* becomes one named thread row —
``server`` first, then ``worker/0``, ``worker/0/wire``, ... — so an
async→sync K(t) run reads as a timeline: per-worker ``grad_compute``
spans interleaving with the server's ``flush``/``publish`` spans, wire
``grad_rx`` spans showing backpressure waits, and instant markers for
K(t) switches, kills, and restores.

Produced by ``python -m repro run --backend cluster --trace out.json``
(or the ``python -m repro trace out.json ...`` sugar).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List


def chrome_trace(tel) -> Dict[str, Any]:
    """The trace-event document for a telemetry bus's span buffer."""
    spans = tel.spans()
    tracks = sorted({s[1] for s in spans},
                    key=lambda t: (t != "server", t))
    tid = {track: i for i, track in enumerate(tracks)}
    events: List[Dict[str, Any]] = [
        {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid[t],
         "args": {"name": t}} for t in tracks]
    events += [
        {"name": "thread_sort_index", "ph": "M", "pid": 1,
         "tid": tid[t], "args": {"sort_index": tid[t]}} for t in tracks]
    for kind, track, name, t_rel, dur, args in spans:
        ev: Dict[str, Any] = {
            "name": name, "pid": 1, "tid": tid[track],
            "ts": round(t_rel * 1e6, 3),
            "cat": track.split("/", 1)[0],
        }
        if kind == "X":
            ev["ph"] = "X"
            ev["dur"] = round(dur * 1e6, 3)
        else:
            ev["ph"] = "i"
            ev["s"] = "t"           # instant scoped to its thread row
        if args:
            ev["args"] = dict(args)
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(tel, path: str) -> int:
    """Write the trace JSON; returns the number of timeline events
    (excluding track metadata)."""
    doc = chrome_trace(tel)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return sum(1 for e in doc["traceEvents"] if e["ph"] != "M")
