"""``repro.obs`` — the telemetry plane.

One lock-cheap in-process event bus (:class:`~repro.obs.telemetry.
Telemetry`: counters / gauges / histograms always on, ring-buffered
monotonic-clock spans when tracing) threaded through the cluster
runtime, the parameter server, the workers, and the socket hubs, plus
three export surfaces:

  * :func:`~repro.obs.trace.write_chrome_trace` — Chrome
    trace-event / Perfetto JSON (``--trace out.json`` /
    ``python -m repro trace``), one track per worker / server / wire;
  * ``RunResult.extra["telemetry"]`` — the structured metrics summary
    (per-worker staleness histograms, wire bytes, queue depths, flush
    latency percentiles) cross-checked against the conservation ledger;
  * the read-only ``STATS`` wire frame + :mod:`repro.obs.top`
    (``python -m repro top HOST:PORT``) — live remote introspection of
    a running ``--listen`` leader, riding the serve-peer admission
    path (never in the barrier or the ledger).

:mod:`repro.obs.top` is imported lazily (it pulls in the cluster wire
code, which itself depends on this package).
"""
from repro.obs.telemetry import NULL, NullTelemetry, Telemetry
from repro.obs.trace import chrome_trace, write_chrome_trace

__all__ = ["NULL", "NullTelemetry", "Telemetry", "chrome_trace",
           "write_chrome_trace"]
