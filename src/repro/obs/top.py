"""``python -m repro top HOST:PORT`` — live remote run introspection.

:class:`StatsClient` rides the STATS handshake
(:func:`repro.cluster.hostlink.negotiate_stats`): it receives the
leader's WELCOME (``stats_id`` + push cadence), then a reader thread
keeps a local cell current from the hub's JSON telemetry pushes —
ledger counters, staleness percentiles, queue depth — a few hundred
bytes per tick, never a params slab.  Stats clients hold no worker-id,
never enter the fleet barrier or the conservation ledger, and the hub
never sends them the params broadcast, so attaching one to a live sync
run leaves the trained model bitwise-identical (regression-tested in
``tests/test_obs.py``).

:func:`top_main` is the CLI body: one line per push with grads/sec
computed from consecutive applied-counter deltas, staleness p50/p99,
and the live ledger columns.  A late attach is not blind: the hub's
first push is a ``{"history": [...]}`` backfill from its STATS ring
(recent ticks it recorded with zero subscribers), which seeds the rate
delta so the very first live row already has a grads/sec figure.
"""
from __future__ import annotations

import json
import sys
import threading
import time
from typing import Any, Dict, List, Optional, TextIO

from repro.cluster.mptransport import (_CTRL, _F_PING, _F_REJECT,
                                       _F_STATS, _HDR, _MAX_FRAME,
                                       _pong_frame, _recv_exact,
                                       WireProtocolError)

class StatsClient:
    """One read-only telemetry subscription to a training leader.

    ``wait_stats(timeout)`` blocks for the next *unconsumed* push (None
    on timeout / close) — pushes are coalesced into a single latest
    cell, so a slow caller skips ticks instead of queueing them.
    """

    def __init__(self, address: Any, *, connect_timeout: float = 30.0):
        from repro.cluster.hostlink import negotiate_stats
        sock, cfg = negotiate_stats(address,
                                    connect_timeout=connect_timeout)
        self.welcome: Dict[str, Any] = cfg
        self.stats_id = int(cfg.get("stats_id", -1))
        sock.settimeout(None)
        self.sock = sock
        self.closed = threading.Event()
        self.reject_reason: Optional[str] = None
        self.pushes_seen = 0
        # the hub's history-ring backfill (sent once, before the first
        # live push): past ticks, oldest first — never coalesced into
        # the live cell, so wait_stats() still only ever returns fresh
        # pushes
        self.backfill: List[Dict[str, Any]] = []
        self._cell: Optional[Dict[str, Any]] = None
        self._cell_seq = 0                  # bumps on every push
        self._taken_seq = 0                 # last seq wait_stats returned
        self._cond = threading.Condition()
        self._wlock = threading.Lock()
        self._close_lock = threading.Lock()
        self._closed_once = False
        self._reader = threading.Thread(
            target=self._read_loop, name=f"stats-reader-{self.stats_id}",
            daemon=True)
        self._reader.start()

    # ---------------------------------------------------------- threads
    def _read_loop(self) -> None:
        try:
            while not self.closed.is_set():
                hdr, _ = _recv_exact(self.sock, _HDR.size)
                if hdr is None:
                    break
                ftype, n = _HDR.unpack(hdr)
                if n > _MAX_FRAME:
                    break
                payload, _ = _recv_exact(self.sock, n)
                if payload is None:
                    break
                if ftype == _F_PING:
                    with self._wlock:
                        try:
                            self.sock.sendall(_pong_frame())
                        except OSError:
                            break
                elif ftype == _F_STATS and n > _CTRL.size:
                    try:
                        doc = json.loads(
                            payload[_CTRL.size:].decode("utf-8"))
                    except (ValueError, UnicodeDecodeError):
                        continue            # malformed tick: skip it
                    if isinstance(doc.get("history"), list):
                        # the one-shot ring backfill: keep it aside,
                        # don't wake wait_stats (it is not a live tick)
                        self.backfill = [c for c in doc["history"]
                                         if isinstance(c, dict)]
                        continue
                    with self._cond:
                        self._cell = doc
                        self._cell_seq += 1
                        self.pushes_seen += 1
                        self._cond.notify_all()
                elif ftype == _F_REJECT:
                    reason = payload[_CTRL.size:].decode(
                        "utf-8", "replace") if n >= _CTRL.size else ""
                    self.reject_reason = reason or "rejected by hub"
                    break
                # other frame types: ignored (forward compat)
        finally:
            self.close()

    def _mark_closed(self) -> None:
        self.closed.set()
        with self._cond:
            self._cond.notify_all()

    # -------------------------------------------------------------- api
    def wait_stats(self, timeout: Optional[float] = None
                   ) -> Optional[Dict[str, Any]]:
        """The next push not yet returned by this method (coalesced:
        only the latest is kept)."""
        deadline = None if timeout is None else \
            time.monotonic() + timeout
        with self._cond:
            while self._taken_seq == self._cell_seq:
                if self.closed.is_set():
                    return None
                remain = None if deadline is None else \
                    deadline - time.monotonic()
                if remain is not None and remain <= 0:
                    return None
                self._cond.wait(0.1 if remain is None
                                else min(0.1, remain))
            self._taken_seq = self._cell_seq
            return self._cell

    def close(self) -> None:
        with self._close_lock:
            if self._closed_once:
                return
            self._closed_once = True
        self._mark_closed()
        try:
            self.sock.shutdown(2)           # SHUT_RDWR
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


# ================================================================ CLI


def _fmt_line(doc: Dict[str, Any], rate: Optional[float]) -> str:
    """One `repro top` row from one stats payload."""
    if doc.get("state") == "waiting":
        return "[top] waiting: leader is up but the run has not started"
    st = doc.get("staleness") or {}
    p50 = st.get("p50")
    p99 = st.get("p99")
    stale = "stale p50/p99 -/-" if p50 is None else \
        f"stale p50/p99 {p50:.0f}/{p99:.0f}"
    rate_s = "grads/s     -" if rate is None else \
        f"grads/s {rate:7.1f}"
    return (f"[top] v{doc.get('version', 0):<6} {rate_s}  {stale}  "
            f"applied {doc.get('applied', 0):<7} "
            f"dropped {doc.get('dropped', 0):<5} "
            f"buffered {doc.get('buffered', 0):<4} "
            f"pending {doc.get('pending_round', 0):<4} "
            f"queue {doc.get('queue_depth', 0):<4} "
            f"workers {doc.get('live_workers', 0)}/"
            f"{doc.get('fleet_size', doc.get('num_workers', 0))} "
            f"serve {doc.get('serve_clients', 0)} "
            f"opt {doc.get('optimizer', 'sgd')}:"
            f"{doc.get('optimizer_steps', 0)} "
            f"[{doc.get('mode', '?')}]")


def top_main(address: str, *, count: Optional[int] = None,
             duration_s: Optional[float] = None,
             connect_timeout: float = 30.0,
             prom_port: Optional[int] = None,
             out: Optional[TextIO] = None) -> int:
    """``python -m repro top`` body: stream the leader's telemetry
    pushes as one line each until EOF / ``count`` rows /
    ``duration_s``.  With ``prom_port`` the newest push is also served
    as a Prometheus ``/metrics`` endpoint (:mod:`repro.obs.prom`) —
    the leader's telemetry re-exported by this read-only client, so a
    scraper never touches the training wire.  Exit codes: 0 ok
    (including a leader that goes away mid-watch), 4 rejected by the
    leader / unreachable."""
    out = out if out is not None else sys.stdout
    try:
        client = StatsClient(address, connect_timeout=connect_timeout)
    except WireProtocolError as e:
        print(f"top failed: {e}", file=sys.stderr, flush=True)
        return 4
    prom = None
    if prom_port is not None:
        from repro.obs.prom import PromServer
        latest: Dict[str, Any] = {}
        orig_wait = client.wait_stats

        def _wait(timeout=None):
            doc = orig_wait(timeout)
            if doc is not None:
                latest["doc"] = doc
            return doc

        client.wait_stats = _wait       # type: ignore[method-assign]
        prom = PromServer(lambda: (latest.get("doc"), None), prom_port)
        print(f"[top] prometheus metrics at {prom.url}", file=out,
              flush=True)
    try:
        print(f"[top] stats client {client.stats_id} connected to "
              f"{address} (push every "
              f"{client.welcome.get('stats_every_s', '?')}s)",
              file=out, flush=True)
        rows = 0
        prev: Optional[Dict[str, Any]] = None   # (for the rate delta)
        prev_t: Optional[float] = None
        t_start = time.monotonic()
        backfilled = False
        while count is None or rows < count:
            if duration_s is not None \
                    and time.monotonic() - t_start > duration_s:
                break
            doc = client.wait_stats(timeout=1.0)
            now = time.monotonic()
            if doc is None:
                if client.closed.is_set():
                    break
                continue
            if not backfilled:
                backfilled = True
                if client.backfill:
                    # seed the rate delta from the hub's history ring:
                    # the first live row is not blind on a late attach
                    prev = client.backfill[-1]
                    print(f"[top] backfilled {len(client.backfill)} "
                          "past tick(s) from the leader's history "
                          "ring", file=out, flush=True)
            rate = None
            if prev is not None and "applied" in doc \
                    and "applied" in prev:
                # prefer the leader's own clock ("t", carried in every
                # cell): backfilled ticks have no local receipt time
                if isinstance(doc.get("t"), (int, float)) \
                        and isinstance(prev.get("t"), (int, float)) \
                        and doc["t"] > prev["t"]:
                    rate = (doc["applied"] - prev["applied"]) \
                        / (doc["t"] - prev["t"])
                elif prev_t is not None and now > prev_t:
                    rate = (doc["applied"] - prev["applied"]) \
                        / (now - prev_t)
            print(_fmt_line(doc, rate), file=out, flush=True)
            rows += 1
            if "applied" in doc:
                prev, prev_t = doc, now
        if client.reject_reason:
            print(f"top: rejected by leader: {client.reject_reason}",
                  file=sys.stderr, flush=True)
            return 4
        if client.closed.is_set() and rows > 0:
            print("[top] leader closed the connection (run over)",
                  file=out, flush=True)
        return 0
    finally:
        if prom is not None:
            prom.close()
        client.close()
