"""Prometheus text-format exposition of the live STATS payload.

One rendering function plus a tiny stdlib HTTP server, so a live run
can be scraped by an ordinary Prometheus/Grafana stack with zero new
dependencies:

* :func:`render_prometheus` turns one STATS payload — the exact dict
  :meth:`repro.cluster.runtime.ClusterRuntime._stats_payload` pushes to
  ``repro top`` clients — into Prometheus text exposition format
  (version 0.0.4): ``repro_grads_applied_total``, staleness quantile
  gauges, fleet gauges, and (when given the telemetry counter dict)
  one ``repro_<name>_total`` counter per bus counter, e.g.
  ``repro_wire_tx_bytes_total``.

* :class:`PromServer` serves ``GET /metrics`` from a provider callable
  returning the newest payload (None → 503, scrape-friendly: Prometheus
  records the target down instead of parsing garbage).  Two mount
  points use it: the training leader itself (``repro run/serve
  --prom-port N`` — the provider is the runtime's live stats payload +
  counter snapshot) and ``repro top --prom-port N`` (the provider is
  the last STATS push received, so any box that can reach the leader's
  wire port can re-export it to Prometheus without touching the run).

The endpoint is read-only and shares no locks with the training loop
beyond what the stats-push plane already takes.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional

_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# STATS payload key -> (metric name, TYPE, HELP).  Only keys present
# (and numeric) in the payload are emitted, so older/newer payloads
# render cleanly.
_PAYLOAD_METRICS = [
    ("t", "repro_uptime_seconds", "gauge",
     "Wall-clock seconds since the run's clock started"),
    ("version", "repro_params_version", "gauge",
     "Current published params version"),
    ("applied", "repro_grads_applied_total", "counter",
     "Gradients applied to the master params"),
    ("dropped", "repro_grads_dropped_total", "counter",
     "Gradients dropped (stale beyond tolerance)"),
    ("buffered", "repro_grads_buffered", "gauge",
     "Gradients held in the staging buffer"),
    ("pending_round", "repro_grads_pending_round", "gauge",
     "Gradients of the current unfinished sync round"),
    ("updates", "repro_updates_total",
     "counter", "Optimizer updates (flushes) performed"),
    ("optimizer_steps", "repro_optimizer_steps_total", "counter",
     "Fused flush+optimizer steps applied on the params slab"),
    ("queue_depth", "repro_queue_depth", "gauge",
     "Gradients waiting in the transport channel"),
    ("live_workers", "repro_live_workers", "gauge",
     "Workers currently registered with the server"),
    ("num_workers", "repro_seed_workers", "gauge",
     "Seed fleet size (cluster_workers)"),
    ("fleet_size", "repro_fleet_size", "gauge",
     "Current fleet size (seed + elastic admissions)"),
    ("max_workers", "repro_max_workers", "gauge",
     "Elastic admission ceiling"),
    ("serve_clients", "repro_serve_clients", "gauge",
     "Connected read-only serve subscribers"),
]


def _sanitize(name: str) -> str:
    """Telemetry counter name -> metric-name fragment (dots and every
    other non-alphanumeric become underscores)."""
    return "".join(c if c.isalnum() else "_" for c in name)


def render_prometheus(doc: Optional[Dict[str, Any]],
                      counters: Optional[Dict[str, int]] = None) -> str:
    """One STATS payload (+ optional telemetry counter snapshot) as
    Prometheus text exposition format."""
    lines = []
    doc = doc or {}
    emitted = set()
    for key, metric, mtype, hlp in _PAYLOAD_METRICS:
        v = doc.get(key)
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            continue
        emitted.add(metric)
        lines.append(f"# HELP {metric} {hlp}")
        lines.append(f"# TYPE {metric} {mtype}")
        lines.append(f"{metric} {v}")
    st = doc.get("staleness")
    if isinstance(st, dict):
        rows = [(q, st.get(p)) for q, p in (("0.5", "p50"),
                                            ("0.99", "p99"))
                if isinstance(st.get(p), (int, float))]
        if rows:
            lines.append("# HELP repro_staleness_versions Gradient "
                         "staleness in params versions")
            lines.append("# TYPE repro_staleness_versions gauge")
            for q, v in rows:
                lines.append('repro_staleness_versions{quantile="'
                             f'{q}"}} {v}')
    if isinstance(doc.get("mode"), str):
        labels = [f'mode="{doc["mode"]}"']
        if isinstance(doc.get("optimizer"), str):
            labels.append(f'optimizer="{doc["optimizer"]}"')
        lines.append("# HELP repro_run_info Run mode/optimizer as labels")
        lines.append("# TYPE repro_run_info gauge")
        lines.append(f'repro_run_info{{{",".join(labels)}}} 1')
    for name in sorted(counters or {}):
        v = counters[name]
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            continue
        metric = f"repro_{_sanitize(name)}_total"
        if metric in emitted:
            # already rendered from the STATS payload (e.g.
            # optimizer_steps): a second series with the same name
            # would be an invalid exposition
            continue
        lines.append(f"# HELP {metric} Telemetry counter {name}")
        lines.append(f"# TYPE {metric} counter")
        lines.append(f"{metric} {v}")
    return "\n".join(lines) + "\n"


class PromServer:
    """A `/metrics` endpoint over a payload provider.

    ``provider()`` is called per scrape and must return
    ``(stats_payload, counters)`` — either may be None.  Runs its own
    daemon threads (stdlib ThreadingHTTPServer); :meth:`close` is
    idempotent.  ``port=0`` picks an ephemeral port; the resolved one
    is on :attr:`port` after construction.
    """

    def __init__(self, provider: Callable[[], tuple], port: int,
                 host: str = "0.0.0.0"):
        self._provider = provider
        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):              # noqa: N802 (stdlib casing)
                if self.path.split("?", 1)[0] not in ("/metrics", "/"):
                    self.send_error(404)
                    return
                try:
                    doc, counters = outer._provider()
                except Exception:          # a dying run must not 500-loop
                    doc, counters = None, None
                if doc is None and not counters:
                    self.send_response(503)
                    self.send_header("Retry-After", "1")
                    self.end_headers()
                    return
                body = render_prometheus(doc, counters).encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", _CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):      # scrapes are not log lines
                pass

        self._httpd = ThreadingHTTPServer((host, int(port)), _Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._closed = False
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="prom-server",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)
