"""Pallas TPU kernel: hybrid gradient-buffer flush.

The Smooth Switch flush aggregates K buffered gradient slabs into one
update with staleness weights (repro.core.buffer.aggregate_flush).  On TPU
this is a memory-bound fused weighted reduction:

    out[p] = Σ_k w[k] · g[k, p]      (+ optional fused momentum update)

Reading K gradient copies from HBM once and writing one slab keeps the op
at the HBM roofline instead of K separate axpy passes (K× fewer output
writes, no intermediate slabs).  Tiling: the parameter dimension is tiled
in (8, 128)-aligned VMEM blocks; the K axis stays resident per tile.

Layout: gradients are flattened & concatenated to (K, P); P is padded to
the tile size by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_P = 8 * 128 * 8          # parameter elements per tile (VMEM-sized)


def _flush_kernel(w_ref, g_ref, o_ref):
    """w: (K, 1) fp32 in SMEM-ish VMEM; g: (K, TILE_P); o: (TILE_P,)."""
    g = g_ref[...].astype(jnp.float32)            # (K, tile)
    w = w_ref[...].astype(jnp.float32)            # (K, 1)
    o_ref[...] = jnp.sum(g * w, axis=0).astype(o_ref.dtype)


def flush_pallas(grads: jax.Array, weights: jax.Array, *,
                 tile_p: int = TILE_P, interpret: bool = False) -> jax.Array:
    """grads: (K, P) with P % tile_p == 0; weights: (K,) fp32 (normalized
    by the caller).  Returns (P,) weighted sum in grads.dtype."""
    K, P = grads.shape
    assert P % tile_p == 0, (P, tile_p)
    w2 = weights.reshape(K, 1).astype(jnp.float32)
    return pl.pallas_call(
        _flush_kernel,
        grid=(P // tile_p,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, tile_p), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), grads.dtype),
        interpret=interpret,
    )(w2, grads)


def flush_pallas_sharded(grad_chunks, weights: jax.Array, *,
                         tile_p: int = TILE_P,
                         interpret: bool = False):
    """Sharded flush entry point: ``grad_chunks`` is a sequence of
    ``(K, P_i)`` staging chunks (each ``P_i % tile_p == 0`` — the
    tile-aligned P-split of one ``(K, P)`` slab, see
    :func:`repro.core.slab.shard_chunks`).  Each chunk is reduced by its
    own :func:`flush_pallas` call, so under ``jax.jit`` a fleet of
    equal-shaped chunks shares **one** compiled executable per distinct
    chunk shape — the single-donated-executable property, per chunk.
    The reduction is elementwise along P, so the concatenated result is
    bitwise identical to an unsharded flush of the whole slab."""
    return [flush_pallas(g, weights, tile_p=tile_p, interpret=interpret)
            for g in grad_chunks]


def _flush_momentum_kernel(w_ref, beta_ref, g_ref, m_ref, o_ref, new_m_ref):
    """Fused flush + momentum: m' = β·m + Σ w·g ; out = m'."""
    g = g_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    beta = beta_ref[0]
    agg = jnp.sum(g * w, axis=0)
    m_new = beta * m_ref[...].astype(jnp.float32) + agg
    new_m_ref[...] = m_new.astype(new_m_ref.dtype)
    o_ref[...] = m_new.astype(o_ref.dtype)


def flush_momentum_pallas(grads: jax.Array, weights: jax.Array,
                          momentum: jax.Array, beta: float, *,
                          tile_p: int = TILE_P,
                          interpret: bool = False):
    """Fused flush+momentum.  Returns (update, new_momentum)."""
    K, P = grads.shape
    assert P % tile_p == 0
    w2 = weights.reshape(K, 1).astype(jnp.float32)
    beta_arr = jnp.full((1,), beta, jnp.float32)
    return pl.pallas_call(
        _flush_momentum_kernel,
        grid=(P // tile_p,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((K, tile_p), lambda i: (0, i)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_p,), lambda i: (i,)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P,), grads.dtype),
            jax.ShapeDtypeStruct((P,), momentum.dtype),
        ],
        interpret=interpret,
    )(w2, beta_arr, grads, momentum)


def _flush_adamw_kernel(w_ref, h_ref, g_ref, p_ref, m_ref, v_ref,
                        new_p_ref, new_m_ref, new_v_ref, *,
                        b1, b2, eps, weight_decay):
    """Fused flush + AdamW step, one HBM pass per tile.

    ``w`` is pre-normalized (the reduction yields the *mean* gradient);
    ``h = (bc1, bc2, scale)`` carries the traced scalars — the bias
    corrections ``1 - b^count`` (count-dependent, so they can't be
    baked static) and the learning-rate scale."""
    g = g_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    bc1, bc2, scale = h_ref[0], h_ref[1], h_ref[2]
    mean_g = jnp.sum(g * w, axis=0)
    m_new = b1 * m_ref[...].astype(jnp.float32) + (1 - b1) * mean_g
    v_new = b2 * v_ref[...].astype(jnp.float32) \
        + (1 - b2) * mean_g * mean_g
    p = p_ref[...].astype(jnp.float32)
    upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps) \
        + weight_decay * p
    new_p_ref[...] = (p - scale * upd).astype(new_p_ref.dtype)
    new_m_ref[...] = m_new.astype(new_m_ref.dtype)
    new_v_ref[...] = v_new.astype(new_v_ref.dtype)


def flush_adamw_pallas(grads: jax.Array, weights: jax.Array,
                       params: jax.Array, mu: jax.Array, nu: jax.Array,
                       bc1, bc2, scale, *, b1: float, b2: float,
                       eps: float, weight_decay: float,
                       tile_p: int = TILE_P, interpret: bool = False):
    """Fused flush+AdamW.  Returns (new_params, new_mu, new_nu) — the
    moments stay in ``mu``/``nu``'s dtype (f32 on the slab path)."""
    K, P = grads.shape
    assert P % tile_p == 0
    w2 = weights.reshape(K, 1).astype(jnp.float32)
    h = jnp.stack([jnp.asarray(bc1, jnp.float32),
                   jnp.asarray(bc2, jnp.float32),
                   jnp.asarray(scale, jnp.float32)])
    kern = functools.partial(_flush_adamw_kernel, b1=b1, b2=b2, eps=eps,
                             weight_decay=weight_decay)
    return pl.pallas_call(
        kern,
        grid=(P // tile_p,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((K, tile_p), lambda i: (0, i)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_p,), lambda i: (i,)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P,), params.dtype),
            jax.ShapeDtypeStruct((P,), mu.dtype),
            jax.ShapeDtypeStruct((P,), nu.dtype),
        ],
        interpret=interpret,
    )(w2, h, grads, params, mu, nu)
