"""Pallas TPU kernel: fused RMSNorm.

Memory-bound: one HBM read of x, one write of y, with the fp32 variance
reduction and scale fused in VMEM (vs. the unfused version's extra
round-trips for square/mean/rsqrt intermediates).  Rows are tiled in
blocks of `block_rows`; the feature dim stays whole (d_model ≤ 8192 rows
fit VMEM comfortably at (256, 8192)·4B ≈ 8 MiB).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, eps: float = 1e-5, *,
                   block_rows: int = 256, interpret: bool = False):
    """x: (N, D) — callers flatten leading dims; scale: (D,)."""
    N, D = x.shape
    assert N % block_rows == 0, (N, block_rows)
    return pl.pallas_call(
        lambda x_ref, s_ref, o_ref: _rmsnorm_kernel(x_ref, s_ref, o_ref,
                                                    eps=eps),
        grid=(N // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, scale)
