"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) Pallas lowers only in interpret mode, so every op
takes `interpret=None` → auto (interpret iff not on TPU).  `use_pallas=
False` falls back to the jnp reference — the default for the dry-run,
where the TPU kernels are represented by their XLA-fused references.
"""
from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hybrid_aggregate import (flush_adamw_pallas,
                                            flush_momentum_pallas,
                                            flush_pallas,
                                            flush_pallas_sharded, TILE_P)
from repro.kernels.rmsnorm import rmsnorm_pallas


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------ flat utils
# Thin wrappers over the slab codec (repro.core.slab) — the canonical
# pytree ⇄ tile-aligned-slab layout shared by the cluster transport, the
# simulator, and these kernels.

def tree_to_flat(grads_trees: List) -> jax.Array:
    """Stack K gradient pytrees into a (K, P_padded) slab matrix (P
    padded to the kernel tile; repro.core.slab layout).  The slab wire
    dtype is float32: narrower float leaves (bf16/f16) are widened, and
    the codec rejects integer or wider-than-32-bit leaves."""
    from repro.core.slab import slab_codec
    codec = slab_codec(grads_trees[0])
    return jnp.stack([codec.encode(t) for t in grads_trees])


def flat_to_tree(flat: jax.Array, like) -> object:
    """Decode one f32 slab back into ``like``'s structure (leaves cast
    back to their template dtypes — exact for <= 32-bit floats)."""
    from repro.core.slab import slab_codec
    return slab_codec(like).decode(flat)


# ------------------------------------------------------------------- ops

@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def hybrid_flush(grads: jax.Array, weights: jax.Array, *,
                 use_pallas: bool = True,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Weighted aggregation of K flattened gradient slabs: (K,P),(K)->(P)."""
    if not use_pallas:
        return ref.flush_ref(grads, weights)
    return flush_pallas(grads, weights,
                        interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def hybrid_flush_sharded(grad_chunks, weights: jax.Array, *,
                         use_pallas: bool = True,
                         interpret: Optional[bool] = None):
    """Sharded weighted aggregation: a tuple/list of (K, P_i) staging
    chunks (the tile-aligned P-split of one (K, P) slab) -> a list of
    (P_i,) reduced chunks.  Per-chunk reduction keeps one compiled
    executable per distinct chunk shape; concatenating the outputs is
    bitwise identical to :func:`hybrid_flush` on the unsplit slab."""
    if not use_pallas:
        return [ref.flush_ref(g, weights) for g in grad_chunks]
    return flush_pallas_sharded(grad_chunks, weights,
                                interpret=_auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("beta", "use_pallas", "interpret"))
def hybrid_flush_momentum(grads, weights, momentum, beta: float, *,
                          use_pallas: bool = True,
                          interpret: Optional[bool] = None):
    if not use_pallas:
        return ref.flush_momentum_ref(grads, weights, momentum, beta)
    return flush_momentum_pallas(grads, weights, momentum, beta,
                                 interpret=_auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "weight_decay",
                                    "use_pallas", "interpret"))
def hybrid_flush_adamw(grads, weights, params, mu, nu, bc1, bc2, scale,
                       *, b1: float, b2: float, eps: float,
                       weight_decay: float, use_pallas: bool = True,
                       interpret: Optional[bool] = None):
    """Fused aggregate + AdamW step: (K,P) staging rows + pre-normalized
    weights + f32 param/moment slabs -> (new_params, new_mu, new_nu).
    ``bc1``/``bc2`` are traced bias corrections (``1 - b^count``)."""
    if not use_pallas:
        return ref.flush_adamw_ref(grads, weights, params, mu, nu,
                                   bc1, bc2, scale, b1=b1, b2=b2,
                                   eps=eps, weight_decay=weight_decay)
    return flush_adamw_pallas(grads, weights, params, mu, nu, bc1, bc2,
                              scale, b1=b1, b2=b2, eps=eps,
                              weight_decay=weight_decay,
                              interpret=_auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("eps", "use_pallas", "interpret",
                                    "block_rows"))
def rmsnorm(x, scale, eps: float = 1e-5, *, use_pallas: bool = True,
            block_rows: int = 256, interpret: Optional[bool] = None):
    """x: (..., D)."""
    if not use_pallas:
        return ref.rmsnorm_ref(x, scale, eps)
    lead = x.shape[:-1]
    D = x.shape[-1]
    flat = x.reshape(-1, D)
    N = flat.shape[0]
    rows = min(block_rows, N)
    while N % rows:
        rows //= 2
    y = rmsnorm_pallas(flat, scale, eps, block_rows=max(rows, 1),
                       interpret=_auto_interpret(interpret))
    return y.reshape(*lead, D)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "q_block",
                                    "kv_block", "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_block: int = 128,
                    kv_block: int = 128, use_pallas: bool = True,
                    interpret: Optional[bool] = None):
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, interpret=_auto_interpret(interpret))
