"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) Pallas lowers only in interpret mode, so every op
takes `interpret=None` → auto (interpret iff not on TPU).  `use_pallas=
False` falls back to the jnp reference — the default for the dry-run,
where the TPU kernels are represented by their XLA-fused references.
"""
from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hybrid_aggregate import (flush_momentum_pallas,
                                            flush_pallas, TILE_P)
from repro.kernels.rmsnorm import rmsnorm_pallas


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------ flat utils

def tree_to_flat(grads_trees: List) -> jax.Array:
    """Stack K gradient pytrees into a (K, P_padded) matrix (P padded to
    the kernel tile)."""
    flats = []
    for tree in grads_trees:
        leaves = [jnp.ravel(x) for x in jax.tree.leaves(tree)]
        flats.append(jnp.concatenate(leaves))
    mat = jnp.stack(flats)
    P = mat.shape[1]
    pad = (-P) % TILE_P
    if pad:
        mat = jnp.pad(mat, ((0, 0), (0, pad)))
    return mat


def flat_to_tree(flat: jax.Array, like) -> object:
    leaves = jax.tree.leaves(like)
    out, off = [], 0
    for leaf in leaves:
        n = leaf.size
        out.append(flat[off:off + n].reshape(leaf.shape).astype(leaf.dtype))
        off += n
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), out)


# ------------------------------------------------------------------- ops

@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def hybrid_flush(grads: jax.Array, weights: jax.Array, *,
                 use_pallas: bool = True,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Weighted aggregation of K flattened gradient slabs: (K,P),(K)->(P)."""
    if not use_pallas:
        return ref.flush_ref(grads, weights)
    return flush_pallas(grads, weights,
                        interpret=_auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("beta", "use_pallas", "interpret"))
def hybrid_flush_momentum(grads, weights, momentum, beta: float, *,
                          use_pallas: bool = True,
                          interpret: Optional[bool] = None):
    if not use_pallas:
        return ref.flush_momentum_ref(grads, weights, momentum, beta)
    return flush_momentum_pallas(grads, weights, momentum, beta,
                                 interpret=_auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("eps", "use_pallas", "interpret",
                                    "block_rows"))
def rmsnorm(x, scale, eps: float = 1e-5, *, use_pallas: bool = True,
            block_rows: int = 256, interpret: Optional[bool] = None):
    """x: (..., D)."""
    if not use_pallas:
        return ref.rmsnorm_ref(x, scale, eps)
    lead = x.shape[:-1]
    D = x.shape[-1]
    flat = x.reshape(-1, D)
    N = flat.shape[0]
    rows = min(block_rows, N)
    while N % rows:
        rows //= 2
    y = rmsnorm_pallas(flat, scale, eps, block_rows=max(rows, 1),
                       interpret=_auto_interpret(interpret))
    return y.reshape(*lead, D)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "q_block",
                                    "kv_block", "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_block: int = 128,
                    kv_block: int = 128, use_pallas: bool = True,
                    interpret: Optional[bool] = None):
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, interpret=_auto_interpret(interpret))
