"""Pallas TPU kernel: blockwise online-softmax attention (forward).

The prefill hot-spot.  Classic FlashAttention adapted to TPU: the MXU
wants 128-aligned (q_block × kv_block) matmul tiles, fp32 accumulators
live in VMEM scratch, and the kv axis is the *innermost sequential* grid
dimension so the (m, l, acc) online-softmax state carries across kv
blocks without HBM traffic.  GQA folds the query-head → kv-head mapping
into the BlockSpec index maps (no kv replication in HBM).  Causal and
sliding-window masks are applied per tile.

Memory: O(q_block · kv_block) scores per step instead of O(S²);
VMEM per step ≈ (qb·d + kb·d + qb·kb + qb·d) · 4B ≈ 0.5 MiB at 128/128/128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  q_block: int, kv_block: int, num_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # (qb, d)
    k = k_ref[0].astype(jnp.float32)                    # (kb, d)
    v = v_ref[0].astype(jnp.float32)                    # (kb, d)

    s = jnp.dot(q, k.T)                                 # (qb, kb)
    q_pos = iq * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 0)
    k_pos = ik * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                     (q_block, kv_block), 1)
    mask = jnp.ones((q_block, kv_block), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (qb, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m_new == NEG_INF): keep exp at 0
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    alpha = jnp.where(m_prev == NEG_INF, 0.0, alpha)

    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _final():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           q_block: int = 128, kv_block: int = 128,
                           scale: Optional[float] = None,
                           interpret: bool = False):
    """q: (B, S, H, d); k/v: (B, S, KV, d); GQA via H % KV == 0.

    Returns (B, S, H, d).  Forward only (inference prefill path; training
    uses the jnp rowblock reference which XLA differentiates).
    """
    B, S, H, d = q.shape
    KV = k.shape[2]
    assert H % KV == 0
    G = H // KV
    assert S % q_block == 0 and S % kv_block == 0
    nq, nk = S // q_block, S // kv_block
    scale = scale if scale is not None else d ** -0.5

    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, d)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * KV, S, d)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * KV, S, d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, kv_block, d),
                         lambda bh, iq, ik, G=G: (bh // G, ik, 0)),
            pl.BlockSpec((1, kv_block, d),
                         lambda bh, iq, ik, G=G: (bh // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(B, H, S, d), 1, 2)
