"""Pure-jnp oracles for every Pallas kernel (the dry-run/training path on
CPU and the allclose reference in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flush_ref(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """grads (K, P), weights (K,) -> (P,) weighted sum."""
    return jnp.einsum("kp,k->p", grads.astype(jnp.float32),
                      weights.astype(jnp.float32)).astype(grads.dtype)


def flush_momentum_ref(grads, weights, momentum, beta: float):
    agg = jnp.einsum("kp,k->p", grads.astype(jnp.float32),
                     weights.astype(jnp.float32))
    m_new = beta * momentum.astype(jnp.float32) + agg
    return m_new.astype(grads.dtype), m_new.astype(momentum.dtype)


def flush_adamw_ref(grads, weights, params, mu, nu, bc1, bc2, scale, *,
                    b1: float, b2: float, eps: float, weight_decay: float):
    """Fused flush + AdamW oracle.  ``weights`` are pre-normalized (the
    weighted sum IS the mean gradient); ``bc1``/``bc2`` are the bias
    corrections ``1 - b^count`` computed by the caller from the int32
    update count.  Returns ``(new_params, new_mu, new_nu)`` — all f32."""
    g = jnp.einsum("kp,k->p", grads.astype(jnp.float32),
                   weights.astype(jnp.float32))
    m_new = b1 * mu.astype(jnp.float32) + (1 - b1) * g
    v_new = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g)
    p = params.astype(jnp.float32)
    upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps) + weight_decay * p
    return p - scale * upd, m_new, v_new


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None):
    """q (B,S,H,d), k/v (B,S,KV,d) -> (B,S,H,d).  Naive fp32 softmax."""
    B, S, H, d = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(B, S, KV, G, d).astype(jnp.float32) * scale
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[None, None, None, :, None], p, 0.0)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, d).astype(q.dtype)
