"""``python -m repro`` — dispatching CLI (see repro.api.cli).

Multi-host quickstart: ``python -m repro serve --listen HOST:PORT``
starts a cluster leader; ``python -m repro join HOST:PORT`` joins it as
a worker from any machine with this package installed (the experiment
spec travels over the wire — see repro.cluster.hostlink).
"""
import sys

if __name__ == "__main__":
    argv = sys.argv[1:]
    if argv and argv[0] == "dryrun":
        # Importing repro.api pulls in jax, after which the device
        # topology is frozen — force the dry-run's 512 host devices
        # first (repro.launch._xla_env is jax-free).
        from repro.launch._xla_env import force_host_device_count
        force_host_device_count()
    from repro.api.cli import main
    sys.exit(main(argv))
