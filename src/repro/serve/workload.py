"""Serving-plane workloads: a tiny trainable LM + inference adapters.

Two pieces live here:

* ``lm-tiny`` — a genuinely *generative* sim workload (registered in
  :mod:`repro.api.trainers` under that name): a 2-layer attention+MLP
  decoder from the shared model stack (:mod:`repro.models.model`) small
  enough to train on CPU in seconds, with float32 params so it rides
  the pinned ``<f4`` slab wire unchanged.  The synthetic task is
  next-symbol succession (``label = (token + 1) mod V``): learnable by
  the embedding/head alone, so the loss drops within a handful of
  gradients and a serve client can watch generations improve across
  param versions.

* **Inference adapters** — what a serve client *does* with a decoded
  params snapshot.  :func:`build_infer_adapter` returns an object with
  the tiny contract the client loop needs: ``codec`` (the slab codec
  matching the training leader's params layout), ``decode(slab)`` and
  ``run(params, i) -> dict``.  ``lm-tiny`` gets real greedy generation
  (:func:`repro.launch.serve.greedy_generate`, sharing its per-config
  jitted decode cache); the classifier workloads (``mlp``/``cnn-*``)
  get a forward-pass probe — a jitted loss on a fixed held-out batch —
  so ``repro infer`` works against any registered arch.
"""
from __future__ import annotations

import numpy as np

from repro.models.config import ATTN, MLP, ModelConfig, uniform_pattern

LM_TINY_SEQ = 16


def lm_tiny_config() -> ModelConfig:
    """The serving demo's model: small enough that init + one forward
    compile in seconds on CPU, float32 so the params round-trip the
    slab wire bitwise."""
    return ModelConfig(
        name="lm-tiny", arch_type="dense", d_model=64, vocab_size=128,
        block_pattern=uniform_pattern(ATTN, MLP, 2), num_groups=1,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        tie_embeddings=True, dtype="float32", param_dtype="float32",
        remat="none", source="repro.serve")


def _lm_tiny_data(seed: int, n: int, seq: int, vocab: int):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, vocab, (n, seq)).astype(np.int32)
    y = ((x + 1) % vocab).astype(np.int32)
    n_test = max(1, n // 8)
    return (x[n_test:], y[n_test:], x[:n_test], y[:n_test])


def lm_tiny_workload(spec):
    """``SIM_WORKLOADS`` builder: ``(loss_fn, init_params, data,
    accuracy_fn)`` with the shared registry contract — ``loss_fn(p, x,
    y)`` scalar, data = ``(x_tr, y_tr, x_te, y_te)``."""
    import jax
    import jax.numpy as jnp

    from repro.models import model as M

    cfg = lm_tiny_config()
    n = 512 if spec.smoke else 4_096
    x_tr, y_tr, x_te, y_te = _lm_tiny_data(spec.seed, n, LM_TINY_SEQ,
                                           cfg.vocab_size)
    params = M.init_params(jax.random.PRNGKey(spec.seed), cfg)

    def loss(p, x, y):
        return M.loss_fn(p, {"tokens": x, "labels": y}, cfg)[0]

    def _acc(p, x, y):
        logits, _ = M.forward(p, {"tokens": x}, cfg)
        preds = jnp.argmax(logits, axis=-1)
        return jnp.mean((preds == y).astype(jnp.float32))

    return loss, params, (x_tr, y_tr, x_te, y_te), jax.jit(_acc)


# ----------------------------------------------------------- adapters


class LMAdapter:
    """Greedy generation against pushed params (``lm-tiny``)."""

    kind = "lm"

    def __init__(self, spec, *, batch: int = 2, prompt_len: int = 8,
                 gen_len: int = 8):
        import jax

        from repro.core.slab import slab_codec
        from repro.models import model as M

        self.cfg = lm_tiny_config()
        template = M.init_params(jax.random.PRNGKey(spec.seed), self.cfg)
        self.codec = slab_codec(template,
                                getattr(spec, "slab_dtype", "f32"))
        rng = np.random.default_rng(spec.seed)
        self.prompts = rng.integers(
            0, self.cfg.vocab_size, (batch, prompt_len)).astype(np.int32)
        self.gen_len = int(gen_len)

    def decode(self, slab):
        return self.codec.decode(slab)

    def run(self, params, i: int):
        from repro.launch.serve import greedy_generate
        out = greedy_generate(self.cfg, params, self.prompts,
                              self.gen_len)
        return {"tokens": out[0, -self.gen_len:].tolist(),
                "n": int(self.prompts.shape[0]) * self.gen_len}

    def summary(self, out) -> str:
        return f"generated tokens {out['tokens']}"


class ProbeAdapter:
    """Forward-pass probe for the classifier workloads: a jitted loss
    on one fixed held-out batch — the arch-agnostic 'inference' a serve
    client can run against any registered sim workload."""

    kind = "probe"

    def __init__(self, spec, *, batch: int = 64):
        import jax

        from repro.api.trainers import SIM_WORKLOADS
        from repro.core.slab import slab_codec

        loss, template, data, _ = SIM_WORKLOADS[spec.arch](spec)
        x_te, y_te = data[2], data[3]
        self.codec = slab_codec(template,
                                getattr(spec, "slab_dtype", "f32"))
        self._probe = (x_te[:batch], y_te[:batch])
        self._loss = jax.jit(loss)

    def decode(self, slab):
        return self.codec.decode(slab)

    def run(self, params, i: int):
        xb, yb = self._probe
        return {"probe_loss": float(self._loss(params, xb, yb)),
                "n": int(xb.shape[0])}

    def summary(self, out) -> str:
        return f"probe loss {out['probe_loss']:.4f}"


def build_infer_adapter(spec, *, batch: int = 2, prompt_len: int = 8,
                        gen_len: int = 8):
    """The serve client's inference engine for ``spec.arch``:
    generation for ``lm-tiny``, a forward-pass probe otherwise."""
    if spec.arch == "lm-tiny":
        return LMAdapter(spec, batch=batch, prompt_len=prompt_len,
                         gen_len=gen_len)
    return ProbeAdapter(spec, batch=max(batch, 64))
