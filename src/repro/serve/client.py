"""The read-only serve client: subscribe to a training leader's params.

:class:`ServeClient` rides the SERVE handshake
(:func:`repro.cluster.hostlink.negotiate_serve`): it receives the
leader's WELCOME (spec + ``serve_id`` + heartbeat cadence), then a
reader thread keeps a local versioned cell current from the coalesced
PARAMS broadcast — the same broadcast-cell semantics as a worker's
``fetch_params``, minus everything trainer-shaped: no worker id, no
gradients, no seat in the fleet barrier.  PINGs are answered with
PONGs, and a hung leader (no frames at all for several heartbeat
periods) trips the watchdog: :attr:`stall_reason` is set with a
readable error and the client closes instead of waiting forever.

:func:`infer_main` is the body of ``python -m repro infer HOST:PORT``:
connect, rebuild the inference workload from the wire spec
(:func:`repro.serve.workload.build_infer_adapter`), and run requests
against each freshly pushed params version, reporting per-request
param version and latency.
"""
from __future__ import annotations

import logging
import os
import subprocess
import sys
import threading
import time
from typing import Any, Dict, List, Optional

from repro.cluster.mptransport import (_CTRL, _F_PARAMS, _F_PING,
                                       _F_REJECT, _HDR, _MAX_FRAME,
                                       _PARAMS, _SLAB_ITEMSIZE,
                                       _pong_frame, _recv_exact,
                                       _slab_from_payload)
from repro.cluster.transport import ParamsMsg

_log = logging.getLogger("repro.serve")


class ServeClient:
    """One read-only subscription to a training leader's params.

    ``wait_params(min_version, timeout)`` blocks for the newest pushed
    snapshot at or above ``min_version`` (None on timeout / close) —
    coalesced, so a slow caller skips versions instead of queueing
    them.  :attr:`versions_seen` records every version the leader
    pushed here, in arrival order (the monotonicity conformance tests
    read it).  ``heartbeat_timeout_s=None`` sizes the hung-leader
    watchdog from the leader's announced cadence; 0 disables it.
    """

    def __init__(self, address: Any, *, connect_timeout: float = 30.0,
                 heartbeat_timeout_s: Optional[float] = None):
        from repro.cluster.hostlink import negotiate_serve
        sock, cfg = negotiate_serve(address,
                                    connect_timeout=connect_timeout)
        self.welcome: Dict[str, Any] = cfg
        self.serve_id = int(cfg.get("serve_id", -1))
        # the run's slab dtype rides the WELCOME spec: the leader
        # pushes the params broadcast to serve subscribers in it
        self.slab_dtype = str((cfg.get("spec") or {})
                              .get("slab_dtype") or "f32")
        hb = float(cfg.get("heartbeat_s") or 0.0)
        if heartbeat_timeout_s is None:
            heartbeat_timeout_s = max(10.0, 5.0 * hb) if hb > 0 else 0.0
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        sock.settimeout(None)
        self.sock = sock
        self.closed = threading.Event()
        self.reject_reason: Optional[str] = None
        self.stall_reason: Optional[str] = None
        self.versions_seen: List[int] = []
        self._cell: Optional[ParamsMsg] = None
        self._cond = threading.Condition()
        self._wlock = threading.Lock()
        self._close_lock = threading.Lock()
        self._closed_once = False
        self._last_rx = time.monotonic()
        self._reader = threading.Thread(
            target=self._read_loop, name=f"serve-reader-{self.serve_id}",
            daemon=True)
        self._reader.start()
        if self.heartbeat_timeout_s > 0:
            threading.Thread(
                target=self._watchdog_loop,
                name=f"serve-watchdog-{self.serve_id}",
                daemon=True).start()

    # ---------------------------------------------------------- threads
    def _read_loop(self) -> None:
        try:
            while not self.closed.is_set():
                hdr, _ = _recv_exact(self.sock, _HDR.size)
                if hdr is None:
                    break
                ftype, n = _HDR.unpack(hdr)
                if n > _MAX_FRAME:
                    break
                payload, _ = _recv_exact(self.sock, n)
                if payload is None:
                    break
                self._last_rx = time.monotonic()
                if ftype == _F_PING:
                    with self._wlock:
                        try:
                            self.sock.sendall(_pong_frame())
                        except OSError:
                            break
                elif ftype == _F_PARAMS and n >= _PARAMS.size \
                        and (n - _PARAMS.size) \
                        % _SLAB_ITEMSIZE[self.slab_dtype] == 0:
                    version, epoch = _PARAMS.unpack(
                        payload[:_PARAMS.size])
                    slab = _slab_from_payload(payload, _PARAMS.size,
                                              self.slab_dtype)
                    with self._cond:
                        self._cell = ParamsMsg(version, slab,
                                               epoch=epoch)
                        self.versions_seen.append(version)
                        self._cond.notify_all()
                elif ftype == _F_REJECT:
                    reason = payload[_CTRL.size:].decode(
                        "utf-8", "replace") if n >= _CTRL.size else ""
                    self.reject_reason = reason or "rejected by hub"
                    _log.warning("hub rejected serve client %d: %s",
                                 self.serve_id, self.reject_reason)
                    break
                # other frame types: ignored (forward compat)
        finally:
            # full close, not just the event: leave no half-open socket
            # for the leader's reader to wait on
            self.close()

    def _watchdog_loop(self) -> None:
        timeout = self.heartbeat_timeout_s
        while not self.closed.wait(min(timeout / 4.0, 1.0)):
            idle = time.monotonic() - self._last_rx
            if idle > timeout:
                self.stall_reason = (
                    f"no frames from the leader for {idle:.1f}s "
                    f"(liveness timeout {timeout:.1f}s) — the leader "
                    "looks hung; giving up on this connection")
                _log.warning("serve client %d: %s", self.serve_id,
                             self.stall_reason)
                self.close()
                return

    def _mark_closed(self) -> None:
        self.closed.set()
        with self._cond:
            self._cond.notify_all()

    # -------------------------------------------------------------- api
    @property
    def spec_dict(self) -> Optional[Dict[str, Any]]:
        return self.welcome.get("spec")

    def wait_params(self, min_version: int = 0,
                    timeout: Optional[float] = None
                    ) -> Optional[ParamsMsg]:
        def ok() -> bool:
            return (self._cell is not None
                    and self._cell.version >= min_version)
        with self._cond:
            if timeout is not None and timeout <= 0:
                return self._cell if ok() else None
            deadline = None if timeout is None else \
                time.monotonic() + timeout
            while not ok():
                if self.closed.is_set():
                    return None
                remain = None if deadline is None else \
                    deadline - time.monotonic()
                if remain is not None and remain <= 0:
                    return None
                self._cond.wait(0.1 if remain is None
                                else min(0.1, remain))
            return self._cell

    def close(self) -> None:
        with self._close_lock:
            if self._closed_once:
                return
            self._closed_once = True
        self._mark_closed()
        try:
            self.sock.shutdown(2)           # SHUT_RDWR
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


# ============================================================= infer CLI


def infer_main(address: str, *, requests: int = 8,
               duration_s: Optional[float] = None, batch: int = 2,
               prompt_len: int = 8, gen_len: int = 8,
               connect_timeout: float = 60.0,
               verbose: bool = True) -> int:
    """``python -m repro infer`` body.  Exit codes: 0 ok, 3 no params
    ever arrived, 4 rejected by the leader, 5 the leader hung."""
    from repro.cluster.mptransport import WireProtocolError
    try:
        client = ServeClient(address, connect_timeout=connect_timeout)
    except WireProtocolError as e:
        print(f"infer failed: {e}", file=sys.stderr, flush=True)
        return 4
    try:
        from repro.api.spec import ExperimentSpec
        from repro.serve.workload import build_infer_adapter
        if client.spec_dict is None:
            print("infer failed: leader's WELCOME carried no spec",
                  file=sys.stderr, flush=True)
            return 4
        spec = ExperimentSpec.from_dict(client.spec_dict)
        if verbose:
            print(f"[infer] serve client {client.serve_id} connected to "
                  f"{address} (arch={spec.arch}); building the "
                  "inference workload", flush=True)
        adapter = build_infer_adapter(spec, batch=batch,
                                      prompt_len=prompt_len,
                                      gen_len=gen_len)
        done = 0
        last_version: Optional[int] = None
        params = None
        t_start = time.monotonic()
        while done < requests:
            if duration_s is not None \
                    and time.monotonic() - t_start > duration_s:
                break
            msg = client.wait_params(min_version=0, timeout=1.0)
            if msg is None:
                if client.closed.is_set():
                    break
                continue
            if msg.version != last_version:
                params = adapter.decode(msg.params)
                last_version = msg.version
            t0 = time.monotonic()
            out = adapter.run(params, done)
            dt = time.monotonic() - t0
            done += 1
            if verbose:
                print(f"[infer] req {done}: params v{msg.version} "
                      f"{dt * 1e3:.1f}ms — {adapter.summary(out)}",
                      flush=True)
        wall = time.monotonic() - t_start
        if client.stall_reason:
            print(f"infer: {client.stall_reason}", file=sys.stderr,
                  flush=True)
            return 5
        if client.reject_reason:
            print(f"infer: rejected by leader: {client.reject_reason}",
                  file=sys.stderr, flush=True)
            return 4
        if done == 0:
            print("infer: no params ever arrived (leader gone before "
                  "the first push?)", file=sys.stderr, flush=True)
            return 3
        if verbose:
            print(f"[infer] {done} requests in {wall:.2f}s "
                  f"({done / max(wall, 1e-9):.2f} req/s), last params "
                  f"version {last_version}", flush=True)
        return 0
    finally:
        client.close()


def spawn_infer_process(address: Any, *, requests: int = 2,
                        connect_timeout: float = 120.0,
                        platform: Optional[str] = None,
                        quiet: bool = True) -> "subprocess.Popen":
    """Launch ``python -m repro infer`` as a separate OS process — the
    test/bench harness's stand-in for a real inference client on
    another machine (distinct interpreter, distinct spec rebuild, TCP
    the only link).  Mirrors
    :func:`repro.cluster.hostlink.spawn_join_process`."""
    from repro.cluster.hostlink import _addr_str
    cmd = [sys.executable, "-m", "repro", "infer", _addr_str(address),
           "--requests", str(requests),
           "--connect-timeout", str(connect_timeout)]
    if quiet:
        cmd.append("--quiet")
    env = dict(os.environ)
    import repro
    pkg_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env["PYTHONPATH"] = pkg_root + os.pathsep + env.get("PYTHONPATH", "")
    if platform:
        env["JAX_PLATFORMS"] = platform
    return subprocess.Popen(cmd, env=env)
