"""repro.serve — the live serving plane.

Read-only SERVE peers that stream fresh params from a training leader
over the slab wire and run inference on every pushed version — the
user-visible half of the K(t) freshness/throughput trade.
"""
from repro.serve.client import ServeClient, infer_main
from repro.serve.workload import (LMAdapter, ProbeAdapter,
                                  build_infer_adapter, lm_tiny_config,
                                  lm_tiny_workload)

__all__ = [
    "ServeClient", "infer_main", "LMAdapter", "ProbeAdapter",
    "build_infer_adapter", "lm_tiny_config", "lm_tiny_workload",
]
