"""Threshold functions K(t) — the heart of the Smooth Switch algorithm.

The paper controls the async→sync transition with a *monotonically
increasing* threshold K(t): the number of gradients that must accumulate in
the server's buffer before a (synchronous) flush.  K=1 ⇒ fully async,
K=num_workers ⇒ fully sync.  The paper uses a step function whose step
*size* is expressed in multiples of 1/lr (their §6: "step sizes in
multiples of 3 and 5 of reciprocal of learning rate"); we provide that plus
the monotone families the paper's future-work section asks about.

All schedules map an update counter t (number of parameter updates applied
so far) to an integer K in [1, num_workers].
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable


@dataclasses.dataclass(frozen=True)
class ThresholdSchedule:
    """K(t): update counter -> aggregation threshold."""
    name: str
    num_workers: int
    fn: Callable[[int], float]

    def __call__(self, t: int) -> int:
        k = int(self.fn(t))
        return max(1, min(self.num_workers, k))

    def phases(self, horizon: int):
        """[(t_start, K)] distinct phases within [0, horizon) — used by the
        SPMD layer to pick compiled variants."""
        out = []
        prev = None
        for t in range(horizon):
            k = self(t)
            if k != prev:
                out.append((t, k))
                prev = k
        return out


def step_schedule(num_workers: int, step_size: int) -> ThresholdSchedule:
    """The paper's schedule: K grows by 1 every `step_size` updates.

    The paper sets step_size = c / lr for c in {3, 5} (e.g. lr=0.01 ->
    step sizes 300 and 500).
    """
    return ThresholdSchedule(
        f"step({step_size})", num_workers,
        lambda t: 1 + t // max(1, step_size))


def linear_schedule(num_workers: int, horizon: int) -> ThresholdSchedule:
    return ThresholdSchedule(
        f"linear({horizon})", num_workers,
        lambda t: 1 + (num_workers - 1) * min(1.0, t / max(1, horizon)))


def cosine_schedule(num_workers: int, horizon: int) -> ThresholdSchedule:
    return ThresholdSchedule(
        f"cosine({horizon})", num_workers,
        lambda t: 1 + (num_workers - 1) * 0.5
        * (1 - math.cos(math.pi * min(1.0, t / max(1, horizon)))))


def exponential_schedule(num_workers: int, horizon: int,
                         rate: float = 5.0) -> ThresholdSchedule:
    return ThresholdSchedule(
        f"exp({horizon},{rate})", num_workers,
        lambda t: 1 + (num_workers - 1)
        * (1 - math.exp(-rate * min(1.0, t / max(1, horizon))))
        / (1 - math.exp(-rate)))


def constant_schedule(num_workers: int, k: int) -> ThresholdSchedule:
    """K fixed: k=1 ≙ pure async, k=num_workers ≙ pure sync."""
    return ThresholdSchedule(f"const({k})", num_workers, lambda t: k)


class _DeprecatedSchedules(dict):
    """Legacy factory dict.  The factories here take *inconsistent*
    positional arguments (``step`` takes a step size, the rest take a
    horizon), which forced per-kind branches in every caller; the unified
    spec mini-language in :mod:`repro.api.schedules` replaces it
    (``parse_schedule("step:300", num_workers)``)."""

    def __getitem__(self, key):
        warnings.warn(
            "repro.core.schedule.SCHEDULES is deprecated; use "
            "repro.api.parse_schedule(spec, num_workers) with a spec "
            'string like "step:300" or "cosine:horizon=2000"',
            DeprecationWarning, stacklevel=2)
        return super().__getitem__(key)


SCHEDULES = _DeprecatedSchedules({
    "step": step_schedule,
    "linear": linear_schedule,
    "cosine": cosine_schedule,
    "exp": exponential_schedule,
})


def group_size_phases(schedule: ThresholdSchedule, horizon: int,
                      axis_size: int):
    """Map threshold phases onto power-of-two reduction-group sizes for the
    SPMD adaptation: K workers aggregating ≙ a reduction group of size
    g = min pow2 >= K * axis_size / num_workers (clamped to divisors of
    axis_size).  Returns [(t_start, g)]."""
    out = []
    prev = None
    for t_start, k in schedule.phases(horizon):
        frac = k / schedule.num_workers
        g = 1
        while g < axis_size and g < frac * axis_size:
            g *= 2
        g = min(g, axis_size)
        if g != prev:
            out.append((t_start, g))
            prev = g
    return out
