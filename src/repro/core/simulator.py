"""Event-driven parameter-server simulator — the paper-faithful layer.

Reproduces the paper's experimental setting exactly, but deterministically:
N workers with heterogeneous speeds, communication/execution delays sampled
from N(0, σ) on a configurable fraction of workers (paper: 50%), one
parameter server, and three aggregation policies:

  * ``async``  — every arriving gradient is applied immediately (Hogwild-
                 style stale reads),
  * ``sync``   — the server waits for all workers each round (faster
                 workers idle until the slowest arrives),
  * ``hybrid`` — the Smooth Switch algorithm: gradients accumulate in a
                 buffer; once |buffer| >= K(t) they are flushed as one
                 aggregated update, with K(t) a monotone threshold schedule.

Time is *virtual* (an event heap), so a 100-second paper run costs only
the gradient computations, all of which are real jitted JAX on real models.
The aggregation itself runs on the same slab path as the wall-clock
cluster server (:mod:`repro.core.slab`): gradients are flattened once
into ``(P,)`` slabs and every flush is one fused, donated executable.
Metrics (train loss / test loss / test accuracy) are sampled on a fixed
virtual-time grid, mirroring the paper's "metric vs time" plots and the
"averaged over the entire training interval" tables.
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.schedule import ThresholdSchedule, constant_schedule
from repro.core.slab import SlabAggregator, SlabBuffer, slab_codec
from repro.optim.slab_form import SlabOptimizer


@dataclasses.dataclass(frozen=True)
class WorkerPool:
    """Static timing model for the worker fleet."""
    num_workers: int = 25
    base_compute: float = 0.05          # seconds per gradient (virtual)
    speed_jitter: float = 0.2           # worker speed ~ U[1-j, 1+j]
    delay_fraction: float = 0.5         # fraction of workers with delays
    delay_mean: float = 0.0             # N(mean, std) extra per gradient
    delay_std: float = 0.25
    comm_delay: float = 0.002           # fixed network latency each way
    # Parameter-server service times: the PS ingests each gradient and
    # applies updates under a lock (the contention that motivates batched
    # flushes — Hogwild/Project Adam territory).  Async pays `apply` per
    # gradient; the hybrid buffer pays it once per flush.
    ps_ingest_time: float = 0.0002      # per-gradient enqueue cost
    ps_apply_time: float = 0.002        # per parameter-update apply cost

    def build(self, rng: np.random.Generator):
        speeds = self.base_compute * rng.uniform(
            1 - self.speed_jitter, 1 + self.speed_jitter, self.num_workers)
        delayed = np.zeros(self.num_workers, bool)
        k = int(round(self.delay_fraction * self.num_workers))
        delayed[rng.permutation(self.num_workers)[:k]] = True
        return speeds, delayed

    def grad_time(self, w: int, speeds, delayed, rng) -> float:
        t = speeds[w]
        if delayed[w]:
            t += max(0.0, rng.normal(self.delay_mean, self.delay_std))
        return t + 2 * self.comm_delay


@dataclasses.dataclass
class SimResult:
    times: np.ndarray            # metric sample times
    train_loss: np.ndarray
    test_loss: np.ndarray
    test_acc: np.ndarray
    num_updates: int
    num_gradients: int
    mode: str

    def averaged(self) -> Dict[str, float]:
        """Paper-style 'averaged over the entire training interval'."""
        return {
            "train_loss": float(np.mean(self.train_loss)),
            "test_loss": float(np.mean(self.test_loss)),
            "test_acc": float(np.mean(self.test_acc)),
        }


class PSTrainer:
    """Runs one simulated training for a given aggregation policy."""

    def __init__(self, loss_fn: Callable, init_params, data,
                 lr: float = 0.01, batch_size: int = 32,
                 pool: WorkerPool = WorkerPool(), seed: int = 0,
                 staleness_decay: float = 1.0, flush_mode: str = "sum",
                 accuracy_fn: Optional[Callable] = None,
                 optimizer: Optional[SlabOptimizer] = None):
        """data = (x_train, y_train, x_test, y_test); loss_fn(params, x, y)
        -> scalar nll.

        flush_mode: "sum" applies every buffered gradient at full lr (the
        paper's Algorithm 1 reading: 'synchronize all the gradients in the
        buffer'; K=1 ≡ async exactly); "mean" averages the buffer (sync-
        style confident update, K× smaller step mass).

        accuracy_fn(params, x, y) -> scalar; when None the test-accuracy
        series is all zeros (e.g. regression workloads).
        """
        assert flush_mode in ("sum", "mean")
        self.flush_mode = flush_mode
        self.loss_fn = loss_fn
        self.init_params = init_params
        self.x_tr, self.y_tr, self.x_te, self.y_te = data
        self.lr = lr
        self.batch = batch_size
        self.pool = pool
        self.seed = seed
        self.staleness_decay = staleness_decay

        # the slab aggregation path (repro.core.slab): each simulated
        # worker's gradient is flattened once, inside the jitted
        # gradient executable, and every flush goes through the same
        # fused slab executable the cluster server uses
        self._codec = slab_codec(init_params)
        grad_fn = jax.grad(loss_fn)
        self._grad = jax.jit(
            lambda p, x, y: self._codec.encode(grad_fn(p, x, y)))
        self._loss = jax.jit(loss_fn)
        self.accuracy_fn = accuracy_fn
        # the server-side optimizer: same slab-resident moments + fused
        # flush+update executable as the cluster server, so the two
        # backends stay bitwise-comparable per optimizer choice
        self.optimizer = optimizer or SlabOptimizer("sgd")
        # aggregators (and their compiled stage/flush executables) are
        # reused across simulate() calls — one compile per staging
        # width, however many runs a comparison sweep makes
        self._agg_cache: Dict[int, SlabAggregator] = {}

    # ------------------------------------------------------------------
    def _sample_batch(self, rng: np.random.Generator, shard_idx):
        idx = rng.choice(shard_idx, size=self.batch, replace=True)
        return self.x_tr[idx], self.y_tr[idx]

    def _metrics(self, params):
        tr = float(self._loss(params, self.x_tr[:2048], self.y_tr[:2048]))
        te = float(self._loss(params, self.x_te, self.y_te))
        acc = float(self.accuracy_fn(params, self.x_te, self.y_te)) \
            if self.accuracy_fn else 0.0
        return tr, te, acc

    def _shards(self):
        n = self.x_tr.shape[0]
        w = self.pool.num_workers
        return [np.arange(i, n, w) for i in range(w)]

    # ------------------------------------------------------------------
    def run(self, mode: str, horizon: float = 20.0,
            schedule: Optional[ThresholdSchedule] = None,
            sample_every: float = 0.5) -> SimResult:
        """Deprecated alias for :meth:`simulate` (the pre-``repro.api``
        entry point).  Prefer ``repro.api.SimulatorTrainer`` /
        ``repro.api.run``, which return a unified ``RunResult``."""
        warnings.warn(
            "PSTrainer.run() is deprecated; use PSTrainer.simulate() or "
            "the unified repro.api layer (ExperimentSpec -> run())",
            DeprecationWarning, stacklevel=2)
        return self.simulate(mode, horizon=horizon, schedule=schedule,
                             sample_every=sample_every)

    def simulate(self, mode: str, horizon: float = 20.0,
                 schedule: Optional[ThresholdSchedule] = None,
                 sample_every: float = 0.5) -> SimResult:
        assert mode in ("sync", "async", "hybrid")
        rng = np.random.default_rng(self.seed)
        speeds, delayed = self.pool.build(rng)
        shards = self._shards()
        params = self.init_params
        W = self.pool.num_workers

        if mode == "async":
            schedule = constant_schedule(W, 1)
        elif mode == "sync":
            schedule = constant_schedule(W, W)
        assert schedule is not None, "hybrid mode needs a schedule"

        # async pins K(t) ≡ 1 (the schedule is the constant built
        # above), so its staging buffer needs a single row; sync/hybrid
        # flushes aggregate at most one gradient per worker — or up to
        # the schedule's own ceiling, if it was built for a larger fleet
        k_max = 1 if mode == "async" else max(W, schedule.num_workers)
        agg = self._agg_cache.get(k_max)
        if agg is None:
            agg = self._agg_cache[k_max] = SlabAggregator(
                self._codec, params, k_max, optimizer=self.optimizer)
        else:
            # reused executables, fresh state: re-seed the params, wipe
            # rows a previous run may have left staged, and zero the
            # optimizer moments + count back to step 0
            agg.reset_params(params)
            agg.wipe_staging()
            agg.reset_opt_state()
        buffer = SlabBuffer(agg, self.staleness_decay)
        version = 0            # number of parameter updates applied
        n_grads = 0
        sample_t = [t for t in np.arange(0.0, horizon + 1e-9, sample_every)]
        samples: List[Tuple[float, float, float]] = []
        next_sample = 0

        def record_until(now):
            nonlocal next_sample
            while next_sample < len(sample_t) and sample_t[next_sample] <= now:
                samples.append(self._metrics(params))
                next_sample += 1

        if mode == "sync":
            now = 0.0
            while now < horizon:
                arrivals = [now + self.pool.grad_time(w, speeds, delayed, rng)
                            for w in range(W)]
                round_end = max(arrivals)
                record_until(min(round_end, horizon))
                if round_end >= horizon:
                    break
                for w in range(W):     # staged in worker order (slot = w)
                    x, y = self._sample_batch(rng, shards[w])
                    agg.stage(self._grad(params, x, y), w)
                    n_grads += 1
                agg.flush_apply(np.ones(W), self.lr)   # round mean
                params = agg.params_tree()
                version += 1
                now = round_end
            record_until(horizon)
        else:
            # async / hybrid share the event loop; async is K(t) ≡ 1.
            # Each heap entry carries the parameter *snapshot* the worker
            # read when it was dispatched — pytrees are immutable, so this
            # is a reference, not a copy.  Staleness is therefore physical:
            # the gradient is computed on params that other workers may
            # have advanced several versions past by arrival time.
            # The PS is a serial resource: each arriving gradient costs
            # `ps_ingest_time` and each flush costs `ps_apply_time` of
            # server time; workers receive fresh params (and redispatch)
            # only once the server has processed their gradient.  Async
            # therefore saturates the PS at high update rates — the
            # contention the hybrid buffer amortises.
            counter = 0  # tie-breaker (params pytrees are not orderable)
            server_free = 0.0
            heap: List[Tuple[float, int, int, int, Any]] = []
            for w in range(W):
                heapq.heappush(
                    heap, (self.pool.grad_time(w, speeds, delayed, rng),
                           counter, w, version, params))
                counter += 1
            while heap and heap[0][0] < horizon:
                now, _, w, v_read, params_read = heapq.heappop(heap)
                record_until(now)
                x, y = self._sample_batch(rng, shards[w])
                grad_slab = self._grad(params_read, x, y)
                n_grads += 1
                done = max(now, server_free) + self.pool.ps_ingest_time
                buffer.add(grad_slab, v_read)
                if len(buffer) >= schedule(version):
                    weights = buffer.weights(version)
                    k = len(buffer)
                    buffer.clear()
                    # "sum" applies every buffered gradient at full lr
                    # (K=1 ≡ async exactly); "mean" averages the buffer
                    scale = self.lr * k if self.flush_mode == "sum" \
                        else self.lr
                    agg.flush_apply(weights, scale)
                    params = agg.params_tree()
                    version += 1
                    done += self.pool.ps_apply_time
                server_free = done
                heapq.heappush(
                    heap, (done + self.pool.grad_time(w, speeds, delayed,
                                                      rng),
                           counter, w, version, params))
                counter += 1
            record_until(horizon)

        arr = np.asarray(samples) if samples else np.zeros((0, 3))
        return SimResult(
            times=np.asarray(sample_t[:len(samples)]),
            train_loss=arr[:, 0], test_loss=arr[:, 1], test_acc=arr[:, 2],
            num_updates=version, num_gradients=n_grads, mode=mode)
