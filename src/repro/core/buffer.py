"""Server-side gradient buffer with staleness-aware aggregation.

The buffer stores worker gradients together with the parameter *version*
they were computed against.  A flush aggregates the buffered gradients into
one update:

    g_agg = Σ_i w_i · g_i / Σ_i w_i,   w_i = staleness_decay^(v_now - v_i)

With staleness_decay=1.0 (default) this is the plain mean, which matches
the paper (their flush gives every buffered gradient equal weight); the
decay knob is the beyond-paper extension evaluated in EXPERIMENTS.md.

This module is the **legacy pytree reference**: the live hot paths
(cluster server, simulator) aggregate on the slab path instead —
:class:`repro.core.slab.SlabBuffer` staging into one fused, donated
flush executable whose TPU inner loop is
``repro.kernels.hybrid_aggregate.flush_pallas``.  `aggregate_flush`
stays as the per-leaf oracle that parity tests and the server
throughput benchmark compare the slab path against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def aggregate_flush(grads: List[Any], weights: np.ndarray):
    """Weighted mean of a list of gradient pytrees.  weights: (K,)."""
    wsum = float(np.sum(weights))
    ws = [float(w) / wsum for w in weights]

    def comb(*leaves):
        out = ws[0] * leaves[0]
        for w, leaf in zip(ws[1:], leaves[1:]):
            out = out + w * leaf
        return out

    return jax.tree.map(comb, *grads)


@dataclasses.dataclass
class GradientBuffer:
    staleness_decay: float = 1.0

    def __post_init__(self):
        self._grads: List[Any] = []
        self._versions: List[int] = []

    def __len__(self) -> int:
        return len(self._grads)

    def add(self, grad, version: int) -> None:
        self._grads.append(grad)
        self._versions.append(version)

    def flush(self, current_version: int):
        """Aggregate + clear.  Returns (g_agg, num_aggregated)."""
        assert self._grads, "flush of empty buffer"
        n = len(self._grads)
        if n == 1:
            # the weighted mean of one gradient is itself (w/w = 1);
            # skipping the per-leaf arithmetic keeps the K=1 (async) hot
            # path at zero aggregation cost
            agg = self._grads[0]
        else:
            stale = current_version - np.asarray(self._versions, np.float64)
            weights = self.staleness_decay ** stale
            agg = aggregate_flush(self._grads, weights)
        self._grads, self._versions = [], []
        return agg, n

    def drain(self):
        """Take the buffered (grads, versions) and clear, without
        aggregating — for callers that fuse the aggregation into a
        jitted update (e.g. the cluster parameter server, where per-leaf
        eager arithmetic would serialize the whole fleet)."""
        grads, versions = self._grads, self._versions
        self._grads, self._versions = [], []
        return grads, versions

    def staleness(self, current_version: int) -> List[int]:
        return [current_version - v for v in self._versions]
