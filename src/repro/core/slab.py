"""Flat gradient/parameter slabs — the wire and aggregation format.

A *slab* is one contiguous ``(P_pad,)`` float32 array holding every leaf
of a pytree: leaves in ``jax.tree`` flatten order, raveled C-order,
concatenated, and zero-padded so ``P_pad`` is a multiple of the Pallas
flush tile (:data:`repro.kernels.hybrid_aggregate.TILE_P`).  Workers
flatten a gradient **once** and ship the slab; the server stages
incoming slabs into a preallocated ``(K_max, P_pad)`` buffer and applies
every flush through **one** jitted, donated executable, regardless of
how many gradients K the flush aggregates.  The same layout is what a
multi-process transport would put on the wire (one buffer, no per-leaf
framing).

Layout::

    offset 0         sizes[0]        sizes[0]+sizes[1]   ...        P  P_pad
    |  leaf 0 (ravel) | leaf 1 (ravel) |  ...  | leaf L-1 | 0-padding |

Donation rules (enforced by :class:`SlabAggregator`, relied on by the
cluster server):

* the aggregator's private params slab and the staging buffer are
  donated into their executables — they are updated in place and must
  never escape the aggregator;
* everything handed to callers (the published params slab, decoded
  trees) is a *fresh* executable output, never an alias of a donated
  buffer, so it stays valid across later flushes;
* long-lived consumers (checkpoints, metric snapshots) must still copy
  to host (``jax.device_get``) before releasing the server lock — see
  ``ParameterServer.snapshot``.

Backend matrix for the flush's inner reduction:

============  =======================================================
TPU           :func:`repro.kernels.hybrid_aggregate.flush_pallas`
              (masked: zero-weight rows beyond K contribute exactly 0)
CPU / other   jnp fallback — a statically unrolled masked fold, bitwise
              identical to the legacy per-leaf fold for uniform weights
tests         the Pallas kernel under ``interpret=True``
============  =======================================================
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hybrid_aggregate import TILE_P, flush_pallas


class SlabCodec:
    """Cached pytree ⇄ slab codec for one (treedef, shapes, dtypes).

    ``encode``/``decode`` are jitted; both return fresh buffers (decode
    never returns views into the slab, so decoded trees survive the
    slab's donation into a later flush).
    """

    def __init__(self, treedef, shapes: Tuple[Tuple[int, ...], ...],
                 dtypes: Tuple[Any, ...]):
        for dt in dtypes:
            if not jnp.issubdtype(dt, jnp.floating):
                raise TypeError(
                    f"slab codec requires floating leaves, got {dt} "
                    "(the slab is a float32 array; integer leaves would "
                    "round-trip lossily)")
            if jnp.dtype(dt).itemsize > 4:
                raise TypeError(
                    f"slab codec requires leaves <= 32-bit, got {dt} "
                    "(the slab is a float32 array; wider floats would "
                    "be silently quantized on the round trip)")
        self.treedef = treedef
        self.shapes = shapes
        self.dtypes = dtypes
        self.sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
        self.offsets = tuple(int(o) for o in
                             np.cumsum((0,) + self.sizes)[:-1])
        self.size = int(sum(self.sizes))            # live elements P
        assert self.size > 0, "empty pytree has no slab"
        self.padded_size = -(-self.size // TILE_P) * TILE_P
        self._encode = jax.jit(self._encode_impl)
        self._decode = jax.jit(self._decode_impl)

    # ------------------------------------------------------------ codec
    def _encode_impl(self, tree):
        leaves = jax.tree_util.tree_leaves(tree)
        flat = jnp.concatenate(
            [jnp.ravel(x).astype(jnp.float32) for x in leaves])
        return jnp.pad(flat, (0, self.padded_size - self.size))

    def _decode_impl(self, slab):
        leaves = [
            slab[off:off + n].reshape(shape).astype(dtype)
            for off, n, shape, dtype in zip(self.offsets, self.sizes,
                                            self.shapes, self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def encode(self, tree) -> jax.Array:
        """tree -> (P_pad,) f32 slab (fresh buffer)."""
        return self._encode(tree)

    def decode(self, slab) -> Any:
        """(P_pad,) slab -> tree with the template's shapes/dtypes."""
        return self._decode(slab)

    def decode_host(self, slab) -> Any:
        """Decode + copy to host numpy — the snapshot/checkpoint form
        (valid forever, regardless of later donations)."""
        return jax.device_get(self._decode(slab))

    def __repr__(self):
        return (f"SlabCodec(leaves={len(self.sizes)}, P={self.size}, "
                f"padded={self.padded_size})")


_CODEC_CACHE: Dict[Tuple, SlabCodec] = {}


def slab_codec(tree) -> SlabCodec:
    """The cached codec for ``tree``'s structure (treedef + leaf shapes
    + dtypes).  Two pytrees with identical structure share one codec —
    and therefore its compiled encode/decode executables."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(np.shape(x)) for x in leaves)
    dtypes = tuple(jnp.dtype(getattr(x, "dtype", None)
                             or jnp.result_type(x)) for x in leaves)
    key = (treedef, shapes, dtypes)
    codec = _CODEC_CACHE.get(key)
    if codec is None:
        codec = _CODEC_CACHE[key] = SlabCodec(treedef, shapes, dtypes)
    return codec


class SlabAggregator:
    """Params slab + ``(K_max, P_pad)`` staging buffer + the **one**
    donated fused flush executable.

    The flush computes, for the first ``k`` staged rows ``g_i`` with
    weights ``w_i`` (zero-padded to ``K_max``)::

        params <- params - scale * (Σ_i w_i · g_i) / (Σ_i w_i)

    in place (the params slab is donated), and returns a fresh
    *published* copy of the new params that is safe to hand to workers:
    it never aliases the donated buffer (guarded by a regression test in
    ``tests/test_slab.py``).  One executable serves every buffer size
    ``1 <= k <= K_max`` purely through zero-weight masking of the
    unused rows.  The jit cache is per-aggregator, so
    ``flush_cache_size()`` is an exact probe that no per-K
    recompilation crept back in.
    """

    def __init__(self, codec: SlabCodec, params, k_max: int, *,
                 use_pallas: Optional[bool] = None,
                 interpret: bool = False):
        assert k_max >= 1, k_max
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.codec = codec
        self.k_max = int(k_max)
        self.use_pallas = use_pallas
        self.interpret = interpret
        # private, donated state: in-place updated, never escapes
        self._slab = codec.encode(params)
        self._staging = jnp.zeros((self.k_max, codec.padded_size),
                                  jnp.float32)
        # published params slab: always a fresh executable output
        self._pub = codec.encode(params)
        self._stage = jax.jit(self._stage_impl, donate_argnums=(0,))
        self._flush = jax.jit(self._flush_impl, donate_argnums=(0,))
        self._zero_row = jnp.zeros((codec.padded_size,), jnp.float32)

    # ------------------------------------------------------ executables
    @staticmethod
    def _stage_impl(staging, row, slot):
        # donated: an in-place row write, not a buffer copy
        return jax.lax.dynamic_update_slice(staging, row[None], (slot, 0))

    def _flush_impl(self, pslab, staging, weights, scale):
        # both branches reduce via zero-weight masking — rows past the
        # live count hold weight 0 and contribute exactly +0.0 — which
        # is what lets ONE executable serve every buffer size k
        if self.use_pallas:
            agg = flush_pallas(staging, weights, interpret=self.interpret)
        else:
            # jnp fallback: a statically unrolled masked fold in staging
            # order — structurally identical to the legacy per-leaf fold
            # (same muls, same adds, same order), which keeps the sync
            # round mean bitwise-equal to the pre-slab server.  (A
            # fori_loop over only the k live rows compiles to different
            # FMA contraction and drifts by 1 ulp.)
            agg = weights[0] * staging[0]
            for i in range(1, self.k_max):
                agg = agg + weights[i] * staging[i]
        new = pslab - scale * (agg / jnp.sum(weights))
        # `new + 0.0` is the published copy: a second output buffer that
        # does NOT alias the donated input (tests/test_slab.py guards
        # this against XLA deciding to alias the two outputs)
        return new, new + 0.0

    # ------------------------------------------------------------- API
    def stage(self, slab: jax.Array, slot: int) -> None:
        """Write one gradient slab into staging row ``slot`` (in place)."""
        assert 0 <= slot < self.k_max, (slot, self.k_max)
        self._staging = self._stage(self._staging, slab,
                                    jnp.asarray(slot, jnp.int32))

    def flush_apply(self, weights: np.ndarray, scale: float) -> jax.Array:
        """Aggregate the first ``len(weights)`` staged rows and apply the
        update.  Returns the freshly published params slab."""
        k = len(weights)
        assert 1 <= k <= self.k_max, (k, self.k_max)
        wfull = np.zeros((self.k_max,), np.float32)
        wfull[:k] = np.asarray(weights, np.float32)
        self._slab, self._pub = self._flush(
            self._slab, self._staging, jnp.asarray(wfull),
            jnp.asarray(scale, jnp.float32))
        return self._pub

    @property
    def params_slab(self) -> jax.Array:
        """The published params slab (safe to ship / hold)."""
        return self._pub

    def params_tree(self):
        """Decode the published params into a fresh pytree."""
        return self.codec.decode(self._pub)

    def params_tree_host(self):
        """Decode + host copy — the checkpoint/snapshot form."""
        return self.codec.decode_host(self._pub)

    def reset_params(self, params) -> None:
        """Replace the live params (checkpoint restore)."""
        self._slab = self.codec.encode(params)
        self._pub = self.codec.encode(params)

    def wipe_staging(self) -> None:
        """Zero every staging row.  Needed when staged gradients are
        *discarded* rather than consumed by a flush: zero-weight masking
        neutralizes any finite leftover, but a non-finite row (a
        diverged gradient the restore is recovering from) would poison
        later flushes — ``0 · inf = nan``."""
        self._staging = jnp.zeros_like(self._staging)

    def warmup(self) -> None:
        """Compile the stage + flush executables before the clock starts
        (one compile each, for any fleet size — vs the pre-slab server's
        one compile per K in 1..num_workers).  The warmup flush uses
        scale=0 over a zero row, so the params are bitwise unchanged."""
        self.stage(self._zero_row, 0)
        self.flush_apply(np.ones((1,), np.float32), 0.0)

    def grow(self, k_max: int) -> None:
        """Resize the staging buffer to ``k_max`` rows (elastic fleet
        admission).  Already-staged rows are preserved — a hybrid buffer
        keeps gradients staged *between* flushes, so growth mid-buffer
        must not lose them — and the new rows are zero, which the
        zero-weight masking keeps inert.  No warmup flush runs here (it
        would fold staged row 0 into the params); the next real flush
        traces the new shape, so growth costs one compile per resize —
        paid only by elastic fleets, never by a fixed one.
        Shrinking is never done: a departed worker's row just keeps
        weight 0."""
        k_max = int(k_max)
        if k_max <= self.k_max:
            return
        old = self._staging
        self.k_max = k_max
        self._staging = jnp.zeros((k_max, self.codec.padded_size),
                                  jnp.float32).at[:old.shape[0]].set(old)

    def flush_cache_size(self) -> int:
        """Number of compiled flush executables (the probe asserted to
        be exactly 1 in tests, regardless of fleet size / K — growth via
        :meth:`grow` adds one entry per resize)."""
        return int(self._flush._cache_size())


class SlabBuffer:
    """Slab-backed gradient buffer: the staged-rows counterpart of
    :class:`repro.core.buffer.GradientBuffer`.

    Gradient slabs are staged into the aggregator as they arrive (row =
    arrival order); only the parameter versions they were computed
    against are tracked host-side, for the staleness weights.  The
    flush itself is :meth:`SlabAggregator.flush_apply`.
    """

    def __init__(self, aggregator: SlabAggregator,
                 staleness_decay: float = 1.0):
        self.agg = aggregator
        self.staleness_decay = float(staleness_decay)
        self._versions: List[int] = []

    def __len__(self) -> int:
        return len(self._versions)

    def add(self, slab: jax.Array, version: int) -> None:
        self.agg.stage(slab, len(self._versions))
        self._versions.append(int(version))

    def weights(self, current_version: int) -> np.ndarray:
        """Staleness weights ``decay^(now - v_i)`` for the staged rows.
        The exponent is clamped at 0: after a checkpoint restore rolls
        the version back, an in-flight gradient can be tagged with a
        *future* version, and a negative exponent would upweight exactly
        the abandoned-history gradients the restore discards."""
        stale = np.maximum(0.0, current_version
                           - np.asarray(self._versions, np.float64))
        return self.staleness_decay ** stale

    def clear(self) -> None:
        """Forget rows that a flush just **consumed** (no wipe needed:
        consumed rows are finite values already folded into the params,
        and zero weights mask them on the next flush)."""
        self._versions = []

    def discard(self) -> None:
        """Drop staged rows **unconsumed** (checkpoint restore).  The
        rows are wiped, not just masked: a discarded gradient may be
        non-finite — that divergence can be exactly what the restore is
        recovering from — and ``0 · inf = nan`` would defeat the
        masking on every later flush."""
        self.agg.wipe_staging()
        self._versions = []
