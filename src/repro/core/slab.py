"""Flat gradient/parameter slabs — the wire and aggregation format.

A *slab* is one contiguous ``(P_pad,)`` array holding every leaf of a
pytree: leaves in ``jax.tree`` flatten order, raveled C-order,
concatenated, and zero-padded so ``P_pad`` is a multiple of the Pallas
flush tile (:data:`repro.kernels.hybrid_aggregate.TILE_P`).  Workers
flatten a gradient **once** and ship the slab; the server stages
incoming slabs into a preallocated ``(K_max, P_pad)`` buffer and applies
every flush through **one** jitted, donated executable, regardless of
how many gradients K the flush aggregates.  The same layout is what a
multi-process transport puts on the wire (one buffer, no per-leaf
framing).

The codec is dtype-aware: it keeps a **per-leaf dtype map** (decode
restores every leaf's original dtype exactly) and carries a declared
**aggregation dtype** — ``slab_dtype`` ``"f32"`` (the default, and the
historical format: byte-identical slabs to the pre-mixed-precision
codec) or ``"bf16"`` (half the bytes on the wire and in staging rows).
Whatever the slab dtype, the aggregator's *master* params slab stays
float32 and the flush reduction runs in float32 — bf16 trades wire and
staging bandwidth, never accumulator precision.

Layout::

    offset 0         sizes[0]        sizes[0]+sizes[1]   ...        P  P_pad
    |  leaf 0 (ravel) | leaf 1 (ravel) |  ...  | leaf L-1 | 0-padding |

Multi-million-parameter slabs can additionally be **sharded along P**
into tile-aligned chunks (:class:`SlabAggregator` ``shards=``): each
chunk gets its own staging buffer and donated flush executable (one per
distinct chunk shape), placed round-robin across local devices, so a
big model's staging traffic spreads across the host topology instead of
funneling through one buffer.  ``shards=1`` (the default for small
slabs) is the historical single-buffer path, bit for bit.

Donation rules (enforced by :class:`SlabAggregator`, relied on by the
cluster server):

* the aggregator's private params slab and the staging buffer are
  donated into their executables — they are updated in place and must
  never escape the aggregator;
* everything handed to callers (the published params slab, decoded
  trees) is a *fresh* executable output, never an alias of a donated
  buffer, so it stays valid across later flushes;
* long-lived consumers (checkpoints, metric snapshots) must still copy
  to host (``jax.device_get``) before releasing the server lock — see
  ``ParameterServer.snapshot``.

Backend matrix for the flush's inner reduction:

============  =======================================================
TPU           :func:`repro.kernels.hybrid_aggregate.flush_pallas`
              (masked: zero-weight rows beyond K contribute exactly 0)
CPU / other   jnp fallback — a statically unrolled masked fold, bitwise
              identical to the legacy per-leaf fold for uniform weights
tests         the Pallas kernel under ``interpret=True``
============  =======================================================
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hybrid_aggregate import (TILE_P, flush_adamw_pallas,
                                            flush_momentum_pallas,
                                            flush_pallas)
from repro.optim.optimizers import bias_correction
from repro.optim.slab_form import SlabOptimizer

# declared aggregation dtypes: spec/CLI name -> jnp dtype.  "f32" is the
# historical pinned format (byte-identical slabs to the pre-dtype-aware
# codec); "bf16" halves wire + staging bytes at documented precision cost
SLAB_DTYPES: Dict[str, Any] = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def resolve_slab_dtype(name: str):
    """``"f32"``/``"bf16"`` (or any alias numpy/jnp resolves to the same
    dtype) -> the jnp slab dtype."""
    if name in SLAB_DTYPES:
        return SLAB_DTYPES[name]
    dt = jnp.dtype(name)
    for jdt in SLAB_DTYPES.values():
        if dt == jnp.dtype(jdt):
            return jdt
    raise ValueError(f"slab_dtype must be one of "
                     f"{sorted(SLAB_DTYPES)}, got {name!r}")


class SlabCodec:
    """Cached pytree ⇄ slab codec for one (treedef, shapes, dtypes,
    slab_dtype).

    The codec carries the **per-leaf dtype map**: ``encode`` casts each
    leaf to the declared aggregation dtype (``slab_dtype``), ``decode``
    restores every leaf's original dtype exactly — a bf16 leaf comes
    back bf16 even off a float32 slab and vice versa.  ``encode``/
    ``decode`` are jitted; both return fresh buffers (decode never
    returns views into the slab, so decoded trees survive the slab's
    donation into a later flush).
    """

    def __init__(self, treedef, shapes: Tuple[Tuple[int, ...], ...],
                 dtypes: Tuple[Any, ...], slab_dtype: str = "f32",
                 paths: Optional[Tuple[str, ...]] = None):
        if paths is None:
            paths = tuple(f"leaf[{i}]" for i in range(len(shapes)))
        for path, dt in zip(paths, dtypes):
            if not jnp.issubdtype(dt, jnp.floating):
                raise TypeError(
                    f"slab codec requires floating leaves, got {dt} "
                    f"at {path} (the slab is a floating array; integer "
                    "leaves would round-trip lossily)")
            if jnp.dtype(dt).itemsize > 4:
                raise TypeError(
                    f"slab codec requires leaves <= 32-bit, got {dt} "
                    f"at {path} (wider floats would be silently "
                    "quantized on the round trip)")
        self.treedef = treedef
        self.shapes = shapes
        self.dtypes = dtypes
        self.paths = paths
        self.slab_dtype = jnp.dtype(resolve_slab_dtype(slab_dtype))
        self.slab_dtype_name = "f32" \
            if self.slab_dtype == jnp.dtype(jnp.float32) else "bf16"
        self.sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
        self.offsets = tuple(int(o) for o in
                             np.cumsum((0,) + self.sizes)[:-1])
        self.size = int(sum(self.sizes))            # live elements P
        assert self.size > 0, "empty pytree has no slab"
        self.padded_size = -(-self.size // TILE_P) * TILE_P
        self._encode = jax.jit(self._encode_impl)
        self._decode = jax.jit(self._decode_impl)
        # the aggregator's master accumulator form: always float32,
        # whatever the wire/staging dtype.  For f32 codecs this IS the
        # encode executable (shared jit cache — zero extra compiles on
        # the historical path)
        if self.slab_dtype == jnp.dtype(jnp.float32):
            self._encode_master = self._encode
        else:
            self._encode_master = jax.jit(
                lambda tree: self._encode_as(tree, jnp.float32))

    # ------------------------------------------------------------ codec
    def _encode_as(self, tree, dtype):
        leaves = jax.tree_util.tree_leaves(tree)
        flat = jnp.concatenate(
            [jnp.ravel(x).astype(dtype) for x in leaves])
        return jnp.pad(flat, (0, self.padded_size - self.size))

    def _encode_impl(self, tree):
        return self._encode_as(tree, self.slab_dtype)

    def _decode_impl(self, slab):
        leaves = [
            slab[off:off + n].reshape(shape).astype(dtype)
            for off, n, shape, dtype in zip(self.offsets, self.sizes,
                                            self.shapes, self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def encode(self, tree) -> jax.Array:
        """tree -> (P_pad,) slab in the aggregation dtype (fresh
        buffer)."""
        return self._encode(tree)

    def encode_master(self, tree) -> jax.Array:
        """tree -> (P_pad,) **float32** slab — the aggregator's master
        params form, precision-independent of ``slab_dtype``."""
        return self._encode_master(tree)

    def decode(self, slab) -> Any:
        """(P_pad,) slab (any slab dtype) -> tree with the template's
        shapes and original per-leaf dtypes."""
        return self._decode(slab)

    def decode_host(self, slab) -> Any:
        """Decode + copy to host numpy — the snapshot/checkpoint form
        (valid forever, regardless of later donations)."""
        return jax.device_get(self._decode(slab))

    def __repr__(self):
        return (f"SlabCodec(leaves={len(self.sizes)}, P={self.size}, "
                f"padded={self.padded_size}, "
                f"dtype={self.slab_dtype_name})")


_CODEC_CACHE: Dict[Tuple, SlabCodec] = {}


def slab_codec(tree, slab_dtype: str = "f32") -> SlabCodec:
    """The cached codec for ``tree``'s structure (treedef + leaf shapes
    + dtypes) at the given aggregation dtype.  Two pytrees with
    identical structure share one codec — and therefore its compiled
    encode/decode executables."""
    flat_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [x for _, x in flat_paths]
    paths = tuple(jax.tree_util.keystr(p) or f"leaf[{i}]"
                  for i, (p, _) in enumerate(flat_paths))
    shapes = tuple(tuple(np.shape(x)) for x in leaves)
    dtypes = tuple(jnp.dtype(getattr(x, "dtype", None)
                             or jnp.result_type(x)) for x in leaves)
    sdt = jnp.dtype(resolve_slab_dtype(slab_dtype))
    key = (treedef, shapes, dtypes, sdt)
    codec = _CODEC_CACHE.get(key)
    if codec is None:
        codec = _CODEC_CACHE[key] = SlabCodec(treedef, shapes, dtypes,
                                              slab_dtype=str(sdt),
                                              paths=paths)
    return codec


_SHARD_AUTO_MIN = 1 << 22     # elements: auto-shard only for multi-
#                               million-parameter slabs (below this the
#                               chunking overhead buys nothing)


def _auto_shards(padded_size: int) -> int:
    """Default shard count: 1 (the historical single-buffer path)
    unless the slab is multi-million-parameter AND the host has several
    local devices to spread the chunks across."""
    ndev = jax.local_device_count()
    if ndev <= 1 or padded_size < _SHARD_AUTO_MIN:
        return 1
    return min(ndev, padded_size // TILE_P)


def shard_chunks(padded_size: int, shards: int) -> Tuple[int, ...]:
    """Split ``padded_size`` (a TILE_P multiple) into ``shards``
    tile-aligned chunk lengths (descending by at most one tile)."""
    tiles = padded_size // TILE_P
    shards = max(1, min(int(shards), tiles))
    base, extra = divmod(tiles, shards)
    return tuple((base + (1 if i < extra else 0)) * TILE_P
                 for i in range(shards))


class SlabAggregator:
    """Params slab + ``(K_max, P_pad)`` staging buffer + the **one**
    donated fused flush executable (per staging chunk shape).

    The flush computes, for the first ``k`` staged rows ``g_i`` with
    weights ``w_i`` (zero-padded to ``K_max``)::

        params <- params - scale * (Σ_i w_i · g_i) / (Σ_i w_i)

    in place (the params slab is donated), and returns a fresh
    *published* copy of the new params that is safe to hand to workers:
    it never aliases the donated buffer (guarded by a regression test in
    ``tests/test_slab.py``).  One executable serves every buffer size
    ``1 <= k <= K_max`` purely through zero-weight masking of the
    unused rows.  The jit cache is per-aggregator, so
    ``flush_cache_size()`` is an exact probe that no per-K
    recompilation crept back in.

    **Mixed precision**: staging rows and the published slab are in the
    codec's ``slab_dtype``; the master params slab is always float32 and
    the reduction runs in float32 (bf16 rows are upcast inside the
    executable).  With the default f32 codec every cast is a trace-time
    no-op and the path is bit-for-bit the historical one.

    **Sharding**: ``shards > 1`` splits staging (and the master slab)
    along P into tile-aligned chunks placed round-robin across local
    devices — multi-million-parameter slabs stage across the host
    topology instead of one buffer.  Chunking never changes the math:
    the masked fold is elementwise along P, so the sharded flush is
    bitwise identical to the unsharded one.  ``shards=None`` picks
    automatically (1 unless the slab is huge and devices are plural).

    **Slab-resident optimizer**: with ``optimizer=``
    :class:`repro.optim.SlabOptimizer` the update step lives here too —
    momentum's ``mu`` / AdamW's ``mu``/``nu`` moments are **f32** slabs
    shaped and sharded exactly like the master params (f32 even under a
    bf16 codec), donated into ONE fused flush+optimizer executable per
    buffer shape, with AdamW's bias correction driven by the int32
    update count carried in state (the convention shared with the
    pytree-form optimizers).  ``optimizer="sgd"`` (the default) keeps
    the historical executable untouched, bit for bit.
    """

    def __init__(self, codec: SlabCodec, params, k_max: int, *,
                 use_pallas: Optional[bool] = None,
                 interpret: bool = False,
                 shards: Optional[int] = None,
                 optimizer: Optional[SlabOptimizer] = None):
        assert k_max >= 1, k_max
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.codec = codec
        self.k_max = int(k_max)
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.opt = optimizer or SlabOptimizer("sgd")
        if shards is None:
            shards = _auto_shards(codec.padded_size)
        self.chunk_sizes = shard_chunks(codec.padded_size, shards)
        self.shards = len(self.chunk_sizes)
        self.chunk_offsets = tuple(int(o) for o in
                                   np.cumsum((0,) + self.chunk_sizes)[:-1])
        self._devices = jax.local_devices()
        self._stage = jax.jit(self._stage_impl, donate_argnums=(0,))
        self._flush = jax.jit(self._flush_impl, donate_argnums=(0,))
        # the fused flush+optimizer executables: the params slab AND the
        # moment slabs are donated — updated in place, never escaping.
        # The unit-lr pytree (init, update) pair supplies the jnp-path
        # math, so slab-form and pytree-form share one convention
        self._pair = self.opt.pair()
        if self.opt.name == "momentum":
            self._flush_opt = jax.jit(self._flush_momentum_impl,
                                      donate_argnums=(0, 1))
        elif self.opt.name == "adamw":
            self._flush_opt = jax.jit(self._flush_adamw_impl,
                                      donate_argnums=(0, 1, 2))
        else:
            self._flush_opt = None
        if self.shards == 1:
            # historical single-buffer path, bit for bit
            self._slab = codec.encode_master(params)
            self._staging = jnp.zeros((self.k_max, codec.padded_size),
                                      codec.slab_dtype)
        else:
            self._slab = self._shard(codec.encode_master(params))
            self._staging = [
                jax.device_put(jnp.zeros((self.k_max, n),
                                         codec.slab_dtype), d)
                for n, d in zip(self.chunk_sizes, self._chunk_devices())]
        # published params slab: always a fresh executable output
        self._pub = codec.encode(params)
        self._zero_row = jnp.zeros((codec.padded_size,), codec.slab_dtype)
        self._init_opt_state()

    def _init_opt_state(self) -> None:
        """Zero the optimizer state: **f32** moment slabs shaped (and
        sharded) exactly like the master params slab — f32 even under a
        bf16 codec, per the moments-never-narrow rule — plus the int32
        update count."""
        self._count = jnp.zeros((), jnp.int32)
        self._moments: Dict[str, Any] = {}
        for name in self.opt.moment_names:
            if self.shards == 1:
                self._moments[name] = jnp.zeros(
                    (self.codec.padded_size,), jnp.float32)
            else:
                self._moments[name] = [
                    jax.device_put(jnp.zeros((n,), jnp.float32), d)
                    for n, d in zip(self.chunk_sizes,
                                    self._chunk_devices())]

    # ------------------------------------------------------ executables
    @staticmethod
    def _stage_impl(staging, row, slot):
        # donated: an in-place row write, not a buffer copy.  The cast
        # is a trace-time no-op when the row already arrives in the
        # staging dtype (the native-wire case)
        if row.dtype != staging.dtype:
            row = row.astype(staging.dtype)
        return jax.lax.dynamic_update_slice(staging, row[None], (slot, 0))

    def _flush_impl(self, pslab, staging, weights, scale):
        # both branches reduce via zero-weight masking — rows past the
        # live count hold weight 0 and contribute exactly +0.0 — which
        # is what lets ONE executable serve every buffer size k.  The
        # reduction always runs in float32 (bf16 staging rows are
        # upcast here; for f32 rows the cast disappears at trace time)
        rows = staging if staging.dtype == jnp.float32 \
            else staging.astype(jnp.float32)
        if self.use_pallas:
            agg = flush_pallas(rows, weights, interpret=self.interpret)
        else:
            # jnp fallback: a statically unrolled masked fold in staging
            # order — structurally identical to the legacy per-leaf fold
            # (same muls, same adds, same order), which keeps the sync
            # round mean bitwise-equal to the pre-slab server.  (A
            # fori_loop over only the k live rows compiles to different
            # FMA contraction and drifts by 1 ulp.)
            agg = weights[0] * rows[0]
            for i in range(1, self.k_max):
                agg = agg + weights[i] * rows[i]
        new = pslab - scale * (agg / jnp.sum(weights))
        # the second output is the published copy: a fresh buffer that
        # does NOT alias the donated input (tests/test_slab.py guards
        # this against XLA deciding to alias the two outputs).  In bf16
        # mode the publish IS the narrowing cast; the f32 master stays
        # exact
        if self.codec.slab_dtype == jnp.dtype(jnp.float32):
            return new, new + 0.0
        return new, new.astype(self.codec.slab_dtype)

    def _published(self, new):
        """The publish copy of a freshly updated master slab: a fresh
        buffer that never aliases the donated master (in bf16 mode the
        publish IS the narrowing cast)."""
        if self.codec.slab_dtype == jnp.dtype(jnp.float32):
            return new + 0.0
        return new.astype(self.codec.slab_dtype)

    def _mean_grad(self, staging, weights):
        """The flush's weighted-mean gradient on the jnp path: the same
        statically unrolled masked f32 fold as the SGD flush (same muls,
        adds, order — deterministic), normalized by the weight sum."""
        rows = staging if staging.dtype == jnp.float32 \
            else staging.astype(jnp.float32)
        agg = weights[0] * rows[0]
        for i in range(1, self.k_max):
            agg = agg + weights[i] * rows[i]
        return agg / jnp.sum(weights)

    def _flush_momentum_impl(self, pslab, mu, count, staging, weights,
                             scale):
        # fused aggregate + heavy-ball momentum:  mu' = β·mu + ĝ ;
        # params' = params - scale·mu'.  ``pslab`` and ``mu`` are
        # donated; the moments stay f32 whatever the staging dtype
        if self.use_pallas:
            rows = staging if staging.dtype == jnp.float32 \
                else staging.astype(jnp.float32)
            upd, mu_new = flush_momentum_pallas(
                rows, weights / jnp.sum(weights), mu, self.opt.beta1,
                interpret=self.interpret)
            new = pslab - scale * upd
            count_new = count + 1
        else:
            g = self._mean_grad(staging, weights)
            upd, st = self._pair.update(
                g, {"count": count, "mu": mu}, pslab)
            new = pslab + scale * upd
            mu_new, count_new = st["mu"], st["count"]
        return new, mu_new, count_new, self._published(new)

    def _flush_adamw_impl(self, pslab, mu, nu, count, staging, weights,
                          scale):
        # fused aggregate + AdamW with bias correction off the int32
        # count carried in state (the shared step-count convention of
        # repro.optim).  ``pslab``/``mu``/``nu`` are donated
        if self.use_pallas:
            rows = staging if staging.dtype == jnp.float32 \
                else staging.astype(jnp.float32)
            c = count + 1
            bc1, bc2 = bias_correction(c, self.opt.beta1, self.opt.beta2)
            new, mu_new, nu_new = flush_adamw_pallas(
                rows, weights / jnp.sum(weights), pslab, mu, nu,
                bc1, bc2, scale, b1=self.opt.beta1, b2=self.opt.beta2,
                eps=self.opt.eps, weight_decay=self.opt.weight_decay,
                interpret=self.interpret)
            count_new = c
        else:
            g = self._mean_grad(staging, weights)
            upd, st = self._pair.update(
                g, {"count": count, "mu": mu, "nu": nu}, pslab)
            new = pslab + scale * upd
            mu_new, nu_new = st["mu"], st["nu"]
            count_new = st["count"]
        return new, mu_new, nu_new, count_new, self._published(new)

    # ----------------------------------------------------------- chunks
    def _chunk_devices(self):
        return tuple(self._devices[i % len(self._devices)]
                     for i in range(self.shards))

    def _shard(self, slab) -> List[jax.Array]:
        """Split a full slab into device-placed chunks."""
        return [jax.device_put(slab[off:off + n], d)
                for off, n, d in zip(self.chunk_offsets, self.chunk_sizes,
                                     self._chunk_devices())]

    def _assemble(self, chunks) -> jax.Array:
        """Concatenate published chunks back into one wire-able slab."""
        return jnp.concatenate(
            [jax.device_put(c, self._devices[0]) for c in chunks])

    # ------------------------------------------------------------- API
    def stage(self, slab: jax.Array, slot: int) -> None:
        """Write one gradient slab into staging row ``slot`` (in place)."""
        assert 0 <= slot < self.k_max, (slot, self.k_max)
        slot_i = jnp.asarray(slot, jnp.int32)
        if self.shards == 1:
            self._staging = self._stage(self._staging, slab, slot_i)
            return
        slab = jnp.asarray(slab)
        for i, (off, n) in enumerate(zip(self.chunk_offsets,
                                         self.chunk_sizes)):
            chunk = jax.device_put(slab[off:off + n],
                                   self._staging[i].devices().pop())
            self._staging[i] = self._stage(self._staging[i], chunk,
                                           slot_i)

    def flush_apply(self, weights: np.ndarray, scale: float) -> jax.Array:
        """Aggregate the first ``len(weights)`` staged rows and apply the
        update.  Returns the freshly published params slab."""
        k = len(weights)
        assert 1 <= k <= self.k_max, (k, self.k_max)
        wfull = np.zeros((self.k_max,), np.float32)
        wfull[:k] = np.asarray(weights, np.float32)
        w = jnp.asarray(wfull)
        s = jnp.asarray(scale, jnp.float32)
        if self.opt.name == "sgd":
            # the historical path, bit for bit: same executable, same
            # arguments, no optimizer-state plumbing in the trace
            if self.shards == 1:
                self._slab, self._pub = self._flush(self._slab,
                                                    self._staging, w, s)
                return self._pub
            pubs = []
            for i in range(self.shards):
                self._slab[i], pub = self._flush(self._slab[i],
                                                 self._staging[i], w, s)
                pubs.append(pub)
            self._pub = self._assemble(pubs)
            return self._pub
        if self.opt.name == "momentum":
            mu = self._moments["mu"]
            if self.shards == 1:
                self._slab, self._moments["mu"], self._count, self._pub \
                    = self._flush_opt(self._slab, mu, self._count,
                                      self._staging, w, s)
                return self._pub
            pubs = []
            cnt = self._count
            for i in range(self.shards):
                self._slab[i], mu[i], cnt, pub = self._flush_opt(
                    self._slab[i], mu[i], self._count,
                    self._staging[i], w, s)
                pubs.append(pub)
            self._count = cnt
            self._pub = self._assemble(pubs)
            return self._pub
        # adamw
        mu, nu = self._moments["mu"], self._moments["nu"]
        if self.shards == 1:
            (self._slab, self._moments["mu"], self._moments["nu"],
             self._count, self._pub) = self._flush_opt(
                self._slab, mu, nu, self._count, self._staging, w, s)
            return self._pub
        pubs = []
        cnt = self._count
        for i in range(self.shards):
            self._slab[i], mu[i], nu[i], cnt, pub = self._flush_opt(
                self._slab[i], mu[i], nu[i], self._count,
                self._staging[i], w, s)
            pubs.append(pub)
        self._count = cnt
        self._pub = self._assemble(pubs)
        return self._pub

    @property
    def params_slab(self) -> jax.Array:
        """The published params slab (safe to ship / hold)."""
        return self._pub

    def params_tree(self):
        """Decode the published params into a fresh pytree."""
        return self.codec.decode(self._pub)

    def params_tree_host(self):
        """Decode + host copy — the checkpoint/snapshot form."""
        return self.codec.decode_host(self._pub)

    def reset_params(self, params) -> None:
        """Replace the live params (checkpoint restore)."""
        master = self.codec.encode_master(params)
        self._slab = master if self.shards == 1 else self._shard(master)
        self._pub = self.codec.encode(params)

    def reset_opt_state(self, state: Optional[Dict[str, Any]] = None
                        ) -> None:
        """Resync the optimizer state (checkpoint restore): ``None``
        zeros the moments and the update count; a dict (the
        :meth:`opt_state_host` form — f32 ``(P_pad,)`` arrays per moment
        name plus an int ``count``) reloads them, re-sharding along P
        exactly like the master slab."""
        if state is None:
            self._init_opt_state()
            return
        missing = [n for n in self.opt.moment_names if n not in state]
        if missing:
            raise ValueError(
                f"optimizer state is missing moment slab(s) {missing} "
                f"for {self.opt.name!r} — the checkpoint was written by "
                "a run with a different optimizer")
        self._count = jnp.asarray(int(state["count"]), jnp.int32)
        self._moments = {}
        for name in self.opt.moment_names:
            full = jnp.asarray(np.asarray(state[name], np.float32))
            assert full.shape == (self.codec.padded_size,), \
                (name, full.shape, self.codec.padded_size)
            self._moments[name] = full if self.shards == 1 \
                else self._shard(full)

    def opt_state_host(self) -> Optional[Dict[str, Any]]:
        """Host copies of the moment slabs + the int update count (the
        checkpoint form), or ``None`` for plain SGD.  Per the donation
        rules this must run under the owner's lock: the moments are
        donated buffers, and a concurrent flush would invalidate them
        mid-copy."""
        if self.opt.name == "sgd":
            return None
        out: Dict[str, Any] = {}
        for name in self.opt.moment_names:
            m = self._moments[name]
            slab = m if self.shards == 1 else self._assemble(m)
            out[name] = np.asarray(jax.device_get(slab), np.float32)
        out["count"] = int(jax.device_get(self._count))
        return out

    def wipe_staging(self) -> None:
        """Zero every staging row.  Needed when staged gradients are
        *discarded* rather than consumed by a flush: zero-weight masking
        neutralizes any finite leftover, but a non-finite row (a
        diverged gradient the restore is recovering from) would poison
        later flushes — ``0 · inf = nan``."""
        if self.shards == 1:
            self._staging = jnp.zeros_like(self._staging)
        else:
            self._staging = [jnp.zeros_like(c) for c in self._staging]

    def warmup(self) -> None:
        """Compile the stage + flush executables before the clock starts
        (one compile each per chunk shape, for any fleet size — vs the
        pre-slab server's one compile per K in 1..num_workers).  The
        warmup flush uses scale=0 over a zero row, so the params are
        bitwise unchanged."""
        self.stage(self._zero_row, 0)
        self.flush_apply(np.ones((1,), np.float32), 0.0)
        # a zero-gradient scale-0 flush leaves params AND moments
        # bitwise unchanged, but it does tick the update count — rewind
        # it so training starts at step 0 with warm executables
        if self.opt.name != "sgd":
            self._count = jnp.zeros((), jnp.int32)

    def grow(self, k_max: int) -> None:
        """Resize the staging buffer to ``k_max`` rows (elastic fleet
        admission).  Already-staged rows are preserved — a hybrid buffer
        keeps gradients staged *between* flushes, so growth mid-buffer
        must not lose them — and the new rows are zero, which the
        zero-weight masking keeps inert.  No warmup flush runs here (it
        would fold staged row 0 into the params); the next real flush
        traces the new shape, so growth costs one compile per resize —
        paid only by elastic fleets, never by a fixed one.
        Shrinking is never done: a departed worker's row just keeps
        weight 0."""
        k_max = int(k_max)
        if k_max <= self.k_max:
            return
        self.k_max = k_max
        if self.shards == 1:
            old = self._staging
            self._staging = jnp.zeros(
                (k_max, self.codec.padded_size),
                self.codec.slab_dtype).at[:old.shape[0]].set(old)
        else:
            self._staging = [
                jax.device_put(
                    jnp.zeros((k_max, old.shape[1]),
                              self.codec.slab_dtype
                              ).at[:old.shape[0]].set(old),
                    old.devices().pop())
                for old in self._staging]

    def flush_cache_size(self) -> int:
        """Number of compiled flush executables (the probe asserted to
        be exactly 1 in tests for the unsharded default, regardless of
        fleet size / K — growth via :meth:`grow` adds one entry per
        resize, and sharded staging holds one entry per distinct chunk
        shape).  With a moment-carrying optimizer the probe covers the
        fused flush+optimizer executable instead — still exactly one
        per buffer shape."""
        fn = self._flush if self.opt.name == "sgd" else self._flush_opt
        return int(fn._cache_size())


class SlabBuffer:
    """Slab-backed gradient buffer: the staged-rows counterpart of
    :class:`repro.core.buffer.GradientBuffer`.

    Gradient slabs are staged into the aggregator as they arrive (row =
    arrival order); only the parameter versions they were computed
    against are tracked host-side, for the staleness weights.  The
    flush itself is :meth:`SlabAggregator.flush_apply`.
    """

    def __init__(self, aggregator: SlabAggregator,
                 staleness_decay: float = 1.0):
        self.agg = aggregator
        self.staleness_decay = float(staleness_decay)
        self._versions: List[int] = []

    def __len__(self) -> int:
        return len(self._versions)

    def add(self, slab: jax.Array, version: int) -> None:
        self.agg.stage(slab, len(self._versions))
        self._versions.append(int(version))

    def weights(self, current_version: int) -> np.ndarray:
        """Staleness weights ``decay^(now - v_i)`` for the staged rows.
        The exponent is clamped at 0: after a checkpoint restore rolls
        the version back, an in-flight gradient can be tagged with a
        *future* version, and a negative exponent would upweight exactly
        the abandoned-history gradients the restore discards."""
        stale = np.maximum(0.0, current_version
                           - np.asarray(self._versions, np.float64))
        return self.staleness_decay ** stale

    def clear(self) -> None:
        """Forget rows that a flush just **consumed** (no wipe needed:
        consumed rows are finite values already folded into the params,
        and zero weights mask them on the next flush)."""
        self._versions = []

    def discard(self) -> None:
        """Drop staged rows **unconsumed** (checkpoint restore).  The
        rows are wiped, not just masked: a discarded gradient may be
        non-finite — that divergence can be exactly what the restore is
        recovering from — and ``0 · inf = nan`` would defeat the
        masking on every later flush."""
        self.agg.wipe_staging()
        self._versions = []
