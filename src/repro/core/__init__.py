from repro.core.buffer import GradientBuffer, aggregate_flush  # noqa: F401
from repro.core.schedule import (SCHEDULES, ThresholdSchedule,  # noqa: F401
                                 constant_schedule, cosine_schedule,
                                 exponential_schedule, linear_schedule,
                                 step_schedule)
from repro.core.simulator import PSTrainer, SimResult, WorkerPool  # noqa: F401
