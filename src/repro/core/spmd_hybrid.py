"""Group-size-annealed data parallelism — the TPU-native Smooth Switch.

The paper's threshold K(t) ("how many gradients aggregate per update")
maps onto SPMD as the *reduction-group size* of data parallelism:

  * the data-parallel mesh axis is factored into R replica groups of size
    g = axis/R.  Parameters carry an explicit leading replica axis of size
    R, sharded over the ``rep`` mesh axis, so each group owns an
    independent replica (sharded FSDP-style *within* the group);
  * a train step computes per-replica gradients with ``jax.vmap`` over the
    replica axis — XLA reduces batch gradients only *inside* each group
    (the SPMD analogue of "K gradients aggregated per update");
  * groups evolve independently ("async": divergence ≙ staleness) until a
    **merge**, where replicas are averaged (all-reduce over ``rep``) — the
    analogue of the paper's buffer flush;
  * the threshold schedule anneals g: 1 → axis (R: axis → 1), finishing in
    standard fully-synchronous data parallelism.

Memory honesty: a replica group of size g holds params/optimizer sharded
over only g×model chips, so per-chip bytes scale with 1/g.  Big models
therefore have a g_min below which the hybrid phase cannot start — reported
by `min_group_size` and recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.schedule import ThresholdSchedule, group_size_phases


def factored_mesh(devices: np.ndarray, rep: int, axis_names=("rep", "data",
                                                             "model")):
    """Reshape a (data, model) device grid into (rep, data/rep, model)."""
    d, m = devices.shape[-2], devices.shape[-1]
    flat = devices.reshape(-1, d, m)
    pods = flat.shape[0]
    assert (pods * d) % rep == 0, (pods, d, rep)
    grid = devices.reshape(rep, (pods * d) // rep, m)
    return Mesh(grid, axis_names)


def replicate_params(params, R: int):
    """Add the leading replica axis (same initial values in every group)."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (R,) + p.shape),
                        params)


def merge_replicas(params_R, alpha: float = 1.0):
    """Flush: average replicas (all-reduce over ``rep`` once sharded).

    alpha < 1 gives a partial (Lookahead-style) merge — a beyond-paper
    extension: θ_r ← α·mean + (1-α)·θ_r.

    This is the per-leaf reference; the train driver's phase switches
    use :func:`merge_replicas_slab`, which routes the same reduction
    through the slab aggregation path (the Pallas flush kernel on TPU).
    """
    def m(p):
        mean = jnp.mean(p, axis=0, keepdims=True)
        return alpha * jnp.broadcast_to(mean, p.shape) + (1 - alpha) * p
    return jax.tree.map(m, params_R)


def merge_replicas_slab(params_R, alpha: float = 1.0, *,
                        use_pallas: Optional[bool] = None,
                        interpret: Optional[bool] = None):
    """The hybrid flush on the slab path: replicas are encoded into an
    ``(R, P)`` slab matrix and averaged by the same fused weighted
    reduction the parameter server's flush uses
    (:func:`repro.kernels.ops.hybrid_flush` → ``flush_pallas`` on TPU,
    the jnp reference elsewhere), then decoded and α-blended exactly
    like :func:`merge_replicas`."""
    from repro.core.slab import slab_codec
    from repro.kernels import ops

    codec = slab_codec(jax.tree.map(lambda p: p[0], params_R))
    R = jax.tree.leaves(params_R)[0].shape[0]
    rows = jax.vmap(codec.encode)(params_R)          # (R, P_pad)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    total = ops.hybrid_flush(rows, jnp.ones((R,), jnp.float32),
                             use_pallas=use_pallas, interpret=interpret)
    mean_tree = codec.decode(total / R)

    def m(mean_leaf, p):
        mean_b = jnp.broadcast_to(mean_leaf[None], p.shape)
        return alpha * mean_b + (1 - alpha) * p
    return jax.tree.map(m, mean_tree, params_R)


def reshard_replicas(params_R, R_new: int):
    """Change the replica count at a phase switch: merge down (average
    consecutive groups) or split up (broadcast copies)."""
    R_old = jax.tree.leaves(params_R)[0].shape[0]
    if R_new == R_old:
        return params_R
    if R_new < R_old:
        assert R_old % R_new == 0
        f = R_old // R_new
        return jax.tree.map(
            lambda p: jnp.mean(p.reshape((R_new, f) + p.shape[1:]), axis=1),
            params_R)
    assert R_new % R_old == 0
    f = R_new // R_old
    return jax.tree.map(
        lambda p: jnp.repeat(p, f, axis=0), params_R)


def make_replica_step(loss_fn: Callable, opt_update: Callable):
    """Build train_step(params_R, opt_R, batch_R) -> (params, opt, metrics).

    loss_fn(params, batch) -> (loss, metrics); opt_update(grads, opt,
    params) -> (updates, new_opt).  Everything is vmapped over the leading
    replica axis, so under a ("rep","data","model") mesh the gradient
    all-reduce stays inside each replica group.
    """
    def one(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        updates, new_opt = opt_update(grads, opt_state, params)
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        return new_params, new_opt, loss, metrics

    def step(params_R, opt_R, batch_R):
        new_p, new_o, loss, metrics = jax.vmap(one)(params_R, opt_R, batch_R)
        # divergence computed inside the same executable: a second eager
        # SPMD module with collectives can interleave with the next step's
        # module across device threads and deadlock XLA-CPU's in-process
        # communicator (and costs an extra launch on TPU).
        # "replicas" is the gradient count of this step (one gradient per
        # replica group), reported by the executable itself so the
        # driver's exact num_gradients accounting is grounded in what
        # actually ran, not in what the host believes it launched.
        return new_p, new_o, {"loss": jnp.mean(loss),
                              "loss_per_replica": loss,
                              "replicas": jnp.asarray(loss.shape[0],
                                                      jnp.int32),
                              "divergence": replica_divergence(new_p), **{
            k: jnp.mean(v) for k, v in metrics.items()}}

    return step


@dataclasses.dataclass
class HybridPhase:
    t_start: int
    group_size: int
    num_replicas: int


def build_phases(schedule: ThresholdSchedule, horizon: int,
                 data_axis: int, g_min: int = 1) -> List[HybridPhase]:
    """Threshold schedule -> [(t_start, g, R)] with g clamped to >= g_min."""
    phases = []
    for t_start, g in group_size_phases(schedule, horizon, data_axis):
        g = max(g, g_min)
        R = data_axis // g
        if phases and phases[-1].group_size == g:
            continue
        phases.append(HybridPhase(t_start, g, R))
    if not phases or phases[0].t_start > 0:
        phases.insert(0, HybridPhase(0, max(g_min, 1),
                                     data_axis // max(g_min, 1)))
    return phases


def min_group_size(param_bytes: int, opt_bytes: int, model_axis: int,
                   hbm_per_chip: int = 16 * 2 ** 30,
                   act_budget_frac: float = 0.5) -> int:
    """Smallest replica-group size whose per-chip state fits in HBM."""
    budget = hbm_per_chip * (1 - act_budget_frac)
    g = 1
    while (param_bytes + opt_bytes) / (g * model_axis) > budget:
        g *= 2
    return g


def replica_param_shardings(params_template, mesh):
    """Shardings for replicated params: leading replica axis over ``rep``,
    inner dims per the logical partition rules (FSDP over ``data`` within
    each group, tensor over ``model``) — sanitized for divisibility."""
    from repro.parallel.partition import (param_logical_tree,
                                          sanitize_sharding)
    from repro.parallel.sharding import axis_rules, logical_spec

    with axis_rules(mesh):
        logical = param_logical_tree(params_template)

        def to_sharding(names, leaf):
            spec = logical_spec(names)
            full = P("rep", *spec)
            return sanitize_sharding(NamedSharding(mesh, full),
                                     (0,) + tuple(leaf.shape))

        flat_n = jax.tree.leaves(
            logical, is_leaf=lambda v: isinstance(v, tuple) and all(
                isinstance(e, (str, type(None))) for e in v))
        flat_p, treedef = jax.tree_util.tree_flatten(params_template)
        shardings = [to_sharding(n, p) for n, p in zip(flat_n, flat_p)]
        return jax.tree_util.tree_unflatten(treedef, shardings)


def replica_divergence(params_R) -> jnp.ndarray:
    """Mean L2 distance of replicas from their mean — the SPMD analogue of
    the paper's staleness (how far apart the groups have drifted)."""
    def d(p):
        mean = jnp.mean(p, axis=0, keepdims=True)
        return jnp.sum(jnp.square(p - mean))
    total = sum(jax.tree.leaves(jax.tree.map(d, params_R)))
    return jnp.sqrt(total)
