"""The common result every Trainer returns.

:class:`RunResult` supersedes the simulator's ``SimResult`` and the SPMD
driver's ad-hoc ``history`` list of dicts with one shape: a metric grid
(``grid`` in ``grid_unit`` units — virtual seconds for the simulator,
optimizer steps for SPMD, real wall-clock seconds for the cluster
runtime) with aligned per-metric series, plus update / gradient counters
and provenance (the spec that produced it).

``averaged()`` computes the paper's headline statistic — every metric
averaged over the entire training interval — and ``to_json`` /
``from_json`` round-trip the whole thing for experiment artifacts.

``extra`` key contract (``backend="cluster"``) — these keys are stable
and consumers may rely on their *shape*, not just their presence:

  * ``accounting``   — the conservation ledger: ``applied``,
    ``dropped``, ``buffered``, ``pending_round``, ``updates`` (exact,
    to the gradient, on every transport).
  * ``events``       — fault/checkpoint/phase timeline (list of dicts
    with at least ``t`` and ``event``).
  * ``start_version`` — server version at t=0 (non-zero after resume).
  * ``serve_wall_s`` — the serving-window denominator for grads/sec.
  * ``serving``      — **always present**: ``clients``,
    ``rejected_peers``, ``serve_every``, ``stats_clients``,
    ``per_client``.  Transports without a serving plane report the
    empty shape (``clients == 0`` …) rather than omitting the key, so
    consumers key on *content*, never on key presence.
  * ``telemetry``    — :meth:`repro.obs.telemetry.Telemetry.summary`
    (counters / gauges / histograms / spans_recorded) plus
    ``ledger_check`` cross-checking the counters against
    ``accounting`` (``consistent`` must be True).
  * ``listen``       — resolved ``host:port`` (host transport only).
  * ``trace_path``   — Chrome trace-event JSON path (only when the run
    was traced via ``--trace``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class RunResult:
    backend: str                       # "sim" | "spmd" | "cluster"
    mode: str                          # "sync" | "async" | "hybrid"
    schedule: Optional[str]            # schedule spec string (hybrid)
    grid_unit: str                     # "virtual_s" | "step" | "wall_s"
    grid: Tuple[float, ...]            # metric sample points
    metrics: Dict[str, Tuple[float, ...]]  # name -> series, len == len(grid)
    num_updates: int = 0               # parameter updates applied
    num_gradients: int = 0             # gradients computed
    wall_s: float = 0.0                # real (host) seconds
    spec: Optional[Dict[str, Any]] = None  # ExperimentSpec.to_dict()
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for name, series in self.metrics.items():
            if len(series) != len(self.grid):
                raise ValueError(
                    f"metric {name!r} has {len(series)} samples for a "
                    f"grid of {len(self.grid)}")

    # ----------------------------------------------------------- queries
    def averaged(self) -> Dict[str, float]:
        """Paper-style 'averaged over the entire training interval'."""
        return {k: float(sum(v) / len(v))
                for k, v in self.metrics.items() if len(v)}

    def final(self) -> Dict[str, float]:
        """Last sample of each metric."""
        return {k: float(v[-1]) for k, v in self.metrics.items() if len(v)}

    def series(self, name: str) -> Tuple[float, ...]:
        return self.metrics[name]

    # ------------------------------------------------------ serialization
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["grid"] = list(self.grid)
        d["metrics"] = {k: list(v) for k, v in self.metrics.items()}
        d["averaged"] = self.averaged()
        d["final"] = self.final()
        return d

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunResult":
        d = dict(d)
        d.pop("averaged", None)   # derived on the way out
        d.pop("final", None)
        d["grid"] = tuple(d.get("grid", ()))
        d["metrics"] = {k: tuple(v)
                        for k, v in d.get("metrics", {}).items()}
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "RunResult":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    # ---------------------------------------------------------- builders
    @classmethod
    def from_sim(cls, sim, spec=None, wall_s: float = 0.0) -> "RunResult":
        """Adapt a :class:`repro.core.simulator.SimResult`."""
        return cls(
            backend="sim", mode=sim.mode,
            schedule=getattr(spec, "schedule", None)
            if sim.mode == "hybrid" else None,
            grid_unit="virtual_s", grid=tuple(float(t) for t in sim.times),
            metrics={
                "train_loss": tuple(float(x) for x in sim.train_loss),
                "test_loss": tuple(float(x) for x in sim.test_loss),
                "test_acc": tuple(float(x) for x in sim.test_acc),
            },
            num_updates=int(sim.num_updates),
            num_gradients=int(sim.num_gradients),
            wall_s=float(wall_s),
            spec=spec.to_dict() if spec is not None else None)

    @classmethod
    def from_history(cls, history: Sequence[Dict[str, Any]], spec=None,
                     wall_s: float = 0.0, num_updates: int = 0,
                     num_gradients: int = 0,
                     metric_keys: Tuple[str, ...] = ("loss", "divergence",
                                                     "group_size",
                                                     "replicas")
                     ) -> "RunResult":
        """Adapt the SPMD driver's logged ``history`` (list of dicts)."""
        history = list(history)
        grid = tuple(float(h["step"]) for h in history)
        metrics = {k: tuple(float(h[k]) for h in history)
                   for k in metric_keys if history and k in history[0]}
        mode = getattr(spec, "mode", "hybrid")
        return cls(
            backend="spmd", mode=mode,
            schedule=getattr(spec, "schedule", None)
            if mode == "hybrid" else None,
            grid_unit="step", grid=grid, metrics=metrics,
            num_updates=num_updates, num_gradients=num_gradients,
            wall_s=float(wall_s),
            spec=spec.to_dict() if spec is not None else None,
            extra={"history": history})

    @classmethod
    def from_cluster(cls, cres, spec=None, wall_s: float = 0.0
                     ) -> "RunResult":
        """Adapt a :class:`repro.cluster.runtime.ClusterResult`.

        ``num_gradients`` is the server's applied-gradient counter,
        exactly; the full conservation ledger and the fault/checkpoint
        timeline ride along in ``extra``."""
        mode = cres.mode
        return cls(
            backend="cluster", mode=mode,
            schedule=getattr(spec, "schedule", None)
            if mode == "hybrid" else None,
            grid_unit="wall_s",
            grid=tuple(float(t) for t in cres.times),
            metrics={
                "train_loss": tuple(float(x) for x in cres.train_loss),
                "test_loss": tuple(float(x) for x in cres.test_loss),
                "test_acc": tuple(float(x) for x in cres.test_acc),
            },
            num_updates=int(cres.num_updates),
            num_gradients=int(cres.num_gradients),
            wall_s=float(wall_s),
            spec=spec.to_dict() if spec is not None else None,
            extra={"accounting": dict(cres.accounting),
                   "events": list(cres.events),
                   "start_version": int(cres.start_version),
                   # serving window only (clock starts after the fleet
                   # is ready) — the denominator for gradients/sec that
                   # is comparable across transports, unlike wall_s
                   # which includes worker-process startup
                   "serve_wall_s": float(cres.wall_s)})
