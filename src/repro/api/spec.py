"""The one experiment description every backend consumes.

:class:`ExperimentSpec` is a frozen dataclass naming *what* to run —
architecture/workload, backend (``sim`` = the paper-faithful event-driven
parameter-server simulator, ``spmd`` = the group-annealed data-parallel
driver, ``cluster`` = the wall-clock parameter-server runtime with real
concurrent workers and fault injection), aggregation mode, threshold
schedule (as a
:mod:`repro.api.schedules` spec string), worker pool or step budget,
seed, and flush/merge options.  It round-trips through JSON
(``to_json``/``from_json``), so a run is reproducible from a single
artifact:

    spec = ExperimentSpec(arch="mlp", backend="sim", mode="hybrid",
                          schedule="step:300", horizon=8.0)
    result = repro.api.run(spec)        # -> RunResult
    print(result.averaged())            # paper-style interval averages

Backend-specific fields are simply ignored by the other backends (the
simulator reads ``pool``/``horizon``; the SPMD driver reads
``steps``/``seq``/``mesh_model``; the cluster runtime reads
``cluster_workers``/``wall_budget_s``/``faults``/``transport``), so one
spec can be re-targeted by changing ``backend`` alone.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

from repro.api.schedules import parse_schedule
from repro.cluster.faults import FaultPlan
from repro.cluster.transport import TRANSPORTS
from repro.core.simulator import WorkerPool

BACKENDS = ("sim", "spmd", "cluster")
MODES = ("sync", "async", "hybrid")
FLUSH_MODES = ("sum", "mean")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Declarative description of one training experiment."""
    # what + where
    arch: str = "mlp"              # sim: workload name; spmd: registry arch
    backend: str = "sim"
    mode: str = "hybrid"
    schedule: Optional[str] = "step:300"   # spec string; None for sync/async
    seed: int = 0
    # optimization
    lr: float = 0.01
    batch: int = 32
    optimizer: str = "sgd"         # server-side slab optimizer:
    #                                "sgd" (historical flush, bit for
    #                                bit) | "momentum" | "adamw" —
    #                                moments live as f32 slab buffers
    #                                inside the fused flush executable
    beta1: float = 0.9             # momentum decay / AdamW b1
    beta2: float = 0.95            # AdamW b2 (second-moment decay)
    weight_decay: float = 0.0      # AdamW decoupled weight decay
    # simulator backend (virtual time)
    horizon: float = 20.0          # virtual seconds
    sample_every: float = 0.5      # metric-grid spacing (virtual seconds)
    pool: WorkerPool = WorkerPool()
    flush_mode: str = "sum"        # buffer flush: "sum" | "mean"
    staleness_decay: float = 1.0   # <1 = staleness-weighted flush
    # SPMD backend (steps)
    steps: int = 100
    seq: int = 128
    merge_alpha: float = 1.0       # partial (Lookahead-style) merges
    mesh_model: int = 1            # model-parallel axis size
    smoke: bool = True             # reduced config / dataset sizes
    log_every: int = 10
    # cluster backend (wall clock, real concurrent workers)
    cluster_workers: int = 4
    wall_budget_s: float = 5.0     # real seconds of training
    wall_sample_every_s: float = 0.25   # metric-grid spacing (real s)
    max_gradients: Optional[int] = None  # stop after N applied gradients
    faults: FaultPlan = FaultPlan()      # stragglers / kills / checkpoints
    transport: str = "inproc"  # worker wire: inproc | socket | proc | host
    listen: str = "127.0.0.1:0"    # host transport: leader bind address
    #                                HOST:PORT (port 0 = pick; the
    #                                resolved address is printed and
    #                                recorded in the run's events)
    heartbeat_s: float = 2.0       # host transport: leader-liveness PING
    #                                cadence (0 disables; workers and
    #                                serve clients size their hung-leader
    #                                watchdog from it)
    serve_every: int = 1           # serving plane: push every Nth params
    #                                version to serve clients (the
    #                                staleness-vs-bandwidth knob; 1 =
    #                                every version)
    max_workers: Optional[int] = None   # host transport: elastic
    #                                admission ceiling — JOINs beyond
    #                                cluster_workers grow the fleet up
    #                                to this many ids; None = fixed
    #                                membership (pre-elastic behavior,
    #                                bit for bit)
    slab_dtype: str = "f32"        # gradient/params slab precision on
    #                                the staging buffer and the wire:
    #                                "f32" (pinned v1 layout, bitwise-
    #                                reproducible) | "bf16" (half the
    #                                wire bytes; master params + flush
    #                                reduction stay f32)
    zoo_scale: float = 0.25        # zoo:* workloads: width multiplier
    #                                applied to the registry config
    #                                (1.0 = the full published tier)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, "
                             f"got {self.mode!r}")
        if self.flush_mode not in FLUSH_MODES:
            raise ValueError(f"flush_mode must be one of {FLUSH_MODES}, "
                             f"got {self.flush_mode!r}")
        if self.transport not in TRANSPORTS:
            raise ValueError(f"transport must be one of {TRANSPORTS}, "
                             f"got {self.transport!r}")
        if self.transport == "host":
            # fail at spec construction, not as a hub that can't bind
            from repro.cluster.hostlink import parse_hostport
            parse_hostport(self.listen)
        if isinstance(self.pool, dict):   # from_json convenience
            object.__setattr__(self, "pool", WorkerPool(**self.pool))
        if isinstance(self.faults, dict):  # from_json convenience
            object.__setattr__(self, "faults", FaultPlan(**self.faults))
        if self.mode == "hybrid":
            if not self.schedule:
                raise ValueError("hybrid mode requires a schedule spec "
                                 '(e.g. "step:300")')
            # validate the spec string eagerly; worker count is irrelevant
            # for syntax, any plausible value will do
            parse_schedule(self.schedule, max(2, self.pool.num_workers))
        for field in ("steps", "horizon", "sample_every", "batch", "seq",
                      "mesh_model", "log_every", "cluster_workers",
                      "wall_budget_s", "wall_sample_every_s"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be > 0, "
                                 f"got {getattr(self, field)!r}")
        if self.max_gradients is not None and self.max_gradients <= 0:
            raise ValueError(f"max_gradients must be None or > 0, "
                             f"got {self.max_gradients!r}")
        if self.heartbeat_s < 0:
            raise ValueError(f"heartbeat_s must be >= 0 (0 disables), "
                             f"got {self.heartbeat_s!r}")
        if self.serve_every < 1:
            raise ValueError(f"serve_every must be >= 1, "
                             f"got {self.serve_every!r}")
        if self.slab_dtype not in ("f32", "bf16"):
            raise ValueError('slab_dtype must be "f32" or "bf16", '
                             f"got {self.slab_dtype!r}")
        from repro.optim.slab_form import OPTIMIZER_NAMES
        if self.optimizer not in OPTIMIZER_NAMES:
            raise ValueError(f"optimizer must be one of "
                             f"{OPTIMIZER_NAMES}, got {self.optimizer!r}")
        if not (0.0 <= self.beta1 < 1.0 and 0.0 <= self.beta2 < 1.0):
            raise ValueError(f"beta1/beta2 must be in [0, 1), got "
                             f"{self.beta1!r}/{self.beta2!r}")
        if self.weight_decay < 0:
            raise ValueError(f"weight_decay must be >= 0, "
                             f"got {self.weight_decay!r}")
        if self.zoo_scale <= 0:
            raise ValueError(f"zoo_scale must be > 0, "
                             f"got {self.zoo_scale!r}")
        if self.max_workers is not None:
            if self.transport != "host":
                raise ValueError(
                    "max_workers (elastic admission) requires "
                    'transport="host", got '
                    f"transport={self.transport!r}")
            if self.max_workers < self.cluster_workers:
                raise ValueError(
                    f"max_workers must be >= cluster_workers "
                    f"({self.cluster_workers}), "
                    f"got {self.max_workers!r}")

    # --------------------------------------------------------- derivation
    def with_(self, **changes) -> "ExperimentSpec":
        """Functional update (``dataclasses.replace`` with validation)."""
        return dataclasses.replace(self, **changes)

    def slab_optimizer(self):
        """The server-side optimizer config
        (:class:`repro.optim.SlabOptimizer`) this spec names."""
        from repro.optim.slab_form import SlabOptimizer
        return SlabOptimizer(self.optimizer, beta1=self.beta1,
                             beta2=self.beta2,
                             weight_decay=self.weight_decay)

    # ------------------------------------------------------ serialization
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)   # recurses into pool and faults
        # canonical JSON form for the fault pair lists (tuples would
        # come back as lists and break dict-level equality)
        d["faults"] = {**d["faults"],
                       "stragglers": [list(p) for p
                                      in d["faults"]["stragglers"]],
                       "kill": [list(p) for p in d["faults"]["kill"]]}
        return d

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown ExperimentSpec fields: "
                             f"{sorted(unknown)}")
        return cls(**d)   # __post_init__ coerces a dict pool

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())
