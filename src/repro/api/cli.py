"""``python -m repro`` — one CLI for every surface.

Subcommands:
  run       execute an ExperimentSpec (flags and/or --spec JSON file) on
            any backend (sim | spmd | cluster) and emit a RunResult JSON
  simulate  alias for ``run --backend sim`` (paper-faithful simulator);
            ``--smoke`` picks a seconds-scale CI configuration
  serve     batched prefill+decode demo (repro.launch.serve) — unless
            ``--listen HOST:PORT`` is given, which starts a multi-host
            cluster *leader* (= ``run --backend cluster --transport
            host``) that remote workers ``join``
  join      join a cluster leader as one or more workers: the spec
            arrives over the wire, the workload is rebuilt locally
            (repro.cluster.hostlink)
  infer     connect to a training leader as a read-only serve client:
            stream fresh params and run inference on every pushed
            version (repro.serve)
  top       connect to a training leader as a read-only *stats* client:
            stream live telemetry — grads/sec, staleness p50/p99,
            ledger state — without perturbing the run (repro.obs.top)
  trace     run a cluster experiment with tracing on and write a
            Chrome trace-event / Perfetto JSON timeline: sugar for
            ``run --backend cluster --trace FILE``
  dryrun    multi-pod lower/compile analysis (repro.launch.dryrun, with
            the 512 forced host devices set up before jax imports)
  bench     paper tables + kernel microbenches (benchmarks.run)
  schedules list the registered threshold-schedule families

Every entry point shares one logging setup (``setup_logging``):
per-component ``repro.<component>`` logger names, one format, a
``--log-level`` flag (default WARNING).

Examples:
  python -m repro simulate --smoke
  python -m repro run --backend spmd --arch xlstm-350m --smoke \
      --steps 40 --mode hybrid --schedule step:10 --out /tmp/result.json
  python -m repro run --backend cluster --arch mlp --cluster-workers 4 \
      --wall-budget 10 --straggler 0:0.1 --kill 1:4 --respawn-after 1
  python -m repro run --backend cluster --arch mlp --transport proc \
      --cluster-workers 2 --wall-budget 8 --max-gradients 100
  # terminal 1 (leader), terminal 2+ (workers, possibly other machines):
  python -m repro serve --listen 0.0.0.0:5555 --arch mlp \
      --cluster-workers 2 --wall-budget 30
  python -m repro join LEADER_HOST:5555 --workers 2
  python -m repro infer LEADER_HOST:5555 --requests 8
  python -m repro top LEADER_HOST:5555 --duration 10
  python -m repro trace /tmp/t.json --arch mlp --transport proc \
      --cluster-workers 2 --wall-budget 5
  python -m repro run --spec experiment.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.api.schedules import schedule_help
from repro.api.spec import BACKENDS, FLUSH_MODES, MODES, ExperimentSpec

_LOG_LEVELS = ("debug", "info", "warning", "error")


def setup_logging(level: Optional[str] = None) -> None:
    """The one logging setup every CLI entry point shares (``repro
    run``, ``join``, ``infer``, ``top``, ...): per-component
    ``repro.<component>`` logger names, one line format, stderr.
    Idempotent — ``basicConfig`` is a no-op once a handler exists, so
    nested entry points (e.g. ``serve --listen`` forwarding into
    ``run``) keep the first configuration."""
    import logging
    lvl = getattr(logging, (level or "warning").upper(), logging.WARNING)
    logging.basicConfig(
        level=lvl,
        format="%(asctime)s.%(msecs)03d %(name)s %(levelname)s: "
               "%(message)s",
        datefmt="%H:%M:%S", stream=sys.stderr)
    # scope the chosen level to this package: --log-level debug must
    # not unleash every third-party library's debug firehose
    logging.getLogger("repro").setLevel(lvl)

# CLI flag -> (spec field, type).  Every flag defaults to None so that
# only explicitly-passed flags override the --spec file / dataclass
# defaults.
_SPEC_FLAGS = [
    ("--arch", "arch", str, "workload (sim: mlp|cnn-mnist|cnn-cifar; "
                            "spmd: registry arch)"),
    ("--mode", "mode", str, f"one of {MODES}"),
    ("--schedule", "schedule", str,
     'threshold schedule spec, e.g. "step:300"'),
    ("--seed", "seed", int, "RNG seed"),
    ("--lr", "lr", float, "learning rate"),
    ("--batch", "batch", int, "per-gradient batch size"),
    ("--optimizer", "optimizer", str,
     "server-side slab optimizer: sgd (historical flush, bit for bit, "
     "default) | momentum | adamw — moments live as f32 slab buffers "
     "inside the fused flush executable"),
    ("--beta1", "beta1", float,
     "momentum decay / AdamW b1 (default 0.9)"),
    ("--beta2", "beta2", float,
     "AdamW second-moment decay b2 (default 0.95)"),
    ("--weight-decay", "weight_decay", float,
     "AdamW decoupled weight decay (default 0)"),
    ("--horizon", "horizon", float, "sim: virtual seconds"),
    ("--sample-every", "sample_every", float, "sim: metric grid spacing"),
    ("--flush-mode", "flush_mode", str, f"sim: one of {FLUSH_MODES}"),
    ("--staleness-decay", "staleness_decay", float,
     "sim: staleness weight decay"),
    ("--steps", "steps", int, "spmd: optimizer steps"),
    ("--seq", "seq", int, "spmd: sequence length"),
    ("--merge-alpha", "merge_alpha", float, "spmd: partial-merge factor"),
    ("--mesh-model", "mesh_model", int, "spmd: model-parallel axis size"),
    ("--log-every", "log_every", int, "spmd: metric logging interval"),
    ("--cluster-workers", "cluster_workers", int,
     "cluster: worker count (threads or processes, see --transport)"),
    ("--transport", "transport", str,
     "cluster: worker wire — inproc (threads+queue, default), socket "
     "(threads over TCP slab frames), proc (one OS process per worker "
     "over Unix-domain sockets), host (bind --listen and wait for "
     "`repro join` workers, possibly from other machines)"),
    ("--listen", "listen", str,
     "cluster host transport: leader bind address HOST:PORT (port 0 = "
     "pick one; the resolved address is printed and recorded in the "
     "run's events)"),
    ("--wall-budget", "wall_budget_s", float,
     "cluster: wall-clock training budget (real seconds)"),
    ("--wall-sample-every", "wall_sample_every_s", float,
     "cluster: metric grid spacing (real seconds)"),
    ("--max-gradients", "max_gradients", int,
     "cluster: stop after N applied gradients"),
    ("--heartbeat", "heartbeat_s", float,
     "cluster host transport: leader-liveness PING cadence in seconds "
     "(0 disables; workers and serve clients size their hung-leader "
     "watchdog from it)"),
    ("--serve-every", "serve_every", int,
     "serving plane: push every Nth params version to serve clients "
     "(staleness-vs-bandwidth knob; default 1 = every version)"),
    ("--max-workers", "max_workers", int,
     "cluster host transport: elastic admission ceiling — `repro join` "
     "workers beyond --cluster-workers grow the fleet online up to "
     "this many ids (default: --cluster-workers, i.e. fixed "
     "membership)"),
    ("--slab-dtype", "slab_dtype", str,
     "cluster: gradient/params slab precision on the staging buffer "
     "and the wire — f32 (pinned v1 layout, bitwise-reproducible, "
     "default) | bf16 (half the wire bytes; master params and the "
     "flush reduction stay f32)"),
    ("--zoo-scale", "zoo_scale", float,
     "zoo:* workloads: width multiplier applied to the registry "
     "config (default 0.25; 1.0 = the full published tier)"),
]
# fault-plan flags (cluster backend): merged into spec.faults
_FAULT_FLAGS = [
    ("--straggler", "stragglers", 'WID:SECONDS[,WID:SECONDS...]',
     "cluster: extra seconds of delay per gradient for these workers"),
    ("--kill", "kill", 'WID:AT_S[,WID:AT_S...]',
     "cluster: kill these workers at the given wall-clock seconds"),
    ("--respawn-after", "respawn_after_s", float,
     "cluster: respawn killed workers after this many seconds"),
    ("--ckpt-every", "checkpoint_every_s", float,
     "cluster: server checkpoint cadence (needs --ckpt-dir)"),
    ("--restore-at", "restore_at_s", float,
     "cluster: restore the latest checkpoint at this wall-clock second"),
]
_POOL_FLAGS = [
    ("--workers", "num_workers", int, "sim: worker count"),
    ("--base-compute", "base_compute", float,
     "sim: seconds per gradient (virtual)"),
    ("--delay-fraction", "delay_fraction", float,
     "sim: fraction of delayed workers"),
    ("--delay-std", "delay_std", float, "sim: delay std (virtual s)"),
]


def _add_spec_flags(ap: argparse.ArgumentParser, backend_flag: bool):
    ap.add_argument("--spec", default=None, metavar="FILE",
                    help="ExperimentSpec JSON file (flags override it)")
    if backend_flag:
        ap.add_argument("--backend", choices=BACKENDS, default=None)
    for flag, dest, typ, hlp in _SPEC_FLAGS:
        ap.add_argument(flag, dest=dest, type=typ, default=None, help=hlp)
    for flag, dest, typ, hlp in _POOL_FLAGS:
        ap.add_argument(flag, dest=dest, type=typ, default=None, help=hlp)
    for flag, dest, typ, hlp in _FAULT_FLAGS:
        if isinstance(typ, str):     # WID:SECONDS pair lists
            ap.add_argument(flag, dest=f"fault_{dest}", metavar=typ,
                            default=None, help=hlp)
        else:
            ap.add_argument(flag, dest=f"fault_{dest}", type=typ,
                            default=None, help=hlp)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=None, help="reduced config / dataset sizes")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="write the full RunResult JSON here")
    ap.add_argument("--save-spec", default=None, metavar="FILE",
                    help="write the resolved ExperimentSpec JSON here")
    ap.add_argument("--ckpt-dir", default=None,
                    help="spmd/cluster: checkpoint directory")
    ap.add_argument("--resume-from", default=None, metavar="CKPT",
                    help="cluster: restore this checkpoint into the "
                         "server before training (K(t) resumes from the "
                         "restored step)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-step logs; print only the result")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="cluster: write a Chrome trace-event / "
                         "Perfetto JSON timeline of the run here (load "
                         "in ui.perfetto.dev or chrome://tracing)")
    ap.add_argument("--prom-port", type=int, default=None, metavar="N",
                    help="cluster: serve a Prometheus /metrics endpoint "
                         "on this port for the duration of the run "
                         "(live ledger, staleness quantiles, wire byte "
                         "counters; 0 = pick a free port, logged as a "
                         "prom_listening event)")
    ap.add_argument("--join-secret", default=None, metavar="SECRET",
                    help="cluster host transport: require joiners to "
                         "prove this shared secret (HMAC challenge/"
                         "response on JOIN); an invocation credential, "
                         "never written into the spec (env: "
                         "REPRO_JOIN_SECRET)")
    ap.add_argument("--log-level", choices=_LOG_LEVELS, default=None,
                    help="repro.* logger level (default warning)")


def _build_spec(args, backend: Optional[str]) -> ExperimentSpec:
    spec = ExperimentSpec.load(args.spec) if args.spec else ExperimentSpec()
    changes = {}
    if backend:
        changes["backend"] = backend
    for _, field, _, _ in _SPEC_FLAGS:
        v = getattr(args, field)
        if v is not None:
            changes[field] = v
    if args.smoke is not None:
        changes["smoke"] = args.smoke
    pool_changes = {f: getattr(args, f) for _, f, _, _ in _POOL_FLAGS
                    if getattr(args, f) is not None}
    if pool_changes:
        import dataclasses
        changes["pool"] = dataclasses.replace(spec.pool, **pool_changes)
    fault_changes = {}
    for _, field, typ, _ in _FAULT_FLAGS:
        v = getattr(args, f"fault_{field}")
        if v is not None:
            if isinstance(typ, str):
                from repro.cluster.faults import parse_fault_pairs
                v = parse_fault_pairs(v)
            fault_changes[field] = v
    if fault_changes:
        import dataclasses
        changes["faults"] = dataclasses.replace(spec.faults,
                                                **fault_changes)
    return spec.with_(**changes) if changes else spec


def _cmd_run(args, forced_backend: Optional[str] = None) -> int:
    setup_logging(getattr(args, "log_level", None))
    spec = _build_spec(args, forced_backend or args_backend(args))
    if args.save_spec:
        spec.save(args.save_spec)
    trace = getattr(args, "trace", None)
    if trace and spec.backend != "cluster":
        print(f"warning: --trace records the cluster runtime's "
              f"timeline and does nothing on backend="
              f"{spec.backend!r}; ignoring it", file=sys.stderr)
        trace = None
    prom_port = getattr(args, "prom_port", None)
    if prom_port is not None and spec.backend != "cluster":
        print(f"warning: --prom-port exposes the cluster runtime's "
              f"live stats and does nothing on backend="
              f"{spec.backend!r}; ignoring it", file=sys.stderr)
        prom_port = None
    from repro.api import trainers
    if spec.backend == "spmd":
        trainer = trainers.SpmdTrainer(ckpt_dir=args.ckpt_dir,
                                       verbose=not args.quiet)
    elif spec.backend == "cluster":
        from repro.cluster.trainer import ClusterTrainer
        join_secret = getattr(args, "join_secret", None) \
            or os.environ.get("REPRO_JOIN_SECRET") or None
        trainer = ClusterTrainer(ckpt_dir=args.ckpt_dir,
                                 resume_from=args.resume_from,
                                 verbose=not args.quiet, trace=trace,
                                 join_secret=join_secret,
                                 prom_port=prom_port)
    else:
        trainer = trainers.SimulatorTrainer()
    result = trainer.run(spec)
    if args.out:
        result.save(args.out)
        d = result.to_dict()
        summary = {k: d[k]
                   for k in ("backend", "mode", "schedule", "num_updates",
                             "num_gradients", "wall_s", "averaged",
                             "final")}
        print(json.dumps(summary, indent=2))
        print(f"full RunResult written to {args.out}", file=sys.stderr)
    else:
        print(result.to_json())
    return 0


def args_backend(args) -> Optional[str]:
    return getattr(args, "backend", None)


def _cmd_simulate(args) -> int:
    if args.smoke and not args.spec:
        # seconds-scale CI configuration unless explicitly overridden
        # (never applied over a --spec file: only real flags override it)
        if args.horizon is None:
            args.horizon = 3.0
        if args.num_workers is None:
            args.num_workers = 5
        if args.schedule is None and args.mode in (None, "hybrid"):
            args.schedule = "step:50"
    return _cmd_run(args, forced_backend="sim")


def _forward(module_main, argv: List[str]) -> int:
    rc = module_main(argv)
    return int(rc) if rc else 0


def _cmd_join(rest: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro join",
        description="join a repro cluster leader as one or more workers"
                    " — the experiment spec arrives over the wire in "
                    "the leader handshake, so this host only needs the "
                    "repro package (repro.cluster.hostlink)")
    ap.add_argument("address", metavar="HOST:PORT",
                    help="the leader's listen address "
                         "(repro serve --listen HOST:PORT)")
    ap.add_argument("--worker-id", type=int, default=None,
                    help="request a specific worker id / data shard "
                         "(default: the leader leases the lowest free "
                         "one)")
    ap.add_argument("--workers", type=int, default=1,
                    help="join this many workers, one OS process each "
                         "(default 1)")
    ap.add_argument("--connect-timeout", "--join-timeout",
                    dest="connect_timeout", type=float, default=60.0,
                    help="keep retrying the leader (refused/busy, with "
                         "jittered backoff) for this many seconds "
                         "before exiting 4 with the leader's reason "
                         "(the leader may not be up yet)")
    ap.add_argument("--join-secret", default=None, metavar="SECRET",
                    help="shared secret for a leader started with "
                         "--join-secret (answers its HMAC challenge; "
                         "env: REPRO_JOIN_SECRET)")
    ap.add_argument("--reconnect", dest="reconnect_s", type=float,
                    default=5.0, metavar="SECONDS",
                    help="after a mid-run connection drop, try to "
                         "rejoin the same worker-id lease for this "
                         "many seconds before giving up cleanly "
                         "(default 5; 0 disables)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress join progress logs")
    ap.add_argument("--log-level", choices=_LOG_LEVELS, default=None,
                    help="repro.* logger level (default warning)")
    args = ap.parse_args(rest)
    setup_logging(args.log_level)
    secret = args.join_secret \
        or os.environ.get("REPRO_JOIN_SECRET") or None
    from repro.cluster.hostlink import join_main
    code = join_main(args.address, worker_id=args.worker_id,
                     workers=args.workers,
                     connect_timeout=args.connect_timeout,
                     verbose=not args.quiet, secret=secret,
                     reconnect_s=args.reconnect_s)
    sys.stdout.flush()
    sys.stderr.flush()
    # skip interpreter finalization: this process ran a JAX runtime and
    # fast exits intermittently abort in C++ teardown (see
    # repro.cluster.mptransport._proc_worker_main)
    os._exit(code)


def _cmd_infer(rest: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro infer",
        description="read-only serve client: subscribe to a training "
                    "leader's params broadcast and run inference on "
                    "every pushed version (repro.serve) — the leader's "
                    "WELCOME carries the spec, so this host only needs "
                    "the repro package")
    ap.add_argument("address", metavar="HOST:PORT",
                    help="the leader's listen address "
                         "(repro serve --listen HOST:PORT)")
    ap.add_argument("--requests", type=int, default=8,
                    help="run this many inference requests (default 8)")
    ap.add_argument("--duration", type=float, default=None,
                    help="stop after this many seconds even if "
                         "--requests has not been reached")
    ap.add_argument("--batch", type=int, default=2,
                    help="inference batch size (prompts per request)")
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="prompt length in tokens (lm archs)")
    ap.add_argument("--gen-len", type=int, default=8,
                    help="tokens to generate per request (lm archs)")
    ap.add_argument("--connect-timeout", type=float, default=60.0,
                    help="keep retrying the leader for this many "
                         "seconds (the leader may not be up yet)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-request logs")
    ap.add_argument("--log-level", choices=_LOG_LEVELS, default=None,
                    help="repro.* logger level (default warning)")
    args = ap.parse_args(rest)
    setup_logging(args.log_level)
    from repro.serve.client import infer_main
    code = infer_main(args.address, requests=args.requests,
                      duration_s=args.duration, batch=args.batch,
                      prompt_len=args.prompt_len, gen_len=args.gen_len,
                      connect_timeout=args.connect_timeout,
                      verbose=not args.quiet)
    sys.stdout.flush()
    sys.stderr.flush()
    # skip interpreter finalization: this process ran a JAX runtime (see
    # _cmd_join)
    os._exit(code)


def _cmd_top(rest: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro top",
        description="read-only stats client: stream a training "
                    "leader's live telemetry — grads/sec, staleness "
                    "p50/p99, conservation-ledger state — one line per "
                    "push, without perturbing the run (repro.obs.top)")
    ap.add_argument("address", metavar="HOST:PORT",
                    help="the leader's listen address "
                         "(repro serve --listen HOST:PORT)")
    ap.add_argument("--count", type=int, default=None,
                    help="stop after this many stats rows")
    ap.add_argument("--duration", type=float, default=None,
                    help="stop after this many seconds")
    ap.add_argument("--connect-timeout", type=float, default=30.0,
                    help="keep retrying the leader for this many "
                         "seconds (the leader may not be up yet)")
    ap.add_argument("--prom-port", type=int, default=None, metavar="N",
                    help="also serve the newest stats push as a "
                         "Prometheus /metrics endpoint on this port "
                         "(0 = pick a free port; printed at startup)")
    ap.add_argument("--log-level", choices=_LOG_LEVELS, default=None,
                    help="repro.* logger level (default warning)")
    args = ap.parse_args(rest)
    setup_logging(args.log_level)
    # no JAX runtime in this process (it only renders JSON), so a
    # normal return is safe — no os._exit needed
    from repro.obs.top import top_main
    return top_main(args.address, count=args.count,
                    duration_s=args.duration,
                    connect_timeout=args.connect_timeout,
                    prom_port=args.prom_port)


def _cmd_serve_leader(rest: List[str]) -> int:
    """``repro serve --listen HOST:PORT`` — the multi-host leader: sugar
    for ``run --backend cluster --transport host --listen ...``."""
    ap = argparse.ArgumentParser(
        prog="python -m repro serve --listen HOST:PORT",
        description="multi-host cluster leader: bind HOST:PORT, wait "
                    "for `repro join` workers, train, report")
    _add_spec_flags(ap, backend_flag=False)
    args = ap.parse_args(rest)
    if args.transport not in (None, "host"):
        # --listen only means something on the host transport; silently
        # training locally while remote joins dial a port nobody bound
        # would be the worst possible failure mode
        print(f"error: --listen is the host transport's bind address "
              f"and cannot be combined with --transport "
              f"{args.transport} (drop --transport, or use "
              f"`repro run --backend cluster`)", file=sys.stderr)
        return 2
    args.transport = "host"
    try:
        return _cmd_run(args, forced_backend="cluster")
    except (ValueError, FileNotFoundError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


def _cmd_passthrough(name: str, rest: List[str]) -> int:
    if name == "serve":
        if any(a == "--listen" or a.startswith("--listen=")
               for a in rest):
            return _cmd_serve_leader(rest)
        from repro.launch.serve import main as serve_main
        return _forward(serve_main, rest)
    if name == "dryrun":
        # topology must be forced before jax (and hence dryrun) imports
        from repro.launch._xla_env import force_host_device_count
        force_host_device_count()
        from repro.launch.dryrun import main as dryrun_main
        return _forward(dryrun_main, rest)
    if name == "bench":
        try:
            from benchmarks.run import main as bench_main
        except ImportError:
            # the top-level benchmarks package lives next to src/ in the
            # repo; resolve it relative to the repro package so
            # `python -m repro bench` works from any CWD (repro is a
            # namespace package: use __path__, __file__ is None)
            import repro
            pkg_dir = os.path.abspath(list(repro.__path__)[0])
            root = os.path.dirname(os.path.dirname(pkg_dir))
            if root not in sys.path:
                sys.path.insert(0, root)
            try:
                from benchmarks.run import main as bench_main
            except ImportError as e:
                print(f"benchmarks package not importable ({e}; looked "
                      f"next to the repro package in {root})",
                      file=sys.stderr)
                return 1
        return _forward(bench_main, rest)
    raise AssertionError(name)


# these forward their whole tail to the wrapped driver's own argparse
# (dispatched before the main parse: argparse.REMAINDER cannot capture
# leading options)
_PASSTHROUGH = {
    "serve": "serving demo (repro.launch.serve args), or the multi-host "
             "cluster leader with --listen HOST:PORT",
    "dryrun": "compile-only analysis (repro.launch.dryrun args)",
    "bench": "benchmark suite (benchmarks.run args)",
}


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "join":
        # dispatched before the main parse (positional HOST:PORT)
        return _cmd_join(argv[1:])
    if argv and argv[0] == "infer":
        return _cmd_infer(argv[1:])
    if argv and argv[0] == "top":
        return _cmd_top(argv[1:])
    if argv and argv[0] in _PASSTHROUGH:
        return _cmd_passthrough(argv[0], argv[1:])

    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_run = sub.add_parser("run", help="execute an ExperimentSpec")
    _add_spec_flags(p_run, backend_flag=True)
    p_sim = sub.add_parser("simulate",
                           help="run the paper-faithful simulator backend")
    _add_spec_flags(p_sim, backend_flag=False)
    p_trace = sub.add_parser(
        "trace", help="run a cluster experiment with tracing on and "
                      "write a Perfetto/Chrome trace-event JSON "
                      "timeline (trace FILE [run flags])")
    p_trace.add_argument("tracefile", metavar="FILE",
                         help="trace JSON output path")
    _add_spec_flags(p_trace, backend_flag=False)
    for name, hlp in _PASSTHROUGH.items():
        sub.add_parser(name, help=hlp, add_help=False)
    sub.add_parser("join", help="join a cluster leader as one or more "
                                "workers (join HOST:PORT --workers N)",
                   add_help=False)
    sub.add_parser("infer", help="read-only serve client: stream fresh "
                                 "params from a training leader and run "
                                 "inference (infer HOST:PORT)",
                   add_help=False)
    sub.add_parser("top", help="read-only stats client: stream live "
                               "telemetry from a training leader "
                               "(top HOST:PORT)",
                   add_help=False)
    sub.add_parser("schedules", help="list threshold-schedule families")

    args = ap.parse_args(argv)

    if args.cmd == "trace":
        # sugar for `run --backend cluster --trace FILE`
        if args.trace is None:
            args.trace = args.tracefile
        try:
            return _cmd_run(args, forced_backend="cluster")
        except (ValueError, FileNotFoundError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    if args.cmd in ("run", "simulate"):
        try:
            return _cmd_run(args) if args.cmd == "run" \
                else _cmd_simulate(args)
        except (ValueError, FileNotFoundError) as e:
            # spec/schedule validation and missing --spec files are user
            # errors, not crashes
            print(f"error: {e}", file=sys.stderr)
            return 2
    if args.cmd == "schedules":
        print("registered threshold-schedule families "
              "(repro.api.parse_schedule):")
        print(schedule_help())
        return 0
    return _cmd_passthrough(args.cmd, [])


if __name__ == "__main__":
    sys.exit(main())
