"""Unified experiment layer: one spec, one Trainer protocol, one CLI.

The paper's contribution is a single knob — the threshold schedule K(t)
— so the repo exposes a single experiment surface for it:

    from repro.api import ExperimentSpec, run

    spec = ExperimentSpec(arch="mlp", backend="sim", mode="hybrid",
                          schedule="step:300", horizon=8.0)
    result = run(spec)                  # -> RunResult
    print(result.averaged())            # paper-style interval averages
    result.save("result.json")          # reproducible artifact

Change ``backend="spmd"`` and the same spec drives the group-annealed
SPMD driver on real devices; ``backend="cluster"`` runs a wall-clock
parameter server with real concurrent workers and fault injection
(:mod:`repro.cluster`).  ``python -m repro`` exposes the same pieces as
subcommands (run / simulate / serve / dryrun / bench).

Pieces:
  * :class:`ExperimentSpec` — frozen, JSON-round-tripping description
    of one experiment (:mod:`repro.api.spec`);
  * ``parse_schedule`` / ``register_schedule`` — the K(t) spec
    mini-language, e.g. ``"step:300"``, ``"cosine:horizon=2000"``,
    ``"exp:horizon=2000,rate=5"`` (:mod:`repro.api.schedules`);
  * :class:`Trainer` protocol with :class:`SimulatorTrainer` and
    :class:`SpmdTrainer` adapters (:mod:`repro.api.trainers`);
  * :class:`RunResult` — the common metric-grid result with
    ``averaged()`` paper tables and JSON export
    (:mod:`repro.api.result`).
"""
from repro.api.result import RunResult  # noqa: F401
from repro.api.schedules import (SCHEDULE_FAMILIES,  # noqa: F401
                                 ScheduleFamily, parse_schedule,
                                 register_schedule, schedule_help)
from repro.api.spec import (BACKENDS, FLUSH_MODES, MODES,  # noqa: F401
                            TRANSPORTS, ExperimentSpec)
from repro.api.trainers import (SIM_WORKLOADS, TRAINERS,  # noqa: F401
                                SimulatorTrainer, SpmdTrainer, Trainer,
                                get_trainer, register_sim_workload, run)
from repro.cluster.faults import FaultPlan  # noqa: F401

__all__ = [
    "BACKENDS", "MODES", "FLUSH_MODES", "TRANSPORTS", "ExperimentSpec",
    "RunResult",
    "FaultPlan", "SCHEDULE_FAMILIES", "ScheduleFamily", "parse_schedule",
    "register_schedule", "schedule_help", "Trainer", "SimulatorTrainer",
    "SpmdTrainer", "TRAINERS", "SIM_WORKLOADS",
    "get_trainer", "register_sim_workload", "run",
]
# ClusterTrainer deliberately stays out of the eager exports: the
# cluster runtime loads lazily (via TRAINERS["cluster"] / get_trainer,
# or `from repro.cluster.trainer import ClusterTrainer`).
