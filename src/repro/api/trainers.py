"""Trainer protocol + the two backend adapters.

A :class:`Trainer` turns an :class:`~repro.api.spec.ExperimentSpec` into
a :class:`~repro.api.result.RunResult`:

  * :class:`SimulatorTrainer` — the paper-faithful event-driven
    parameter-server simulator (``backend="sim"``).  ``spec.arch`` names
    a registered simulator workload (``mlp``, ``cnn-mnist``,
    ``cnn-cifar`` by default; extend via :func:`register_sim_workload`),
    or pass a prepared ``(loss_fn, init_params, data, accuracy_fn)``
    directly to the constructor for bespoke setups.
  * :class:`SpmdTrainer` — the group-annealed SPMD driver
    (``backend="spmd"``); ``spec.arch`` names an architecture from
    :mod:`repro.configs.registry`.  The trained parameters of the last
    run are kept on ``self.last_params``.
  * :class:`repro.cluster.trainer.ClusterTrainer` — the wall-clock
    parameter-server runtime with real concurrent workers and fault
    injection (``backend="cluster"``); ``spec.arch`` names the same
    workloads as the simulator.

All three backends aggregate gradients on the shared slab path
(:mod:`repro.core.slab`): one flat tile-aligned gradient slab per
message, one fused (donated) flush executable per run — the Pallas
kernel on TPU, its jnp formulation elsewhere — so a spec re-targets
simulator → SPMD → cluster without changing the aggregation numerics.

Both return the same ``RunResult`` shape, so downstream analysis
(`averaged()`, JSON artifacts, paper tables) is backend-agnostic.
:func:`run` is the one-call entry point that dispatches on
``spec.backend``.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Protocol, Tuple

from repro.api.result import RunResult
from repro.api.schedules import parse_schedule
from repro.api.spec import ExperimentSpec


class Trainer(Protocol):
    """Anything that executes an ExperimentSpec."""

    def run(self, spec: ExperimentSpec) -> RunResult:   # pragma: no cover
        ...


# ------------------------------------------------------- sim workloads

# name -> builder(spec) -> (loss_fn, init_params, data, accuracy_fn)
SIM_WORKLOADS: Dict[str, Callable[[ExperimentSpec], Tuple]] = {}


def register_sim_workload(name: str, builder: Callable,
                          overwrite: bool = False) -> None:
    """Register a simulator workload under ``name`` (= ``spec.arch``)."""
    if name in SIM_WORKLOADS and not overwrite:
        raise ValueError(f"sim workload {name!r} already registered")
    SIM_WORKLOADS[name] = builder


def _mlp_workload(spec: ExperimentSpec):
    import jax
    from repro.data.synthetic import random_classification
    from repro.models.cnn import (accuracy, init_mlp_clf, mlp_clf_forward,
                                  nll_loss)
    n = 2_000 if spec.smoke else 10_000
    data = random_classification(seed=spec.seed, n=n)
    params = init_mlp_clf(jax.random.PRNGKey(spec.seed))
    loss = lambda p, x, y: nll_loss(mlp_clf_forward(p, x), y)  # noqa: E731
    acc = jax.jit(lambda p, x, y: accuracy(mlp_clf_forward(p, x), y))
    return loss, params, data, acc


def _cnn_workload(dataset_name: str, image_shape):
    def build(spec: ExperimentSpec):
        import jax
        from repro.data import synthetic
        from repro.models.cnn import (accuracy, cnn_forward, init_cnn,
                                      nll_loss)
        dataset = getattr(synthetic, dataset_name)
        if spec.smoke:
            data = dataset(seed=spec.seed, n_train=2_000, n_test=500)
        else:
            data = dataset(seed=spec.seed)
        params = init_cnn(jax.random.PRNGKey(spec.seed), image_shape)
        loss = lambda p, x, y: nll_loss(cnn_forward(p, x), y)  # noqa: E731
        acc = jax.jit(lambda p, x, y: accuracy(cnn_forward(p, x), y))
        return loss, params, data, acc
    return build


def _lm_tiny_workload(spec: ExperimentSpec):
    # lazy import: the serving-plane workload pulls in the full model
    # stack, which classifier-only runs never need
    from repro.serve.workload import lm_tiny_workload
    return lm_tiny_workload(spec)


def _zoo_workload(spec: ExperimentSpec):
    # lazy import: the zoo pulls in the full model stack + the config
    # registry (see repro.models.zoo; spec.zoo_scale picks the width)
    from repro.models.zoo import zoo_workload
    return zoo_workload(spec)


register_sim_workload("mlp", _mlp_workload)
register_sim_workload("cnn-mnist", _cnn_workload("mnist_like", (28, 28, 1)))
register_sim_workload("cnn-cifar", _cnn_workload("cifar10_like",
                                                 (32, 32, 3)))
register_sim_workload("lm-tiny", _lm_tiny_workload)
register_sim_workload("zoo:xlstm", _zoo_workload)
register_sim_workload("zoo:transformer", _zoo_workload)


# ------------------------------------------------------------- adapters

class SimulatorTrainer:
    """Adapter: ExperimentSpec -> event-driven PS simulator -> RunResult.

    With no constructor arguments the workload is built from
    ``spec.arch`` via the :data:`SIM_WORKLOADS` registry; pass a prepared
    workload to pin the model/data/initialization across several runs
    (the paper's shared-initialization protocol)."""

    def __init__(self, loss_fn: Optional[Callable] = None,
                 init_params: Any = None, data: Any = None,
                 accuracy_fn: Optional[Callable] = None):
        self._workload = None
        if loss_fn is not None:
            self._workload = (loss_fn, init_params, data, accuracy_fn)
        # one workload build / PSTrainer (and its jitted fns) per distinct
        # key, so running several modes/schedules off one trainer instance
        # reuses the dataset and compiled functions (the paper's
        # shared-initialization protocol, and what the examples do)
        self._workload_cache: Tuple[Optional[tuple], Optional[tuple]] \
            = (None, None)
        self._engine_cache: Tuple[Optional[tuple], Any] = (None, None)

    def _build(self, spec: ExperimentSpec):
        if self._workload is not None:
            return self._workload
        key = (spec.arch, spec.seed, spec.smoke)
        cached_key, cached = self._workload_cache
        if cached_key == key:
            return cached
        builder = SIM_WORKLOADS.get(spec.arch)
        if builder is None:
            known = ", ".join(sorted(SIM_WORKLOADS))
            raise ValueError(f"unknown sim workload {spec.arch!r} "
                             f"(known: {known}; register new ones via "
                             f"repro.api.register_sim_workload)")
        workload = builder(spec)
        self._workload_cache = (key, workload)
        return workload

    def _engine(self, spec: ExperimentSpec):
        from repro.core.simulator import PSTrainer

        workload = self._build(spec)
        key = (id(workload), spec.lr, spec.batch, spec.pool, spec.seed,
               spec.staleness_decay, spec.flush_mode, spec.optimizer,
               spec.beta1, spec.beta2, spec.weight_decay)
        cached_key, cached = self._engine_cache
        if cached_key == key:
            return cached
        loss_fn, init_params, data, accuracy_fn = workload
        trainer = PSTrainer(
            loss_fn, init_params, data, lr=spec.lr, batch_size=spec.batch,
            pool=spec.pool, seed=spec.seed,
            staleness_decay=spec.staleness_decay,
            flush_mode=spec.flush_mode, accuracy_fn=accuracy_fn,
            optimizer=spec.slab_optimizer())
        self._engine_cache = (key, trainer)
        return trainer

    def run(self, spec: ExperimentSpec) -> RunResult:
        trainer = self._engine(spec)
        schedule = None
        if spec.mode == "hybrid":
            schedule = parse_schedule(spec.schedule, spec.pool.num_workers)
        t0 = time.time()
        sim = trainer.simulate(spec.mode, horizon=spec.horizon,
                               schedule=schedule,
                               sample_every=spec.sample_every)
        return RunResult.from_sim(sim, spec=spec, wall_s=time.time() - t0)


class SpmdTrainer:
    """Adapter: ExperimentSpec -> group-annealed SPMD driver -> RunResult.

    ``num_gradients`` counts one gradient per replica per step, reported
    exactly by the driver (every step counts the replica axis it
    actually launched — no reconstruction from the log_every-thinned
    history)."""

    def __init__(self, ckpt_dir: Optional[str] = None,
                 verbose: bool = True):
        self.ckpt_dir = ckpt_dir
        self.verbose = verbose
        self.last_params = None

    def run(self, spec: ExperimentSpec) -> RunResult:
        from repro.launch.train import run_training

        t0 = time.time()
        params, history, stats = run_training(spec, ckpt_dir=self.ckpt_dir,
                                              verbose=self.verbose)
        self.last_params = params
        return RunResult.from_history(
            history, spec=spec, wall_s=time.time() - t0,
            num_updates=stats["num_updates"],
            num_gradients=stats["num_gradients"])


def _cluster_trainer() -> Trainer:
    from repro.cluster.trainer import ClusterTrainer
    return ClusterTrainer()


TRAINERS: Dict[str, Callable[[], Trainer]] = {
    "sim": SimulatorTrainer,
    "spmd": SpmdTrainer,
    "cluster": _cluster_trainer,
}


def get_trainer(backend: str) -> Trainer:
    try:
        return TRAINERS[backend]()
    except KeyError:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(known: {', '.join(sorted(TRAINERS))})") from None


def run(spec: ExperimentSpec) -> RunResult:
    """One spec in, one RunResult out — dispatches on ``spec.backend``."""
    return get_trainer(spec.backend).run(spec)
