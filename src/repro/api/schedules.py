"""Threshold-schedule spec mini-language and registry.

The paper's single knob — the threshold schedule K(t) — is named by a
compact string so every surface (simulator, SPMD driver, CLI, JSON
specs) describes it the same way:

    "step:300"                  K grows by 1 every 300 updates (paper)
    "linear:2000"               linear ramp to W over 2000 updates
    "cosine:horizon=2000"       half-cosine ramp
    "exp:horizon=2000,rate=5"   exponential saturation
    "const:4"                   fixed K (1 ≙ async, W ≙ sync)

Grammar: ``family[:arg,...,key=value,...]``.  Bare args fill the
family's declared positional slots in order; ``key=value`` pairs are
keyword arguments.  Numbers are coerced (int where int-like, float
otherwise).

``parse_schedule(spec, num_workers)`` binds a spec to a worker count and
returns a :class:`repro.core.schedule.ThresholdSchedule`; new families
plug in via :func:`register_schedule` without touching any driver —
this replaces the old ``SCHEDULES`` dict whose factories took
inconsistent positional arguments (``step`` took a step size while the
rest took a horizon, forcing per-kind branches in callers).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

from repro.core.schedule import (ThresholdSchedule, constant_schedule,
                                 cosine_schedule, exponential_schedule,
                                 linear_schedule, step_schedule)


@dataclasses.dataclass(frozen=True)
class ScheduleFamily:
    """One registered K(t) family.

    ``factory(num_workers, **kwargs) -> ThresholdSchedule``;
    ``positional`` names the kwargs that bare (non ``key=value``) spec
    arguments bind to, in order.
    """
    name: str
    factory: Callable[..., ThresholdSchedule]
    positional: Tuple[str, ...] = ()
    doc: str = ""


SCHEDULE_FAMILIES: Dict[str, ScheduleFamily] = {}


def register_schedule(name: str, factory: Callable[..., ThresholdSchedule],
                      positional: Tuple[str, ...] = (), doc: str = "",
                      overwrite: bool = False) -> ScheduleFamily:
    """Register a schedule family under ``name`` for the spec language."""
    if name in SCHEDULE_FAMILIES and not overwrite:
        raise ValueError(f"schedule family {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    fam = ScheduleFamily(name, factory, tuple(positional), doc)
    SCHEDULE_FAMILIES[name] = fam
    return fam


def _coerce(token: str):
    try:
        return int(token)
    except ValueError:
        pass
    try:
        return float(token)
    except ValueError:
        return token


def parse_schedule(spec: str, num_workers: int) -> ThresholdSchedule:
    """Parse ``"family:args"`` and bind it to ``num_workers`` workers."""
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError(f"empty schedule spec: {spec!r}")
    name, _, argstr = spec.strip().partition(":")
    name = name.strip()
    fam = SCHEDULE_FAMILIES.get(name)
    if fam is None:
        known = ", ".join(sorted(SCHEDULE_FAMILIES))
        raise ValueError(f"unknown schedule family {name!r} in {spec!r} "
                         f"(known: {known})")
    kwargs = {}
    pos_used = 0
    for raw in filter(None, (t.strip() for t in argstr.split(","))):
        if "=" in raw:
            key, _, val = raw.partition("=")
            key = key.strip()
            if key in kwargs:
                raise ValueError(f"duplicate argument {key!r} in {spec!r}")
            kwargs[key] = _coerce(val.strip())
        else:
            if pos_used >= len(fam.positional):
                raise ValueError(
                    f"too many positional arguments in {spec!r}: "
                    f"{name!r} takes {len(fam.positional)} "
                    f"({', '.join(fam.positional) or 'none'})")
            key = fam.positional[pos_used]
            if key in kwargs:
                raise ValueError(f"duplicate argument {key!r} in {spec!r}")
            kwargs[key] = _coerce(raw)
            pos_used += 1
    try:
        sched = fam.factory(num_workers, **kwargs)
    except TypeError as e:
        raise ValueError(f"bad arguments for schedule {spec!r}: {e}") from e
    if not isinstance(sched, ThresholdSchedule):
        raise TypeError(f"factory for {name!r} returned "
                        f"{type(sched).__name__}, not ThresholdSchedule")
    return sched


def schedule_help() -> str:
    """One line per registered family (CLI help text)."""
    return "\n".join(f"  {f.name:8s} {f.doc}"
                     for f in SCHEDULE_FAMILIES.values())


# --------------------------------------------------------------- builtins

register_schedule(
    "step", lambda w, step_size: step_schedule(w, int(step_size)),
    positional=("step_size",),
    doc='"step:300" — K grows by 1 every step_size updates (the paper\'s; '
        'paper uses step_size = c/lr, c ∈ {3, 5})')
register_schedule(
    "linear", lambda w, horizon: linear_schedule(w, int(horizon)),
    positional=("horizon",),
    doc='"linear:2000" — linear ramp 1 → W over horizon updates')
register_schedule(
    "cosine", lambda w, horizon: cosine_schedule(w, int(horizon)),
    positional=("horizon",),
    doc='"cosine:horizon=2000" — half-cosine ramp 1 → W')
register_schedule(
    "exp",
    lambda w, horizon, rate=5.0: exponential_schedule(w, int(horizon),
                                                      float(rate)),
    positional=("horizon",),
    doc='"exp:horizon=2000,rate=5" — exponential saturation 1 → W')
register_schedule(
    "const", lambda w, k: constant_schedule(w, int(k)),
    positional=("k",),
    doc='"const:4" — fixed K (1 ≙ async, num_workers ≙ sync)')
