"""Shard-aware input pipeline.

Builds device-sharded global batches from host numpy streams: each batch is
placed with `jax.device_put` against the mesh's batch sharding, with a
single-step host prefetch thread so input building overlaps compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_sharding(mesh: Mesh) -> NamedSharding:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return NamedSharding(mesh, P(tuple(axes)))


def shard_batch(batch: Dict[str, np.ndarray], mesh: Optional[Mesh]):
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    sh = batch_sharding(mesh)

    def put(x):
        spec = P(sh.spec[0], *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, batch)


class Prefetcher:
    """One-deep host-side prefetch of sharded batches."""

    def __init__(self, it: Iterator[Dict[str, np.ndarray]],
                 mesh: Optional[Mesh] = None, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def work():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(shard_batch(item, mesh))
            self._q.put(None)

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()


def worker_shards(n_samples: int, num_workers: int):
    """Deterministic round-robin shard indices (the simulator's data
    partition across PS workers)."""
    return [np.arange(w, n_samples, num_workers) for w in range(num_workers)]


def shard_iterator(x: np.ndarray, y: np.ndarray, worker_id: int,
                   num_workers: int, batch: int, seed: int = 0,
                   generation: int = 0) -> Iterator:
    """Infinite per-worker minibatch iterator over the worker's shard —
    the cluster runtime's data feed.  Deterministic per
    ``(seed, worker_id, generation)``: the i-th batch a worker draws is
    the same in every run, which is what makes the sync policy bitwise
    reproducible; ``generation`` bumps on respawn so a resurrected
    worker does not replay its dead predecessor's stream."""
    idx = worker_shards(x.shape[0], num_workers)[worker_id]
    rng = np.random.default_rng((seed, worker_id, generation))
    while True:
        take = rng.choice(idx, size=batch, replace=True)
        yield x[take], y[take]
