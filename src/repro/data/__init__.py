from repro.data.pipeline import Prefetcher, shard_batch  # noqa: F401
from repro.data import synthetic  # noqa: F401
