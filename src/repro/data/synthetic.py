"""Deterministic synthetic datasets.

The container is offline: MNIST/CIFAR-10 are replaced by synthetic
stand-ins of identical shape and cardinality whose classes are genuinely
learnable (class-conditional pattern + noise), so optimization dynamics
(the paper's subject) are preserved.  The random 20-dim/10-class dataset
reproduces the paper's §6 setup exactly.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _class_image_dataset(n_train: int, n_test: int, shape, num_classes: int,
                         seed: int, noise: float):
    """Images = class template (low-frequency pattern) + per-sample noise."""
    rng = np.random.default_rng(seed)
    H, W, C = shape
    # smooth class templates: random low-rank outer products per channel
    templates = np.zeros((num_classes, H, W, C), np.float32)
    for c in range(num_classes):
        for ch in range(C):
            u = rng.normal(size=(H, 3)).astype(np.float32)
            v = rng.normal(size=(3, W)).astype(np.float32)
            templates[c, :, :, ch] = (u @ v) / 3.0

    def make(n, seed2):
        r = np.random.default_rng(seed2)
        y = r.integers(0, num_classes, size=n)
        x = templates[y] + noise * r.normal(size=(n, H, W, C)).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = make(n_train, seed + 1)
    x_te, y_te = make(n_test, seed + 2)
    return x_tr, y_tr, x_te, y_te


def mnist_like(seed: int = 0, n_train: int = 60_000, n_test: int = 10_000):
    """MNIST stand-in: 28x28x1, 10 classes, 60k/10k."""
    return _class_image_dataset(n_train, n_test, (28, 28, 1), 10, seed,
                                noise=0.8)


def cifar10_like(seed: int = 0, n_train: int = 50_000, n_test: int = 10_000):
    """CIFAR-10 stand-in: 32x32x3, 10 classes, 50k/10k; noisier => the
    'harder optimization problem' role CIFAR plays in the paper."""
    return _class_image_dataset(n_train, n_test, (32, 32, 3), 10, seed,
                                noise=1.6)


def random_classification(seed: int = 0, n: int = 10_000, dim: int = 20,
                          num_classes: int = 10, train_frac: float = 0.8):
    """The paper's randomly-generated dataset: 20 dims, 10 classes, 10k
    samples, 80:20 split.  Labels from a random linear teacher + noise."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    teacher = rng.normal(size=(dim, num_classes)).astype(np.float32)
    logits = x @ teacher + 0.5 * rng.normal(size=(n, num_classes))
    y = np.argmax(logits, axis=-1).astype(np.int32)
    k = int(train_frac * n)
    return x[:k], y[:k], x[k:], y[k:]


def token_stream(seed: int, vocab_size: int, batch: int, seq: int):
    """Deterministic LM token batches: a bigram-ish synthetic language so
    loss actually decreases during example training runs."""
    rng = np.random.default_rng(seed)
    # random sparse bigram table
    next_tok = rng.integers(0, vocab_size, size=(vocab_size, 4))

    def batches():
        r = np.random.default_rng(seed + 1)
        while True:
            t = np.empty((batch, seq + 1), np.int64)
            t[:, 0] = r.integers(0, vocab_size, size=batch)
            for i in range(seq):
                choice = r.integers(0, 4, size=batch)
                noise = r.random(batch) < 0.1
                nxt = next_tok[t[:, i], choice]
                t[:, i + 1] = np.where(
                    noise, r.integers(0, vocab_size, size=batch), nxt)
            yield {"tokens": t[:, :-1].astype(np.int32),
                   "labels": t[:, 1:].astype(np.int32)}

    return batches()
