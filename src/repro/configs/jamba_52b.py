"""jamba-v0.1-52b — Mamba+attention 1:7 hybrid with MoE [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Jamba block = 8
layers: attention at index 3, Mamba elsewhere; MoE (16 experts top-2) on
every other layer, dense MLP otherwise.  4 scanned groups of 8.
"""
from repro.models.config import ATTN, MAMBA, MLP, MOE, ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    d_model=4096,
    vocab_size=65536,
    block_pattern=(
        (MAMBA, MLP), (MAMBA, MOE), (MAMBA, MLP), (ATTN, MOE),
        (MAMBA, MLP), (MAMBA, MOE), (MAMBA, MLP), (MAMBA, MOE),
    ),
    num_groups=4,                      # 32 layers, attn:mamba = 1:7
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    moe_d_ff=14336,
    num_experts=16,
    num_experts_per_tok=2,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    ssm_chunk=256,
    source="arXiv:2403.19887",
)
