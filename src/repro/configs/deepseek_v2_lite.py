"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

27L d_model=2048 16H d_ff(moe)=1408 vocab=102400.  MLA: kv_lora_rank=512,
decoupled rope dim 64, qk_nope/v head dim 128.  MoE: 64 routed experts
top-6 + 2 shared experts (per the V2-Lite model card; the scaled V2 uses
160 routed — noted in DESIGN.md).  The model card's single leading dense
layer is regularized to MoE so the 27 layers scan uniformly (DESIGN.md
§Arch-applicability).
"""
from repro.models.config import MLA, MOE, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    d_model=2048,
    vocab_size=102400,
    block_pattern=((MLA, MOE),),
    num_groups=27,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    kv_lora_rank=512,
    rope_head_dim=64,
    v_head_dim=128,
    d_ff=10944,
    moe_d_ff=1408,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    source="arXiv:2405.04434",
)
