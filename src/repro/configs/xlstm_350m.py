"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H d_ff=0 vocab=50304.  Blocks carry their own up/down
projections (proj factor 2), so there is no separate FFN.  Mix: 3 mLSTM :
1 sLSTM per group of 4 (an xLSTM[7:1]-like mostly-mLSTM mix; the paper's
350M configuration is mLSTM-dominant).
"""
from repro.models.config import MLSTM, NONE, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    d_model=1024,
    vocab_size=50304,
    block_pattern=((MLSTM, NONE), (MLSTM, NONE), (MLSTM, NONE),
                   (SLSTM, NONE)),
    num_groups=6,                      # 24 layers
    num_heads=4,
    num_kv_heads=4,
    xlstm_proj_factor=2.0,
    xlstm_conv=4,
    source="arXiv:2405.04517",
)
