"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

48L d_model=1280 16H d_ff=5120 vocab=504 (masked-prediction codebook).
The conv waveform feature extractor is a stub per the assignment:
`input_specs` provides (B, frames, 512) frame embeddings; the model owns
the learned 512→1280 projection.  Bidirectional attention, GELU MLP,
LayerNorm (wav2vec2 family).  Encoder-only ⇒ no decode shapes.
"""
from repro.models.config import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    d_model=1280,
    vocab_size=504,
    block_pattern=((ATTN, MLP),),
    num_groups=48,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    mlp_act="gelu",
    norm="layernorm",
    causal=False,
    encoder_only=True,
    frontend="audio",
    frontend_dim=512,
    source="arXiv:2106.07447",
)
