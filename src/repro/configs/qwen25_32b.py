"""qwen2.5-32b — dense GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B family scaling].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""
from repro.models.config import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    arch_type="dense",
    d_model=5120,
    vocab_size=152064,
    block_pattern=((ATTN, MLP),),
    num_groups=64,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    attn_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
)
