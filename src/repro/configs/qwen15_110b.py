"""qwen1.5-110b — dense, QKV bias [hf:Qwen/Qwen1.5-0.5B family scaling].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from repro.models.config import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    arch_type="dense",
    d_model=8192,
    vocab_size=152064,
    block_pattern=((ATTN, MLP),),
    num_groups=80,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    attn_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)
