"""llama4-scout-17b-a16e — MoE 16e top-1, early fusion, iRoPE chunked
attention [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.  Every layer is
MoE (16 routed experts top-1 + 1 shared expert).  Attention interleave:
3 chunked-local (8192) layers then 1 global (NoPE/global) layer — the
chunked layers make long_500k decode sub-quadratic in cache size.
"""
from repro.models.config import ATTN, ATTN_GLOBAL, MOE, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    d_model=5120,
    vocab_size=202048,
    block_pattern=((ATTN, MOE), (ATTN, MOE), (ATTN, MOE),
                   (ATTN_GLOBAL, MOE)),
    num_groups=12,                     # 48 layers
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    moe_d_ff=8192,
    num_experts=16,
    num_experts_per_tok=1,
    num_shared_experts=1,
    attn_chunk=8192,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
