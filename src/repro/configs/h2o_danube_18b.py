"""h2o-danube-1.8b — llama/mistral mix with sliding-window attention
[arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
The sliding window makes 500k-context decode sub-quadratic (ring KV cache
of window size).
"""
from repro.models.config import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    arch_type="dense",
    d_model=2560,
    vocab_size=32000,
    block_pattern=((ATTN, MLP),),
    num_groups=24,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    sliding_window=4096,
    source="arXiv:2401.16818",
)
