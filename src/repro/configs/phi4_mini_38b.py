"""phi4-mini-3.8b — dense RoPE/SwiGLU/GQA with 200k vocab
[arXiv:2412.08905].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, tied embeddings.
"""
from repro.models.config import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    d_model=3072,
    vocab_size=200064,
    block_pattern=((ATTN, MLP),),
    num_groups=32,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    tie_embeddings=True,
    source="arXiv:2412.08905",
)
