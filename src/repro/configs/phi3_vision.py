"""phi-3-vision-4.2b — phi3-mini decoder + CLIP vision stub
[hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064.  The CLIP ViT-L/14
tower is a stub per the assignment: `input_specs` provides (B, 576, 1024)
patch embeddings; the model owns the 2-layer MLP projector 1024→3072.
Image tokens are prefixed to text (early fusion); loss over text only.
"""
from repro.models.config import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    d_model=3072,
    vocab_size=32064,
    block_pattern=((ATTN, MLP),),
    num_groups=32,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    frontend="vision",
    frontend_dim=1024,
    num_image_tokens=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
