"""Architecture registry: configs, input shapes, applicability, smoke
variants and ShapeDtypeStruct input specs for the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import (ATTN, ATTN_GLOBAL, MLA, MOE, ModelConfig,
                                 NONE)

_MODULES = {
    "xlstm-350m": "xlstm_350m",
    "qwen1.5-110b": "qwen15_110b",
    "qwen2.5-32b": "qwen25_32b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "hubert-xlarge": "hubert_xlarge",
    "phi-3-vision-4.2b": "phi3_vision",
    "h2o-danube-1.8b": "h2o_danube_18b",
    "jamba-v0.1-52b": "jamba_52b",
    "phi4-mini-3.8b": "phi4_mini_38b",
}

ARCH_NAMES = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(applicable, reason-if-not).  DESIGN.md §Arch-applicability."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full attention: 500k decode cache is not sub-quadratic"
    return True, ""


def applicable_pairs():
    out = []
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            out.append((name, shape.name, ok, why))
    return out


# ----------------------------------------------------------- input specs

def input_specs(cfg: ModelConfig, shape: InputShape,
                batch_override: Optional[int] = None):
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train/prefill -> kwargs for loss_fn/forward; decode -> kwargs for
    decode_step (one new token + a seq_len KV cache).
    """
    from repro.models import model as M

    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct

    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            batch = {"features": sds((B, S, cfg.frontend_dim), f32),
                     "labels": sds((B, S), i32),
                     "loss_mask": sds((B, S), f32)}
        elif cfg.frontend == "vision":
            n_img = cfg.num_image_tokens
            batch = {"tokens": sds((B, S - n_img), i32),
                     "image_embeds": sds((B, n_img, cfg.frontend_dim), f32),
                     "labels": sds((B, S - n_img), i32)}
        else:
            batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        return {"batch": batch}

    # decode: one new token against a seq_len-deep cache
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    return {
        "cache": cache,
        "tokens": sds((B, 1), i32),
        "cur_index": sds((), i32),
    }


# --------------------------------------------------------- smoke variants

def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: <=2 layers, d_model<=512, <=4 experts."""
    pattern = cfg.block_pattern
    if len(pattern) > 2:
        # keep family diversity: first occurrence of each distinct mixer/ffn
        seen, keep = set(), []
        for pair in pattern:
            if pair not in seen:
                keep.append(pair)
                seen.add(pair)
            if len(keep) == 2:
                break
        pattern = tuple(keep)
    heads = min(cfg.num_heads, 4) or 4
    kv = max(1, min(cfg.num_kv_heads, heads))
    if heads % kv:
        kv = heads
    d_model = min(cfg.d_model, 256)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        vocab_size=min(cfg.vocab_size, 512),
        block_pattern=pattern,
        num_groups=1,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=min(cfg.resolved_head_dim, 64),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        moe_d_ff=min(cfg.moe_d_ff, 256) if cfg.moe_d_ff else 0,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        num_experts_per_tok=(min(cfg.num_experts_per_tok, 2)
                             if cfg.num_experts else 0),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        kv_lora_rank=min(cfg.kv_lora_rank, 64) if cfg.kv_lora_rank else 0,
        rope_head_dim=min(cfg.rope_head_dim, 16),
        v_head_dim=min(cfg.resolved_v_head_dim, 64) if cfg.v_head_dim else 0,
        sliding_window=(min(cfg.sliding_window, 16)
                        if cfg.sliding_window else None),
        attn_chunk=min(cfg.attn_chunk, 16) if cfg.attn_chunk else None,
        frontend_dim=min(cfg.frontend_dim, 32) if cfg.frontend_dim else 0,
        num_image_tokens=(min(cfg.num_image_tokens, 8)
                          if cfg.num_image_tokens else 0),
        ssm_chunk=8,
        mamba_dt_rank=8,
        dtype="float32",
        remat="none",
    )


def smoke_batch(cfg: ModelConfig, batch: int = 2, seq: int = 32):
    """Concrete (tiny) host batch matching input_specs' train layout."""
    import numpy as np
    rng = np.random.default_rng(0)
    if cfg.frontend == "audio":
        return {
            "features": rng.normal(size=(batch, seq, cfg.frontend_dim)
                                   ).astype(np.float32),
            "labels": rng.integers(0, cfg.vocab_size, (batch, seq)
                                   ).astype(np.int32),
            "loss_mask": (rng.random((batch, seq)) < 0.5).astype(np.float32),
        }
    if cfg.frontend == "vision":
        n_img = cfg.num_image_tokens
        return {
            "tokens": rng.integers(0, cfg.vocab_size, (batch, seq - n_img)
                                   ).astype(np.int32),
            "image_embeds": rng.normal(size=(batch, n_img, cfg.frontend_dim)
                                       ).astype(np.float32),
            "labels": rng.integers(0, cfg.vocab_size, (batch, seq - n_img)
                                   ).astype(np.int32),
        }
    return {
        "tokens": rng.integers(0, cfg.vocab_size, (batch, seq)
                               ).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (batch, seq)
                               ).astype(np.int32),
    }
