"""Slab-form optimizer definitions — the server-side optimizer config.

The cluster server, the simulator's ``PSTrainer``, and the SPMD driver
all apply gradient flushes through ``repro.core.slab.SlabAggregator``;
with a :class:`SlabOptimizer` attached, the aggregator owns the
optimizer state as additional **f32 slab-shaped buffers** (sharded along
P exactly like staging, donated into the fused flush+update executable).

The math is not re-derived here: :meth:`SlabOptimizer.pair` returns the
existing pytree-form ``(init, update)`` pair from
:mod:`repro.optim.optimizers` bound at **unit learning rate** — a slab
is a valid single-leaf pytree, so the fused executable's jnp path calls
the exact same ``update`` on the f32 slabs and applies
``params + scale * updates`` (``scale`` carries the learning rate, the
way the historical SGD flush already threads it).  The int32 step count
lives in the same state dict, per the shared convention of
:func:`repro.optim.optimizers.bias_correction`.

Moment-buffer names follow the pytree state keys: momentum carries
``mu``; AdamW carries ``mu``/``nu`` (its first/second moments m and v).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.optim.optimizers import adamw, momentum, sgd, Optimizer

# spec/CLI names of the server-side (slab-resident) optimizers
OPTIMIZER_NAMES: Tuple[str, ...] = ("sgd", "momentum", "adamw")


@dataclasses.dataclass(frozen=True)
class SlabOptimizer:
    """Server-side optimizer choice + hyperparameters.

    ``beta1`` doubles as momentum's decay and AdamW's b1; ``beta2``,
    ``eps`` and ``weight_decay`` are AdamW-only.  ``sgd`` carries no
    moment buffers and is the bit-for-bit historical flush.
    """

    name: str = "sgd"
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def __post_init__(self):
        if self.name not in OPTIMIZER_NAMES:
            raise ValueError(f"optimizer must be one of "
                             f"{OPTIMIZER_NAMES}, got {self.name!r}")
        if not (0.0 <= self.beta1 < 1.0 and 0.0 <= self.beta2 < 1.0):
            raise ValueError(f"betas must be in [0, 1): "
                             f"beta1={self.beta1}, beta2={self.beta2}")

    @property
    def moment_names(self) -> Tuple[str, ...]:
        """Names of the f32 slab-shaped moment buffers this optimizer
        carries (matching the pytree state dict keys)."""
        if self.name == "momentum":
            return ("mu",)
        if self.name == "adamw":
            return ("mu", "nu")
        return ()

    def pair(self) -> Optimizer:
        """The pytree-form ``(init, update)`` pair at unit learning
        rate — the slab executable applies ``params + scale * updates``
        with ``scale`` carrying the lr."""
        if self.name == "momentum":
            return momentum(1.0, beta=self.beta1)
        if self.name == "adamw":
            return adamw(1.0, b1=self.beta1, b2=self.beta2, eps=self.eps,
                         weight_decay=self.weight_decay)
        return sgd(1.0)
