from repro.optim.optimizers import (adamw, bias_correction,  # noqa: F401
                                    momentum, sgd, Optimizer)
from repro.optim.slab_form import (OPTIMIZER_NAMES,  # noqa: F401
                                   SlabOptimizer)
