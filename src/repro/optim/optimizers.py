"""Native pytree optimizers (optax-style (init, update) pairs).

update(grads, state, params) -> (updates, new_state); apply with
params + updates.  All state is fp32 regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def _cast_like(src, ref):
    return jax.tree.map(lambda s, r: s.astype(r.dtype), src, ref)


def bias_correction(count, b1: float, b2: float):
    """Adam bias corrections ``(1 - b1^count, 1 - b2^count)`` from the
    **int32 update count carried in optimizer state** — the one
    step-count convention shared by the pytree-form :func:`adamw` and
    the slab-form server optimizer
    (:class:`repro.core.slab.SlabAggregator`), so a checkpointed count
    round-trips between the two without re-deriving the step from any
    other clock.  ``count`` is the count *after* this step's increment
    (first step -> 1)."""
    cf = jnp.asarray(count, jnp.int32).astype(jnp.float32)
    return 1 - b1 ** cf, 1 - b2 ** cf


def sgd(lr: float) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        updates = jax.tree.map(lambda g: (-lr * g.astype(jnp.float32)), grads)
        return _cast_like(updates, params), {"count": state["count"] + 1}

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False
             ) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params)}

    def update(grads, state, params):
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32),
                          state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(
                lambda m, g: -lr * (beta * m + g.astype(jnp.float32)),
                mu, grads)
        else:
            upd = jax.tree.map(lambda m: -lr * m, mu)
        return _cast_like(upd, params), {"count": state["count"] + 1,
                                         "mu": mu}

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0,
          lr_schedule: Optional[Callable] = None) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"count": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(z, params),
                "nu": jax.tree.map(z, params)}

    def update(grads, state, params):
        c = state["count"] + 1
        step_lr = lr_schedule(c) * lr if lr_schedule else lr
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state["nu"], grads)
        bc1, bc2 = bias_correction(c, b1, b2)

        def u(m, v, p):
            upd = -step_lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                              + weight_decay * p.astype(jnp.float32))
            return upd
        upd = jax.tree.map(u, mu, nu, params)
        return _cast_like(upd, params), {"count": c, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""
    def update(grads, state, params):
        leaves = jax.tree.leaves(jax.tree.map(
            lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
        gnorm = jnp.sqrt(sum(leaves))
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)


def cosine_warmup(warmup: int, total: int, floor: float = 0.1):
    """lr multiplier schedule."""
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(1.0, warmup)
        prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return f
