"""Parameter partitioning: key-path → logical axis names.

FSDP axis = "embed" (maps to mesh `data`), tensor axes = "heads"/"mlp"/
"vocab"/"experts"/"inner"/"embed_tensor" (map to mesh `model`).  Every
leaf under `params["groups"]` carries a leading group-stack dim (the scan
axis), which is never sharded.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding

from repro.parallel.sharding import logical_sharding, logical_spec


def _resolve(path: Tuple[str, ...], ndim: int) -> Tuple[Optional[str], ...]:
    name = path[-1]
    joined = "/".join(path)
    grouped = path[0] == "groups"

    def g(*names):
        """Prepend the unsharded group-stack axis when inside groups."""
        out = (None,) + names if grouped else names
        assert len(out) == ndim, (joined, ndim, out)
        return out

    # --- embedding / head / frontend
    if name == "embed":
        return ("vocab", "embed")
    if name == "lm_head":
        return ("embed", "vocab")
    if joined.startswith("frontend_proj"):
        return (None, "embed_tensor") if name == "w1" else ("embed_tensor", None)

    # --- norms
    if name in ("scale", "bias"):
        return (None,) * ndim
    if name == "gn_scale":
        return g("heads", None) if ndim - int(grouped) == 2 else g(None)

    # --- attention family
    if name == "wq":
        return g("embed", "heads", None)
    if name in ("wk", "wv"):
        return g("embed", "kv_heads", None)
    if name in ("lq", "lk", "lv"):                 # mLSTM qkv (di, H, dh)
        return g("embed", None, None)
    if name == "wo":
        return g("heads", None, "embed")
    if name in ("bq", "bk", "bv"):
        return g("heads" if name == "bq" else "kv_heads", None)
    if name == "w_dkv" or name == "w_kr":
        return g("embed", None)
    if name in ("w_uk", "w_uv"):
        return g(None, "heads", None)

    # --- MoE
    if "experts" in path:
        if name in ("w_gate", "w_up"):
            return g("experts", "embed", None)
        if name == "w_down":
            return g("experts", None, "embed")
    if name == "router":
        return g("embed", None)

    # --- MLP (incl. moe shared expert, xlstm block projections)
    if name in ("w_up", "w_gate", "w_z"):
        return g("embed", "mlp")
    if name == "w_down":
        return g("mlp", "embed")

    # --- mamba
    if name == "w_in":
        return g("embed", "inner")
    if name == "conv_w":
        return g(None, "inner")
    if name == "conv_b":
        return g("inner")
    if name == "w_x":
        if ndim - int(grouped) == 3:          # slstm (d, 4, d)
            return g("embed", None, "embed_tensor")
        return g("inner", None)               # mamba (di, dt+2s)
    if name == "w_dt":
        return g(None, "inner")
    if name in ("dt_bias", "D"):
        return g("inner")
    if name == "A_log":
        return g("inner", None)
    if name == "w_out":
        return g("inner", "embed")

    # --- xlstm extras
    if name == "w_if":
        return g("embed", None, None)
    if name == "b_if":
        return g(None, None)
    if name == "r_h":
        return g("heads", None, None, None)
    if name == "b":
        return g(None, "embed_tensor")

    return (None,) * ndim


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def param_logical_tree(params) -> Any:
    """Parallel pytree of logical-axis tuples."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    names = []
    for path, leaf in flat:
        # drop numeric tuple indices; keep the 'groups' marker for matching
        key = tuple(n for n in _path_names(path) if not n.isdigit())
        names.append(_resolve(key, leaf.ndim))
    return jax.tree_util.tree_unflatten(treedef, names)


def sanitize_sharding(sharding, shape):
    """Drop mesh axes from dims they don't divide evenly (jit in_shardings
    requires exact divisibility; e.g. 8 kv-heads can't shard over a 16-way
    model axis, 4 xLSTM heads can't shard at all)."""
    if sharding is None:
        return None
    mesh = sharding.mesh
    spec = sharding.spec
    new = []
    for dim, axes in enumerate(tuple(spec) + (None,) * (len(shape)
                                                        - len(spec))):
        if axes is None:
            new.append(None)
            continue
        axes_t = axes if isinstance(axes, tuple) else (axes,)
        kept = []
        size = shape[dim]
        for a in axes_t:
            n = mesh.shape[a]
            if size % n == 0:
                kept.append(a)
                size //= n
        if not kept:
            new.append(None)
        elif len(kept) == 1:
            new.append(kept[0])
        else:
            new.append(tuple(kept))
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P(*new))


def param_shardings(params):
    """NamedShardings for every param leaf (requires installed axis_rules)."""
    logical = param_logical_tree(params)
    from repro.parallel.sharding import active_mesh
    if active_mesh() is None:
        return jax.tree.map(lambda _: None, params)
    shardings = jax.tree_util.tree_map(
        lambda names: logical_sharding(names), logical,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v))
    return jax.tree.map(lambda s, p: sanitize_sharding(s, p.shape),
                        shardings, params)


def cache_shardings(cache, batch_size: int, mesh):
    """Decode-cache shardings.

    Two regimes: (a) batch >= data axis — shard batch over (pod×)data and
    kv-heads/channels over model; (b) tiny batch (long_500k B=1) — shard
    the sequence dim of KV caches over `data` and fat channel dims over
    (data, model).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsz = 1
    for a in batch_axes:
        dsz *= mesh.shape[a]
    msz = mesh.shape["model"]
    batch_mode = batch_size % dsz == 0

    def div(n, axis_names):
        """axis tuple if n divides evenly, else None."""
        total = 1
        for a in axis_names:
            total *= mesh.shape[a]
        if n % total == 0:
            return axis_names if len(axis_names) > 1 else axis_names[0]
        return None

    def spec_for(path, leaf):
        name = path[-1]
        nd = leaf.ndim
        # dims: 0 = group stack, 1 = batch
        if batch_mode:
            b = batch_axes if len(batch_axes) > 1 else batch_axes[0]
            if name in ("k", "v"):                    # (G,B,L,KV,hd)
                # kv-heads rarely divide the 16-way model axis (GQA kv=8),
                # so shard the *sequence* dim of the cache over `model`:
                # decode attention contracts over L, which GSPMD partitions
                # with a partial-softmax reduce instead of gathering 100s
                # of GiB of cache.
                kvh = div(leaf.shape[3], ("model",))
                if kvh is not None:
                    return P(None, b, None, kvh, None)
                return P(None, b, div(leaf.shape[2], ("model",)), None, None)
            if name in ("c_kv", "k_rope"):            # (G,B,L,r)
                return P(None, b, div(leaf.shape[2], ("model",)), None)
            rest = [None] * (nd - 2)
            # shard the fattest trailing dim over model when divisible
            if nd > 2:
                rest[-1] = div(leaf.shape[-1], ("model",))
            return P(None, b, *rest)
        # tiny-batch regime: shard sequence / channels instead
        if name in ("k", "v"):
            kvh = div(leaf.shape[3], ("model",))
            seq_axes = ("data",) if kvh is not None else ("data", "model")
            return P(None, None, div(leaf.shape[2], seq_axes), kvh, None)
        if name in ("c_kv", "k_rope"):
            return P(None, None, div(leaf.shape[2], ("data", "model")), None)
        if name == "conv":                             # (G,B,dc-1,di)
            return P(None, None, None, div(leaf.shape[3], ("data", "model")))
        if name == "h" and nd == 4:                    # mamba h (G,B,di,ds)
            return P(None, None, div(leaf.shape[2], ("data", "model")), None)
        if name == "C":                                # mlstm (G,B,H,dh,dh)
            return P(None, None, None, div(leaf.shape[3], ("data", "model")),
                     None)
        if name == "n" and nd == 4:                    # mlstm n (G,B,H,dh)
            return P(None, None, None, div(leaf.shape[3], ("data", "model")))
        if nd == 3:                                    # slstm states (G,B,d)
            return P(None, None, div(leaf.shape[2], ("data", "model")))
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = [NamedSharding(mesh, spec_for(
        tuple(n for n in _path_names(p) if not n.isdigit()) or ("x",), leaf))
        for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(opt_state, params):
    """Optimizer state shards exactly like its mirrored params (mu/nu);
    scalars replicate."""
    pshard = param_shardings(params)

    def walk(state):
        out = {}
        for k, v in state.items():
            if k in ("mu", "nu"):
                out[k] = pshard
            else:
                out[k] = jax.tree.map(lambda _: logical_sharding(()), v)
        return out

    return walk(opt_state)
