"""Logical-axis sharding rules (MaxText-style).

Layers annotate activations with *logical* axis names; a rule-set installed
for the active mesh maps logical names to mesh axes.  Without an installed
rule-set every annotation is a no-op, so the same model code runs on a
single CPU device (tests) and on the 512-chip production mesh (dry-run).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Default logical->mesh rules for the production mesh.
#  - "batch" shards over the pod axis too (data parallel across pods).
#  - "embed" is the FSDP axis (weights' d_model dim over `data`).
#  - "heads"/"mlp"/"vocab"/"experts" are the tensor axes (over `model`).
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "batch_nopod": "data",
    "seq": None,
    "seq_shard": "data",        # long-context cache sharding over sequence
    "embed": "data",            # fsdp axis for weights
    "embed_tensor": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_capacity": None,
    "inner": "model",           # ssm / xlstm inner channels
    "state": None,
    "buffer": None,             # hybrid gradient-buffer slot axis
}


class _RuleState(threading.local):
    def __init__(self):
        self.rules: Optional[Dict[str, MeshAxes]] = None
        self.mesh: Optional[Mesh] = None


_STATE = _RuleState()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None):
    """Install logical sharding rules (and the mesh) for the enclosed scope."""
    prev = (_STATE.rules, _STATE.mesh)
    rules = dict(DEFAULT_RULES if rules is None else rules)
    if mesh is not None and "pod" not in mesh.axis_names:
        rules = {k: _drop_axis(v, "pod") for k, v in rules.items()}
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def _drop_axis(axes: MeshAxes, name: str) -> MeshAxes:
    if axes is None or axes == name:
        return None if axes == name else axes
    if isinstance(axes, tuple):
        kept = tuple(a for a in axes if a != name)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return axes


def active_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def logical_spec(names: Sequence[Optional[str]]) -> P:
    rules = _STATE.rules if _STATE.rules is not None else {}
    return P(*[rules.get(n) if n is not None else None for n in names])


def logical_sharding(names: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    if _STATE.mesh is None:
        return None
    return NamedSharding(_STATE.mesh, logical_spec(names))


def lconstraint(x, names: Sequence[Optional[str]]):
    """Annotate `x` with logical axes; no-op when no rules are installed.

    Axes that don't divide the dim evenly are dropped (GSPMD would accept
    the constraint with padding, e.g. 8 kv-heads over a 16-way model axis,
    and then every consumer pays gather/permute traffic on the padded
    shards — measured +1.6 TB/step on qwen2.5-32b train_4k)."""
    if _STATE.mesh is None or _STATE.rules is None:
        return x
    if x.ndim != len(names):
        raise ValueError(f"rank {x.ndim} != logical names {names}")
    spec = logical_spec(names)
    clean = []
    for dim, axes in enumerate(spec):
        if axes is None:
            clean.append(None)
            continue
        axes_t = axes if isinstance(axes, tuple) else (axes,)
        kept = []
        size = x.shape[dim]
        for a in axes_t:
            n = _STATE.mesh.shape[a]
            if size % n == 0:
                kept.append(a)
                size //= n
        clean.append(tuple(kept) if len(kept) > 1
                     else (kept[0] if kept else None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE.mesh, P(*clean)))


def tree_shardings(logical_tree):
    """Map a pytree of logical-name tuples to NamedShardings (or None)."""
    return jax.tree.map(
        lambda names: logical_sharding(names),
        logical_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v),
    )
