from repro.parallel.sharding import axis_rules, lconstraint, logical_sharding  # noqa: F401
