"""Pytree checkpointing (npz-based, shard-agnostic).

Leaves are gathered to host, flattened with '/'-joined key paths, and
stored in a single .npz plus a metadata sidecar.  Restore rebuilds the
exact pytree (dtypes included) and re-places leaves against target
shardings when a mesh is provided.

Optimizer slab state (the server-side moments + update count of
:mod:`repro.core.slab`) rides in the same .npz under a reserved
``__opt__/`` prefix, with the moment names + count recorded in the
sidecar.  Old checkpoints simply lack the block — :func:`load_opt_state`
returns ``None`` and a restore starts the moments from zero.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # npz can't store ml_dtypes (bf16/f8): upcast; restore casts
            # back to the template's dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


_OPT_PREFIX = "__opt__/"    # reserved npz namespace for optimizer slabs


def save_checkpoint(path: str, params, step: int,
                    extra: Optional[Dict[str, Any]] = None,
                    opt_state: Optional[Dict[str, Any]] = None) -> None:
    """``opt_state`` is the :meth:`repro.core.slab.SlabAggregator.
    opt_state_host` form — f32 ``(P_pad,)`` moment slabs keyed by name
    plus an int ``"count"`` — or ``None`` (plain SGD / no optimizer
    state to carry)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    assert not any(k.startswith(_OPT_PREFIX) for k in flat), \
        f"params pytree collides with the reserved {_OPT_PREFIX!r} keys"
    meta = {"step": int(step), "extra": extra or {},
            "keys": sorted(flat.keys())}
    if opt_state is not None:
        names = sorted(k for k in opt_state if k != "count")
        for name in names:
            flat[_OPT_PREFIX + name] = np.asarray(opt_state[name],
                                                  np.float32)
        meta["opt"] = {"names": names,
                       "count": int(opt_state["count"])}
    # write-then-rename so a concurrent reader (e.g. the cluster
    # runtime's mid-run restore) never sees a partial file; the .json
    # sidecar is the commit marker (latest_step keys off it), so it
    # lands last.  savez appends ".npz" when missing, hence ".tmp.npz".
    np.savez(path + ".tmp.npz", **flat)
    os.replace(path + ".tmp.npz", path + ".npz")
    with open(path + ".json.tmp", "w") as f:
        json.dump(meta, f)
    os.replace(path + ".json.tmp", path + ".json")


def restore_checkpoint(path: str, like, shardings=None):
    """Restore into the structure of `like` (a pytree template).

    Returns (params, step)."""
    with open(path + ".json") as f:
        meta = json.load(f)
    data = np.load(path + ".npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    for (path_k, leaf), sh in zip(paths, shard_leaves):
        key = "/".join(_path_str(p) for p in path_k)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        val = jnp.asarray(arr, dtype=leaf.dtype)
        if sh is not None:
            val = jax.device_put(val, sh)
        leaves.append(val)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["step"]


def load_opt_state(path: str) -> Optional[Dict[str, Any]]:
    """The optimizer slab state saved alongside a checkpoint (moment
    slabs + update count), or ``None`` when the checkpoint predates
    slab-resident optimizers or was written by a plain-SGD run — the
    caller then restores with zeroed moments."""
    with open(path + ".json") as f:
        meta = json.load(f)
    opt = meta.get("opt")
    if not opt:
        return None
    data = np.load(path + ".npz")
    state: Dict[str, Any] = {name: data[_OPT_PREFIX + name]
                             for name in opt["names"]}
    state["count"] = int(opt["count"])
    return state


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and name.endswith(".json"):
            steps.append(int(name[len("step_"):-len(".json")]))
    return max(steps) if steps else None
