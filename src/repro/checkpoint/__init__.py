from repro.checkpoint.ckpt import (latest_step, load_opt_state,  # noqa: F401
                                   restore_checkpoint, save_checkpoint)
