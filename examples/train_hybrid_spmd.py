"""End-to-end driver: train a ~100M-parameter xLSTM for a few hundred
steps with the SPMD group-annealed hybrid schedule, against sync and
async baselines (DESIGN.md §2.2 — the TPU-native Smooth Switch), all
through the unified ``repro.api`` layer.

Uses 4 forced host devices so the reduction-group annealing g: 1 -> 4 is
real (4 replicas -> 2 -> 1 with merges between phases).

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/train_hybrid_spmd.py [--steps 200]

(Defaults are sized for the CPU container: a reduced xLSTM of ~8M params;
pass --full-100m on real hardware for the 100M-parameter variant.)
"""
import argparse
import json

import jax

from repro.api import ExperimentSpec, SpmdTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    n_dev = jax.device_count()
    print(f"devices: {n_dev}")
    if n_dev == 1:
        print("hint: run with XLA_FLAGS=--xla_force_host_platform_"
              "device_count=4 to exercise real group annealing")

    base = ExperimentSpec(
        arch="xlstm-350m", backend="spmd", mode="hybrid",
        schedule=f"step:{max(1, args.steps // n_dev)}",
        steps=args.steps, batch=args.batch, seq=args.seq, lr=1e-3,
        smoke=not args.full_100m, log_every=20, seed=0)

    results = {}
    for mode in ("hybrid", "async", "sync"):
        print(f"\n=== mode={mode} ===")
        results[mode] = SpmdTrainer().run(base.with_(mode=mode))

    print("\n=== final losses ===")
    for mode, res in results.items():
        fin = res.final()
        print(f"{mode:8s} loss={fin['loss']:.4f} "
              f"(divergence at end: {fin['divergence']:.2e})")
    with open("/tmp/train_hybrid_spmd.json", "w") as f:
        json.dump({m: r.to_dict() for m, r in results.items()}, f, indent=2)
    print("RunResults saved to /tmp/train_hybrid_spmd.json")


if __name__ == "__main__":
    main()
