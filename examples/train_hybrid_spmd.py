"""End-to-end driver: train a ~100M-parameter xLSTM for a few hundred
steps with the SPMD group-annealed hybrid schedule, against sync and
async baselines (DESIGN.md §2.2 — the TPU-native Smooth Switch).

Uses 4 forced host devices so the reduction-group annealing g: 1 -> 4 is
real (4 replicas -> 2 -> 1 with merges between phases).

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/train_hybrid_spmd.py [--steps 200]

(Defaults are sized for the CPU container: a reduced xLSTM of ~8M params;
pass --full-100m on real hardware for the 100M-parameter variant.)
"""
import argparse
import json

import jax

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    n_dev = jax.device_count()
    print(f"devices: {n_dev}")
    if n_dev == 1:
        print("hint: run with XLA_FLAGS=--xla_force_host_platform_"
              "device_count=4 to exercise real group annealing")

    results = {}
    for mode in ("hybrid", "async", "sync"):
        print(f"\n=== mode={mode} ===")
        _, history = train(
            arch="xlstm-350m", steps=args.steps, mode=mode,
            batch=args.batch, seq=args.seq, lr=1e-3,
            schedule_kind="step", step_size=max(1, args.steps // n_dev),
            smoke=not args.full_100m, log_every=20, seed=0)
        results[mode] = history

    print("\n=== final losses ===")
    for mode, hist in results.items():
        print(f"{mode:8s} loss={hist[-1]['loss']:.4f} "
              f"(divergence at end: {hist[-1]['divergence']:.2e})")
    with open("/tmp/train_hybrid_spmd.json", "w") as f:
        json.dump(results, f, indent=2)
    print("history saved to /tmp/train_hybrid_spmd.json")


if __name__ == "__main__":
    main()
