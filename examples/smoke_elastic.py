"""Elastic-fleet smoke: online admission + SIGKILL shard re-lease.

The three-terminal elasticity quickstart, scripted as one process (the
CI gate behind ``make smoke-elastic``):

  1. a leader starts with a seed fleet of 2 and an admission ceiling of
     3 (``--max-workers``), and two ``repro join`` process groups come
     up;
  2. a third joiner is admitted *mid-run* — the fleet grows beyond the
     seed, staging buffer and K(t) schedule resized online;
  3. one seed worker is SIGKILLed (no goodbye, no flush); its shard is
     re-leased to a fresh process at a bumped generation;
  4. the run is wrapped up and gated on exit codes (every surviving
     joiner exits 0, the killed one shows SIGKILL) and on the exact
     conservation ledger: computed == applied + dropped + buffered +
     pending + in-flight, across every grow / kill / re-lease.

  PYTHONPATH=src python examples/smoke_elastic.py

Exits 0 only if every gate holds; any hang is bounded by the Makefile's
hard ``timeout``.
"""
import sys
import threading
import time


def _poll(predicate, timeout_s, what):
    deadline = time.monotonic() + timeout_s
    while not predicate():
        if time.monotonic() >= deadline:
            print(f"[elastic] FAIL: timed out waiting: {what}")
            sys.exit(1)
        time.sleep(0.05)


def main():
    from repro.api import ExperimentSpec
    from repro.cluster.hostlink import spawn_join_process
    from repro.cluster.trainer import ClusterTrainer

    spec = ExperimentSpec(
        arch="mlp", backend="cluster", mode="async", smoke=True,
        cluster_workers=2, max_workers=3, wall_budget_s=120.0,
        wall_sample_every_s=30.0, batch=16, transport="host",
        listen="127.0.0.1:0")
    trainer = ClusterTrainer()
    runtime = trainer.build_runtime(spec)
    addr = runtime.listen_address
    print(f"[elastic] leader on {addr[0]}:{addr[1]} — seed fleet 2, "
          "admission ceiling 3")

    def applied():
        server = getattr(runtime, "server", None)
        return server.applied if server is not None else 0

    box = {}
    leader = threading.Thread(
        target=lambda: box.update(res=trainer.finish(runtime, spec)),
        daemon=True)
    j0 = spawn_join_process(addr, worker_id=0, platform="cpu")
    j1 = spawn_join_process(addr, worker_id=1, platform="cpu")
    leader.start()
    _poll(lambda: runtime.transport.live_workers() >= {0, 1},
          180.0, "seed fleet assembled")
    _poll(lambda: applied() > 0, 60.0, "seed fleet training")
    print(f"[elastic] seed fleet training ({applied()} gradients "
          "applied)")

    # online admission: a third host dials the live run
    j2 = spawn_join_process(addr, platform="cpu")
    _poll(lambda: 2 in runtime.transport.live_workers(),
          180.0, "third worker admitted mid-run")
    # the hub admits the HELLO a beat before the runtime's
    # ready-callback grows the fleet — poll the growth too
    _poll(lambda: runtime.fleet_size == 3, 30.0, "fleet grew to 3")
    print(f"[elastic] worker 2 admitted mid-run — fleet grew to "
          f"{runtime.fleet_size}")
    mark = applied()
    _poll(lambda: applied() > mark, 60.0, "grown fleet training")

    # departure: SIGKILL a seed worker, then re-lease its shard
    j1.kill()
    _poll(lambda: 1 not in runtime.transport.live_workers(),
          60.0, "killed worker reaped")
    print("[elastic] worker 1 SIGKILLed and reaped — re-leasing its "
          "shard")
    j3 = spawn_join_process(addr, worker_id=1, platform="cpu")
    _poll(lambda: 1 in runtime.transport.live_workers(),
          180.0, "shard re-leased")
    mark = applied()
    _poll(lambda: applied() > mark, 60.0, "re-leased fleet training")
    print(f"[elastic] shard re-leased, fleet training again "
          f"({applied()} gradients applied)")

    runtime.server.done.set()           # smoke over — wrap up the run
    leader.join(timeout=120.0)
    if leader.is_alive():
        print("[elastic] FAIL: leader never finished")
        return 1

    codes = {}
    for name, proc in (("j0", j0), ("j2", j2), ("j3", j3)):
        try:
            codes[name] = proc.wait(timeout=60)
        except Exception:
            proc.kill()
            codes[name] = "stranded"
    if j1.poll() is None:
        j1.kill()
    j1.wait(timeout=30)

    ok = True
    if codes != {"j0": 0, "j2": 0, "j3": 0}:
        print(f"[elastic] FAIL: surviving joiner exit codes {codes}")
        ok = False
    if j1.returncode >= 0:      # SIGKILL surfaces as a negative code
        print(f"[elastic] FAIL: killed worker exited {j1.returncode}, "
              "expected a signal death")
        ok = False

    res = box.get("res")
    if res is None:
        print("[elastic] FAIL: no run result")
        return 1
    a = res.extra["accounting"]
    lhs = a["computed"]
    rhs = (a["applied"] + a["dropped"] + a["buffered"]
           + a["pending_round"] + a["in_flight"])
    if lhs != rhs:
        print(f"[elastic] FAIL: ledger leak — computed {lhs} != "
              f"applied+dropped+buffered+pending+in_flight {rhs}: {a}")
        ok = False
    if set(a["computed_per_worker"]) != {"0", "1", "2"}:
        print("[elastic] FAIL: per-worker ledger missing members: "
              f"{a['computed_per_worker']}")
        ok = False
    grew = [e for e in res.extra["events"] if e["event"] == "fleet_grow"]
    if not grew or grew[0]["to_workers"] != 3:
        print(f"[elastic] FAIL: no fleet_grow to 3 in events: {grew}")
        ok = False
    if not ok:
        return 1
    print(f"[elastic] OK: {a['applied']} gradients applied, ledger "
          f"exact across admit/kill/re-lease "
          f"(per-worker {a['computed_per_worker']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
