"""Serving example: batched greedy generation through the KV/state cache
for three different cache families — ring-buffer SWA (danube), MLA latent
(deepseek), and recurrent SSM state (xlstm).

  PYTHONPATH=src python examples/serve_demo.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import get_config, smoke_variant
from repro.launch.serve import greedy_generate
from repro.models import model as M


def main():
    for arch in ("h2o-danube-1.8b", "deepseek-v2-lite-16b", "xlstm-350m"):
        cfg = dataclasses.replace(smoke_variant(get_config(arch)),
                                  name=arch)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)
        t0 = time.time()
        out = greedy_generate(cfg, params, prompts, gen_len=8)
        dt = time.time() - t0
        kinds = sorted({m for m, _ in cfg.block_pattern})
        print(f"{arch:24s} mixers={kinds} "
              f"out_shape={out.shape} {16 / dt:5.1f} tok/s  "
              f"sample={out[0, -8:].tolist()}")


if __name__ == "__main__":
    main()
