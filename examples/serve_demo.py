"""Serving example: batched greedy generation through the KV/state cache
for three different cache families — ring-buffer SWA (danube), MLA latent
(deepseek), and recurrent SSM state (xlstm).

  PYTHONPATH=src python examples/serve_demo.py

``--live`` runs the whole serving plane in one process instead: an
``lm-tiny`` training leader bound to a loopback port, one joined worker
training against it, and a read-only :class:`repro.serve.ServeClient`
that greedy-decodes the same prompt against three *successive* pushed
params versions — the tokens change under the reader's feet as the
fleet trains, which is the point.

  PYTHONPATH=src python examples/serve_demo.py --live
"""
import dataclasses
import sys
import time

import jax
import numpy as np

from repro.configs.registry import get_config, smoke_variant
from repro.launch.serve import greedy_generate
from repro.models import model as M


def live_main():
    import threading

    from repro.api import ExperimentSpec
    from repro.cluster.hostlink import run_joined_worker
    from repro.cluster.trainer import ClusterTrainer
    from repro.serve.client import ServeClient
    from repro.serve.workload import build_infer_adapter

    spec = ExperimentSpec(
        arch="lm-tiny", backend="cluster", mode="async", smoke=True,
        cluster_workers=1, wall_budget_s=45.0, wall_sample_every_s=45.0,
        batch=16, transport="host", listen="127.0.0.1:0")
    trainer = ClusterTrainer()
    runtime = trainer.build_runtime(spec)
    addr = runtime.listen_address
    print(f"[demo] leader on {addr[0]}:{addr[1]} — one worker joining, "
          "one read-only serve client subscribing")

    result = {}
    leader = threading.Thread(
        target=lambda: result.update(
            res=trainer.finish(runtime, spec)), daemon=True)
    leader.start()
    worker = threading.Thread(
        target=run_joined_worker, args=(addr,),
        kwargs={"connect_timeout": 60.0, "verbose": False}, daemon=True)
    worker.start()

    client = ServeClient(addr)
    adapter = build_infer_adapter(spec, batch=1, prompt_len=6, gen_len=8)
    try:
        last = -1
        for i in range(3):
            msg = client.wait_params(min_version=last + 1, timeout=30.0)
            if msg is None:
                print("[demo] no fresh params within 30s — leader gone?")
                return 1
            last = msg.version
            params = adapter.decode(msg.params)
            out = adapter.run(params, i)
            print(f"[demo] generation {i + 1}: params v{msg.version} — "
                  f"{adapter.summary(out)}")
            time.sleep(1.0)      # let training move the params
    finally:
        client.close()
    print("[demo] the same prompt, three params versions, three "
          "different continuations: serving reads a live training run.")
    runtime.server.done.set()    # demo over — wrap the run up early
    leader.join(timeout=90.0)
    res = result.get("res")
    if res is not None:
        print(f"[demo] training report: {res.num_gradients} gradients "
              f"applied, serving {res.extra.get('serving')}")
    return 0


def main():
    for arch in ("h2o-danube-1.8b", "deepseek-v2-lite-16b", "xlstm-350m"):
        cfg = dataclasses.replace(smoke_variant(get_config(arch)),
                                  name=arch)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)
        t0 = time.time()
        out = greedy_generate(cfg, params, prompts, gen_len=8)
        dt = time.time() - t0
        kinds = sorted({m for m, _ in cfg.block_pattern})
        print(f"{arch:24s} mixers={kinds} "
              f"out_shape={out.shape} {16 / dt:5.1f} tok/s  "
              f"sample={out[0, -8:].tolist()}")


if __name__ == "__main__":
    if "--live" in sys.argv[1:]:
        sys.exit(live_main())
    main()
