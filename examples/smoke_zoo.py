"""Model-zoo smoke: a real transformer on the cluster path, bf16 wire.

The CI gate behind ``make smoke-zoo``: a ``zoo:transformer`` workload
(registry-built config, real forward/backward through the model stack)
trains end-to-end on the cluster backend over the ``proc`` transport —
every worker its own OS process — with the slab wire negotiated down to
bf16 and the slab-resident AdamW optimizer (f32 moment slabs riding a
bf16 params slab).  The run is gated on:

  1. the run result itself (non-zero applied gradients, finite loss);
  2. the exact conservation ledger: computed == applied + dropped +
     buffered + pending + in-flight;
  3. non-empty telemetry with real wire traffic (``wire.tx_bytes`` /
     ``wire.rx_bytes`` both > 0) and a passing internal ledger
     cross-check;
  4. the negotiated dtype actually halving the per-gradient frame:
     tx_bytes per computed gradient must be well under the f32 slab
     size;
  5. the fused flush+AdamW path actually running (``optimizer_steps``
     counter > 0).

  PYTHONPATH=src python examples/smoke_zoo.py

Exits 0 only if every gate holds; any hang is bounded by the Makefile's
hard ``timeout``.
"""
import sys


def main():
    from repro.api import ExperimentSpec, run

    spec = ExperimentSpec(
        arch="zoo:transformer", backend="cluster", mode="async",
        smoke=True, zoo_scale=0.125, slab_dtype="bf16",
        optimizer="adamw", transport="proc", cluster_workers=2,
        wall_budget_s=60.0, wall_sample_every_s=15.0, batch=8,
        max_gradients=24)
    res = run(spec)

    ok = True
    if res.num_gradients <= 0:
        print(f"[zoo] FAIL: no gradients applied ({res.num_gradients})")
        ok = False

    a = res.extra["accounting"]
    lhs = a["computed"]
    rhs = (a["applied"] + a["dropped"] + a["buffered"]
           + a["pending_round"] + a["in_flight"])
    if lhs != rhs:
        print(f"[zoo] FAIL: ledger leak — computed {lhs} != "
              f"applied+dropped+buffered+pending+in_flight {rhs}: {a}")
        ok = False

    tel = res.extra.get("telemetry")
    if not tel or not tel.get("counters"):
        print(f"[zoo] FAIL: telemetry missing/empty: {tel!r}")
        return 1
    counters = tel["counters"]
    tx = counters.get("wire.tx_bytes", 0)
    rx = counters.get("wire.rx_bytes", 0)
    if tx <= 0 or rx <= 0:
        print(f"[zoo] FAIL: no wire traffic recorded (tx={tx} rx={rx})")
        ok = False
    check = tel.get("ledger_check", {})
    if not check.get("consistent", False):
        print(f"[zoo] FAIL: telemetry ledger cross-check: {check}")
        ok = False
    steps = counters.get("optimizer_steps", 0)
    if steps <= 0:
        print(f"[zoo] FAIL: no fused optimizer steps recorded "
              f"(optimizer_steps={steps}) for an adamw run")
        ok = False

    # the bf16 negotiation gate: each uplinked gradient frame carries a
    # 2-byte/element slab, so rx bytes per computed gradient must sit
    # well under the 4-byte/element f32 slab size
    import jax
    from repro.models import model as M
    from repro.models.zoo import num_params, zoo_config
    cfg = zoo_config("transformer", 0.125)
    p = num_params(M.init_params(jax.random.PRNGKey(0), cfg))
    f32_slab = 4 * p
    if a["computed"] > 0:
        per_grad = rx / a["computed"]
        if per_grad > 0.75 * f32_slab:
            print(f"[zoo] FAIL: rx {per_grad:.0f} B/grad is not bf16 "
                  f"({f32_slab} B f32 slab, {p} params)")
            ok = False

    if not ok:
        return 1
    print(f"[zoo] OK: zoo:transformer x0.125 ({p} params) trained over "
          f"proc/bf16 — {a['applied']} applied, ledger exact, "
          f"tx {tx} B rx {rx} B")
    return 0


if __name__ == "__main__":
    sys.exit(main())
