"""Quickstart: the paper's Smooth Switch algorithm in 60 lines.

Runs the event-driven parameter-server simulator on the paper's random
20-dim classification dataset and compares async / sync / hybrid on the
same initialization — the paper's core experiment, CPU-runnable in ~1 min.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import PSTrainer, WorkerPool, step_schedule
from repro.data.synthetic import random_classification
from repro.models.cnn import (accuracy, init_mlp_clf, mlp_clf_forward,
                              nll_loss)


def main():
    # the paper's setting: 25 workers, half of them randomly delayed,
    # lr=0.01, batch 32, threshold step size 3/lr = 300
    data = random_classification(seed=0)
    params0 = init_mlp_clf(jax.random.PRNGKey(0))
    pool = WorkerPool(num_workers=25, base_compute=0.02, delay_std=0.25)

    trainer = PSTrainer(
        loss_fn=lambda p, x, y: nll_loss(mlp_clf_forward(p, x), y),
        init_params=params0, data=data, lr=0.01, batch_size=32,
        pool=pool, seed=0)
    trainer.accuracy_fn = jax.jit(
        lambda p, x, y: accuracy(mlp_clf_forward(p, x), y))

    print(f"{'mode':8s} {'grads':>6s} {'updates':>7s} "
          f"{'avg test acc':>12s} {'final acc':>9s} {'avg loss':>9s}")
    for mode, schedule in [
        ("async", None),
        ("sync", None),
        ("hybrid", step_schedule(num_workers=25, step_size=300)),
    ]:
        res = trainer.run(mode, horizon=8.0, schedule=schedule)
        avg = res.averaged()
        print(f"{mode:8s} {res.num_gradients:6d} {res.num_updates:7d} "
              f"{100 * avg['test_acc']:11.1f}% {100 * res.test_acc[-1]:8.1f}% "
              f"{avg['test_loss']:9.3f}")

    print("\nExpected: hybrid sustains async-level gradient throughput with"
          "\nfewer, more confident parameter updates -> best averaged"
          " metrics\n(the paper's headline result).")


if __name__ == "__main__":
    main()
