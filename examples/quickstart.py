"""Quickstart: the paper's Smooth Switch algorithm through the unified
``repro.api`` layer — one ExperimentSpec, three aggregation modes.

Runs the event-driven parameter-server simulator on the paper's random
20-dim classification dataset and compares async / sync / hybrid on the
same initialization — the paper's core experiment, CPU-runnable in ~1 min.

  PYTHONPATH=src python examples/quickstart.py

(equivalently: python -m repro simulate --arch mlp --mode hybrid \
    --schedule step:300 --workers 25 --base-compute 0.02 --delay-std 0.25 \
    --horizon 8 --no-smoke)
"""
from repro.api import ExperimentSpec, SimulatorTrainer
from repro.core.simulator import WorkerPool


def main():
    # the paper's setting: 25 workers, half of them randomly delayed,
    # lr=0.01, batch 32, threshold step size 3/lr = 300
    base = ExperimentSpec(
        arch="mlp", backend="sim", mode="hybrid", schedule="step:300",
        lr=0.01, batch=32, horizon=8.0, seed=0, smoke=False,
        pool=WorkerPool(num_workers=25, base_compute=0.02, delay_std=0.25))
    # one trainer across modes: same dataset, same initialization, same
    # compiled functions (the paper's shared-initialization protocol)
    trainer = SimulatorTrainer()

    print(f"{'mode':8s} {'grads':>6s} {'updates':>7s} "
          f"{'avg test acc':>12s} {'final acc':>9s} {'avg loss':>9s}")
    for mode in ("async", "sync", "hybrid"):
        res = trainer.run(base.with_(mode=mode))
        avg, fin = res.averaged(), res.final()
        print(f"{mode:8s} {res.num_gradients:6d} {res.num_updates:7d} "
              f"{100 * avg['test_acc']:11.1f}% {100 * fin['test_acc']:8.1f}% "
              f"{avg['test_loss']:9.3f}")

    print("\nExpected: hybrid sustains async-level gradient throughput with"
          "\nfewer, more confident parameter updates -> best averaged"
          " metrics\n(the paper's headline result).")


if __name__ == "__main__":
    main()
