"""Paper §9 (future work): plug different monotone threshold functions
into the Smooth Switch and compare — step (the paper's), linear, cosine,
exponential — plus the staleness-decay extension on the buffer.

Every schedule is named by a ``repro.api`` spec string, so the exact
experiment is reproducible from the printed spec alone.

  PYTHONPATH=src python examples/threshold_functions.py
"""
from repro.api import ExperimentSpec, SimulatorTrainer
from repro.core.simulator import WorkerPool

HORIZON = 8.0
W = 25


def main():
    base = ExperimentSpec(
        arch="mlp", backend="sim", mode="hybrid", schedule="step:300",
        lr=0.01, batch=32, horizon=HORIZON, seed=0, smoke=False,
        pool=WorkerPool(num_workers=W, base_compute=0.02, delay_std=0.25))
    # one trainer instance: dataset + compiled functions are built once
    trainer = SimulatorTrainer()

    # rough horizon in updates for the smooth families
    schedules = {
        "step 300 (paper)": "step:300",
        "step 500 (paper)": "step:500",
        "linear": "linear:2500",
        "cosine": "cosine:horizon=2500",
        "exponential": "exp:horizon=2500,rate=5",
    }
    print(f"{'schedule':20s} {'avg acc':>8s} {'final acc':>9s} "
          f"{'avg loss':>9s} {'updates':>8s}")
    for name, sched in schedules.items():
        r = trainer.run(base.with_(schedule=sched))
        a, f = r.averaged(), r.final()
        print(f"{name:20s} {100 * a['test_acc']:7.1f}% "
              f"{100 * f['test_acc']:8.1f}% {a['test_loss']:9.3f} "
              f"{r.num_updates:8d}")

    print("\nbeyond-paper: staleness-weighted flush (decay^staleness)")
    for decay in (1.0, 0.8, 0.5):
        r = trainer.run(base.with_(staleness_decay=decay))
        a = r.averaged()
        print(f"  decay={decay:3.1f}: avg acc {100 * a['test_acc']:5.1f}%  "
              f"avg loss {a['test_loss']:.3f}")


if __name__ == "__main__":
    main()
