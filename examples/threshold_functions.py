"""Paper §9 (future work): plug different monotone threshold functions
into the Smooth Switch and compare — step (the paper's), linear, cosine,
exponential — plus the staleness-decay extension on the buffer.

  PYTHONPATH=src python examples/threshold_functions.py
"""
import jax

from repro.core import PSTrainer, WorkerPool
from repro.core.schedule import (cosine_schedule, exponential_schedule,
                                 linear_schedule, step_schedule)
from repro.data.synthetic import random_classification
from repro.models.cnn import (accuracy, init_mlp_clf, mlp_clf_forward,
                              nll_loss)

HORIZON = 8.0
W = 25


def main():
    data = random_classification(seed=0)
    params0 = init_mlp_clf(jax.random.PRNGKey(0))
    pool = WorkerPool(num_workers=W, base_compute=0.02, delay_std=0.25)

    def make_trainer(decay=1.0):
        t = PSTrainer(
            lambda p, x, y: nll_loss(mlp_clf_forward(p, x), y),
            params0, data, lr=0.01, batch_size=32, pool=pool, seed=0,
            staleness_decay=decay)
        t.accuracy_fn = jax.jit(
            lambda p, x, y: accuracy(mlp_clf_forward(p, x), y))
        return t

    # rough horizon in updates for the smooth families
    upd_horizon = 2500
    schedules = {
        "step 300 (paper)": step_schedule(W, 300),
        "step 500 (paper)": step_schedule(W, 500),
        "linear": linear_schedule(W, upd_horizon),
        "cosine": cosine_schedule(W, upd_horizon),
        "exponential": exponential_schedule(W, upd_horizon),
    }
    print(f"{'schedule':20s} {'avg acc':>8s} {'final acc':>9s} "
          f"{'avg loss':>9s} {'updates':>8s}")
    base = make_trainer()
    for name, sched in schedules.items():
        r = base.run("hybrid", horizon=HORIZON, schedule=sched)
        a = r.averaged()
        print(f"{name:20s} {100 * a['test_acc']:7.1f}% "
              f"{100 * r.test_acc[-1]:8.1f}% {a['test_loss']:9.3f} "
              f"{r.num_updates:8d}")

    print("\nbeyond-paper: staleness-weighted flush (decay^staleness)")
    for decay in (1.0, 0.8, 0.5):
        t = make_trainer(decay)
        r = t.run("hybrid", horizon=HORIZON, schedule=step_schedule(W, 300))
        a = r.averaged()
        print(f"  decay={decay:3.1f}: avg acc {100 * a['test_acc']:5.1f}%  "
              f"avg loss {a['test_loss']:.3f}")


if __name__ == "__main__":
    main()
