"""Pallas flush kernel (hybrid_aggregate) validation — interpret-mode
execution vs the pure-jnp oracles, swept over shapes/dtypes, plus the
zero-weight masking contract the slab aggregation path relies on.

This file is the CI anchor for the gradient hot path: it runs with
``interpret=True`` on CPU on every push, so the kernel that carries the
server's flush traffic on TPU is exercised everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.hybrid_aggregate import TILE_P

I = dict(interpret=True)


@pytest.mark.parametrize("K", [1, 2, 7, 25])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flush_shapes_dtypes(K, dtype):
    P = TILE_P * (1 if K > 2 else 2)
    g = jax.random.normal(jax.random.PRNGKey(K), (K, P)).astype(dtype)
    w = jax.random.uniform(jax.random.PRNGKey(K + 1), (K,), jnp.float32)
    w = w / jnp.sum(w)
    out = ops.hybrid_flush(g, w, **I)
    want = ref.flush_ref(g, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("beta", [0.0, 0.9])
def test_flush_momentum(beta):
    K, P = 4, TILE_P
    g = jax.random.normal(jax.random.PRNGKey(0), (K, P))
    w = jnp.full((K,), 1.0 / K)
    m = jax.random.normal(jax.random.PRNGKey(1), (P,))
    u, m2 = ops.hybrid_flush_momentum(g, w, m, beta, **I)
    ur, mr = ref.flush_momentum_ref(g, w, m, beta)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ur), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("wd", [0.0, 0.01])
@pytest.mark.parametrize("count", [1, 10])
def test_flush_adamw(wd, count):
    """Fused aggregate+AdamW kernel vs the pure-jnp oracle: params and
    both moment slabs, including bias correction and decoupled weight
    decay."""
    from repro.optim import bias_correction
    K, P = 4, TILE_P
    b1, b2, eps, scale = 0.9, 0.95, 1e-8, 0.01
    g = jax.random.normal(jax.random.PRNGKey(0), (K, P))
    w = jnp.full((K,), 1.0 / K)
    p = jax.random.normal(jax.random.PRNGKey(1), (P,))
    m = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (P,))
    v = 0.01 * jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (P,)))
    bc1, bc2 = bias_correction(count, b1, b2)
    got = ops.hybrid_flush_adamw(g, w, p, m, v, bc1, bc2, scale,
                                 b1=b1, b2=b2, eps=eps, weight_decay=wd,
                                 **I)
    want = ref.flush_adamw_ref(g, w, p, m, v, bc1, bc2, scale,
                               b1=b1, b2=b2, eps=eps, weight_decay=wd)
    for got_a, want_a, name in zip(got, want, ("params", "mu", "nu")):
        np.testing.assert_allclose(np.asarray(got_a), np.asarray(want_a),
                                   rtol=1e-5, atol=1e-6, err_msg=name)


@settings(max_examples=20, deadline=None)
@given(K=st.integers(1, 8), seed=st.integers(0, 2 ** 16),
       uniform=st.booleans())
def test_flush_property_conservation(K, seed, uniform):
    """Property: with uniform weights the flush equals the mean; the flush
    is linear in the weights (paper's aggregation semantics)."""
    P = TILE_P
    g = jax.random.normal(jax.random.PRNGKey(seed), (K, P))
    if uniform:
        w = jnp.full((K,), 1.0 / K)
        out = ops.hybrid_flush(g, w, **I)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.mean(g, 0)),
                                   rtol=1e-5, atol=1e-5)
    else:
        w = jax.random.uniform(jax.random.PRNGKey(seed + 1), (K,)) + 0.1
        o1 = ops.hybrid_flush(g, w, **I)
        o2 = ops.hybrid_flush(g, 2.0 * w, **I)
        np.testing.assert_allclose(np.asarray(o2), 2 * np.asarray(o1),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k", [1, 3, 6])
def test_flush_zero_weight_masking(k):
    """The slab server's one-executable contract: rows past k carry
    weight 0 and contribute exactly nothing, even when they hold stale
    garbage from earlier flushes."""
    K_max, P = 6, TILE_P
    g = jax.random.normal(jax.random.PRNGKey(k), (K_max, P))
    garbage = g.at[k:].set(1e30)              # stale rows, finite junk
    w = jnp.zeros((K_max,), jnp.float32).at[:k].set(
        jax.random.uniform(jax.random.PRNGKey(k + 7), (k,)) + 0.1)
    out = ops.hybrid_flush(garbage, w, **I)
    want = ref.flush_ref(g[:k], w[:k])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flush_matches_buffer_oracle():
    """The kernel implements repro.core.buffer.aggregate_flush."""
    from repro.core.buffer import aggregate_flush
    trees = [{"a": jax.random.normal(jax.random.PRNGKey(i), (300,)),
              "b": jax.random.normal(jax.random.PRNGKey(i + 9), (11, 7))}
             for i in range(3)]
    w = np.array([0.2, 0.5, 0.3])
    want = aggregate_flush(trees, w)
    mat = ops.tree_to_flat(trees)
    out_flat = ops.hybrid_flush(mat, jnp.asarray(w / w.sum()), **I)
    got = ops.flat_to_tree(out_flat, trees[0])
    for k in ("a", "b"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-5)
