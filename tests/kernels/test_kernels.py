"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (+ hypothesis property tests).

The hybrid_aggregate (gradient flush) kernel has its own file,
``test_hybrid_aggregate.py`` — it anchors a dedicated CI step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

I = dict(interpret=True)


# ---------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("shape", [(256, 128), (4, 64, 512), (2, 2, 32, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32)
    y = ops.rmsnorm(x, s, **I)
    want = ref.rmsnorm_ref(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_matches_model_norm():
    from repro.models.norms import rmsnorm as model_rmsnorm
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    s = jnp.ones((128,))
    y = ops.rmsnorm(x, s, **I)
    want = model_rmsnorm({"scale": s}, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


# --------------------------------------------------------- flash attention

@pytest.mark.parametrize("B,S,H,KV,d", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 512, 4, 1, 128),    # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_shapes(B, S, H, KV, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, KV, d))
    v = jax.random.normal(ks[2], (B, S, KV, d))
    o = ops.flash_attention(q, k, v, causal=causal, **I)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 256, 2, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 256, 2, 64)).astype(dtype)
    o = ops.flash_attention(q, k, v, **I)
    want = ref.attention_ref(q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    o = ops.flash_attention(q, k, v, causal=True, window=window, **I)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       qb=st.sampled_from([64, 128]), kb=st.sampled_from([64, 128]))
def test_flash_block_size_invariance(seed, qb, kb):
    """Property: the result must not depend on the tiling."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    o = ops.flash_attention(q, k, v, q_block=qb, kv_block=kb, **I)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_matches_model_rowblock():
    """Kernel vs the model's rowblock path (the dry-run representation)."""
    from repro.models.attention import rowblock_attention
    from repro.models.config import ATTN, MLP, ModelConfig
    cfg = ModelConfig(name="x", arch_type="dense", d_model=64,
                      vocab_size=10, block_pattern=((ATTN, MLP),),
                      num_groups=1, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=64, dtype="float32", remat="none")
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 16))
    k = jax.random.normal(ks[1], (2, 256, 2, 16))
    v = jax.random.normal(ks[2], (2, 256, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(256), (2, 256))
    want = rowblock_attention(q, k, v, pos, cfg, q_block=128)
    o = ops.flash_attention(q, k, v, causal=True, q_block=128, kv_block=128,
                            **I)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
