"""SPMD integration tests (subprocess: device count is fixed at jax
import, so multi-device scenarios run in child processes).

Covers: a dry-run-lite lower+compile on a small mesh, and the group-
annealed hybrid's correctness anchors (R=1 ≡ standard data parallelism;
divergent replicas; exact merge).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 2, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_dryrun_lite_small_mesh():
    """Tiny config lowers + compiles with the full sharding machinery on a
    (2,2) mesh — the in-miniature version of the 512-chip dry-run."""
    out = run_py("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh
        from repro.configs.registry import get_config, smoke_variant
        from repro.models import model as M
        from repro.optim import adamw
        from repro.launch.steps import make_train_step
        from repro.parallel.partition import param_shardings, opt_state_shardings
        from repro.parallel.sharding import axis_rules
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.dryrun import cost_analysis_dict
        # (importing repro.launch.dryrun is safe now: the 512-device
        # override only applies under its __main__ path, so this test
        # keeps the 4 devices forced by its own env)
        def batch_shardings(batch, mesh):
            return jax.tree.map(lambda x: NamedSharding(
                mesh, P("data", *([None] * (x.ndim - 1)))), batch)

        def replicated(mesh):
            return NamedSharding(mesh, P())

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2),
                    ("data", "model"))
        cfg = smoke_variant(get_config("jamba-v0.1-52b"))
        with axis_rules(mesh):
            params = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
            p_sh = param_shardings(params)
            opt = adamw(1e-3)
            opt_sds = jax.eval_shape(lambda: opt.init(params))
            o_sh = opt_state_shardings(opt_sds, params)
            batch = {"tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32)}
            b_sh = batch_shardings(batch, mesh)
            step = make_train_step(cfg, opt, microbatch=2)
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                              out_shardings=(p_sh, o_sh, replicated(mesh))
                              ).lower(params, opt_sds, batch)
            compiled = lowered.compile()
            assert compiled.memory_analysis() is not None
            ca = cost_analysis_dict(compiled)
            assert ca.get("flops", 0) > 0
        print("DRYRUN_LITE_OK")
        """, devices=4)
    assert "DRYRUN_LITE_OK" in out


def test_hybrid_r1_matches_plain_dp():
    """Group size = full axis (R=1) must equal standard data parallelism
    on the same batch (same loss sequence)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.spmd_hybrid import (make_replica_step,
                                            replicate_params)
        from repro.optim import sgd

        def loss_fn(p, b):
            pred = b["x"] @ p["w"]
            return jnp.mean((pred - b["y"]) ** 2), {}

        opt = sgd(0.1)
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4))}
        batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (16, 8)),
                 "y": jax.random.normal(jax.random.PRNGKey(2), (16, 4))}

        # plain DP (single program over all devices)
        def plain_step(p, s, b):
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
            u, s = opt.update(g, s, p)
            return jax.tree.map(lambda a, b: a + b, p, u), s, l

        p1, s1 = params, opt.init(params)
        losses_plain = []
        for i in range(3):
            p1, s1, l = jax.jit(plain_step)(p1, s1, batch)
            losses_plain.append(float(l))

        # replica step with R=1
        step = make_replica_step(loss_fn, opt.update)
        pR = replicate_params(params, 1)
        sR = jax.vmap(opt.init)(pR)
        bR = jax.tree.map(lambda x: x[None], batch)
        losses_R = []
        for i in range(3):
            pR, sR, m = jax.jit(step)(pR, sR, bR)
            losses_R.append(float(m["loss"]))

        np.testing.assert_allclose(losses_plain, losses_R, rtol=1e-6)
        print("R1_MATCH_OK")
        """, devices=2)
    assert "R1_MATCH_OK" in out


def test_hybrid_replicas_diverge_and_merge():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.spmd_hybrid import (make_replica_step, merge_replicas,
                                            replica_divergence,
                                            replicate_params,
                                            reshard_replicas)
        from repro.optim import sgd

        def loss_fn(p, b):
            return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2), {}

        opt = sgd(0.05)
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4))}
        R = 2
        pR = replicate_params(params, R)
        sR = jax.vmap(opt.init)(pR)
        step = jax.jit(make_replica_step(loss_fn, opt.update))
        # different data per replica -> divergence
        bR = {"x": jax.random.normal(jax.random.PRNGKey(1), (R, 8, 8)),
              "y": jax.random.normal(jax.random.PRNGKey(2), (R, 8, 4))}
        assert float(replica_divergence(pR)) == 0.0
        for _ in range(3):
            pR, sR, m = step(pR, sR, bR)
        assert float(m["divergence"]) > 0.0
        merged = merge_replicas(jax.device_get(pR))
        np.testing.assert_allclose(np.asarray(merged["w"][0]),
                                   np.asarray(merged["w"][1]), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(merged["w"][0]),
            np.mean(np.asarray(pR["w"]), axis=0), rtol=1e-5)
        # resharding: split back up to 2 replicas copies the merged value
        up = reshard_replicas(merged, 2)
        np.testing.assert_allclose(np.asarray(up["w"][0]),
                                   np.asarray(up["w"][1]))
        print("DIVERGE_MERGE_OK")
        """, devices=2)
    assert "DIVERGE_MERGE_OK" in out


def test_train_driver_hybrid_end_to_end():
    """The launch.train CLI anneals g=1 -> full and finishes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "xlstm-350m",
         "--smoke", "--steps", "8", "--mode", "hybrid", "--schedule",
         "step", "--step-size", "4", "--batch", "4", "--seq", "32",
         "--out-json", "/tmp/test_hybrid_train.json"],
        capture_output=True, text=True, timeout=420, env=env, cwd=REPO)
    assert p.returncode == 0, p.stderr[-2000:]
    import json
    hist = json.load(open("/tmp/test_hybrid_train.json"))["history"]
    gs = [h["group_size"] for h in hist]
    assert gs[0] == 1 and gs[-1] == 2   # annealed to full axis
    assert all(isinstance(h["loss"], float) for h in hist)
