"""Telemetry-plane tests (``repro.obs``): the bus itself, the Chrome
trace export, and the conformance bars the plane must clear —

  * telemetry counters reconcile EXACTLY with the conservation ledger
    on every transport (``grads_ingested == applied + dropped +
    buffered + pending_round`` and ``computed == grads_ingested +
    in_flight``);
  * a tracing-disabled run is bitwise identical to a tracing-enabled
    one (spans are the only trace-gated work, and they never touch the
    math);
  * a read-only STATS reader attached to a live leader streams
    progress without perturbing the run — a sync host run with a stats
    reader is bitwise identical to inproc;
  * the perf gate fails serve cells that regress training throughput
    or client staleness.
"""
import json
import os
import sys
import time

import numpy as np
import pytest

import jax

from repro.api import ExperimentSpec, run
from repro.cluster.trainer import ClusterTrainer
from repro.obs import NULL, Telemetry, chrome_trace, write_chrome_trace

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:                 # for `import benchmarks.*`
    sys.path.insert(0, REPO)

CHILD_PLATFORM = None if jax.default_backend() == "cpu" else "cpu"


def _spec(**kw):
    base = dict(arch="mlp", backend="cluster", mode="hybrid",
                schedule="step:40", cluster_workers=2, wall_budget_s=1.5,
                wall_sample_every_s=0.5, batch=16, smoke=True)
    base.update(kw)
    return ExperimentSpec(**base)


def _sync_spec(**kw):
    base = dict(arch="mlp", backend="cluster", mode="sync",
                schedule=None, cluster_workers=2, wall_budget_s=30.0,
                wall_sample_every_s=10.0, batch=16, smoke=True,
                max_gradients=12)
    base.update(kw)
    return ExperimentSpec(**base)


def _check_reconcile(res):
    """Telemetry counters vs the conservation ledger, exactly."""
    a = res.extra["accounting"]
    tel = res.extra["telemetry"]
    c = tel["counters"]
    ingested = c.get("grads_ingested", 0)
    # every gradient the server saw is in exactly one ledger bucket
    assert ingested == (a["applied"] + a["dropped"] + a["buffered"]
                        + a["pending_round"]), (c, a)
    # every gradient computed either reached the server or is in flight
    assert a["computed"] == ingested + a["in_flight"], (c, a)
    assert c.get("grads_applied", 0) == a["applied"]
    assert c.get("updates", 0) == a["updates"]
    per_worker = sum(v for k, v in c.items()
                     if k.startswith("grads_ingested.w"))
    assert per_worker == ingested
    check = tel["ledger_check"]
    assert check["consistent"], check
    return tel


# --------------------------------------------------------------- the bus

def test_telemetry_counters_gauges_histograms():
    tel = Telemetry()
    tel.count("grads")
    tel.count("grads", 4)
    tel.count("bytes", 100)
    tel.gauge("depth", 3.0)
    tel.gauge("depth", 7.0)               # last write wins
    for v in range(100):
        tel.observe("staleness", float(v))
    assert tel.counters() == {"grads": 5, "bytes": 100}
    st = tel.hist_stats("staleness")
    assert st["count"] == 100 and st["min"] == 0.0 and st["max"] == 99.0
    assert st["p50"] == 50.0 and st["p99"] == 98.0
    assert tel.hist_stats("nope") is None
    s = tel.summary()
    assert s["trace"] is False and s["spans_recorded"] == 0
    assert s["gauges"] == {"depth": 7.0}
    assert s["counters"]["grads"] == 5
    assert s["histograms"]["staleness"]["mean"] == pytest.approx(49.5)


def test_spans_recorded_only_when_tracing():
    off = Telemetry(trace=False)
    with off.span("server", "flush", k=3):
        pass
    off.span_at("server", "flush", time.monotonic(), 0.001)
    off.instant("server", "k_switch", k=1)
    assert off.spans() == []

    on = Telemetry(trace=True)
    with on.span("worker/0", "grad_compute", version=7):
        pass
    on.span_at("server", "flush", time.monotonic(), 0.002, k=2)
    on.instant("server", "k_switch", k=1)
    spans = on.spans()
    assert len(spans) == 3
    kinds = sorted(s[0] for s in spans)
    assert kinds == ["I", "X", "X"]
    x = next(s for s in spans if s[2] == "grad_compute")
    assert x[1] == "worker/0" and x[5] == {"version": 7}
    assert on.summary()["spans_recorded"] == 3


def test_null_telemetry_is_inert():
    assert NULL.enabled is False
    NULL.count("x")
    NULL.gauge("x", 1.0)
    NULL.observe("x", 1.0)
    with NULL.span("t", "n"):
        pass
    NULL.span_at("t", "n", 0.0, 0.0)
    NULL.instant("t", "n")
    assert NULL.counters() == {} and NULL.spans() == []
    assert NULL.hist_stats("x") is None
    assert NULL.summary() == {"trace": False, "counters": {},
                              "gauges": {}, "histograms": {},
                              "spans_recorded": 0}


def test_chrome_trace_export(tmp_path):
    tel = Telemetry(trace=True)
    t = time.monotonic()
    tel.span_at("worker/1", "grad_compute", t, 0.003, version=5)
    tel.span_at("server", "flush", t + 0.003, 0.001, k=2)
    tel.instant("server", "k_switch", k=1)
    doc = chrome_trace(tel)
    events = doc["traceEvents"]
    # the server track sorts first regardless of name order
    meta = {e["args"]["name"]: e["tid"] for e in events
            if e["ph"] == "M" and e["name"] == "thread_name"}
    assert meta["server"] == 0 and meta["worker/1"] == 1
    flush = next(e for e in events if e["name"] == "flush")
    assert flush["ph"] == "X" and flush["dur"] == pytest.approx(1000.0)
    assert flush["args"] == {"k": 2} and flush["cat"] == "server"
    grad = next(e for e in events if e["name"] == "grad_compute")
    assert grad["tid"] == 1 and grad["cat"] == "worker"
    inst = next(e for e in events if e["name"] == "k_switch")
    assert inst["ph"] == "i" and inst["s"] == "t"
    # X events carry microsecond timestamps relative to the bus epoch
    assert flush["ts"] - grad["ts"] == pytest.approx(3000.0)

    out = tmp_path / "trace.json"
    n = write_chrome_trace(tel, str(out))
    assert n == 3                        # metadata rows not counted
    loaded = json.loads(out.read_text())
    assert loaded["displayTimeUnit"] == "ms"
    assert len(loaded["traceEvents"]) == len(events)


# --------------------------------------------- ledger reconciliation

@pytest.mark.parametrize("transport", ["inproc", "socket"])
def test_counters_reconcile_with_ledger(transport):
    res = run(_spec(transport=transport))
    tel = _check_reconcile(res)
    h = tel["histograms"]
    # the instrumented seams produced samples: staleness per ingest,
    # flush/publish per update, grad/send-wait per worker gradient
    for name in ("staleness", "flush_s", "publish_s", "grad_s",
                 "send_wait_s", "queue_depth"):
        assert h.get(name, {}).get("count", 0) > 0, name
    assert tel["counters"].get("params_published", 0) > 0


def test_counters_reconcile_with_ledger_proc():
    """Same reconciliation across the process boundary: worker-side
    compute telemetry stays in the children, but the server/wire-side
    counters the ledger check needs are all in the parent."""
    res = run(_spec(transport="proc", wall_budget_s=8.0,
                    wall_sample_every_s=2.0, max_gradients=200))
    tel = _check_reconcile(res)
    c = tel["counters"]
    assert c.get("wire.rx_bytes", 0) > 0
    assert c.get("wire.tx_bytes", 0) > 0


# ----------------------------------------------- tracing is inert

def test_trace_on_off_bitwise_identical(tmp_path):
    """A sync run under a gradient budget, traced and untraced, must
    produce bit-identical final parameters — tracing only records
    spans, never reorders or perturbs the math.  The traced run's
    artifact must be a loadable Chrome trace with at least one
    grad-compute span per worker, plus flush and publish spans."""
    spec = _sync_spec()
    plain = ClusterTrainer()
    res = plain.run(spec)
    assert res.extra["accounting"]["applied"] == 12
    assert "trace_path" not in res.extra
    assert res.extra["telemetry"]["trace"] is False
    assert res.extra["telemetry"]["spans_recorded"] == 0

    out = tmp_path / "trace.json"
    traced = ClusterTrainer(trace=str(out))
    res_t = traced.run(spec)
    assert res_t.extra["accounting"]["applied"] == 12
    assert res_t.extra["trace_path"] == str(out)
    assert res_t.extra["telemetry"]["spans_recorded"] > 0

    for key in plain.last_params:
        assert np.array_equal(np.asarray(plain.last_params[key]),
                              np.asarray(traced.last_params[key])), key

    doc = json.loads(out.read_text())
    events = doc["traceEvents"]
    tracks = {e["args"]["name"] for e in events
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"server", "worker/0", "worker/1"} <= tracks
    tid_of = {e["tid"]: e["args"]["name"] for e in events
              if e["ph"] == "M" and e["name"] == "thread_name"}
    grads_by_track = {}
    for e in events:
        if e.get("ph") == "X" and e["name"] == "grad_compute":
            track = tid_of[e["tid"]]
            grads_by_track[track] = grads_by_track.get(track, 0) + 1
    assert grads_by_track.get("worker/0", 0) >= 1
    assert grads_by_track.get("worker/1", 0) >= 1
    names = [e["name"] for e in events if e.get("ph") == "X"]
    assert names.count("flush") >= 1 and names.count("publish") >= 1


# -------------------------------------------- live stats plane (STATS)

def test_stats_reader_does_not_perturb_sync_run():
    """The `repro top` acceptance bar: a read-only STATS subscriber on
    a live host-transport leader streams progress snapshots but never
    enters the run — the sync outcome stays bitwise identical to
    inproc, the ledger stays exact, and the reader is reported as a
    stats client, not a serve client."""
    from repro.cluster.hostlink import spawn_join_process
    from repro.obs.top import StatsClient

    spec = _sync_spec()
    base = ClusterTrainer()
    res = base.run(spec)
    assert res.extra["accounting"]["applied"] == 12
    # serving report is always present, empty-shaped off-host
    assert res.extra["serving"] == {
        "clients": 0, "rejected_peers": 0, "serve_every": 1,
        "stats_clients": 0, "per_client": []}

    hspec = _sync_spec(transport="host", listen="127.0.0.1:0")
    trainer = ClusterTrainer()
    runtime = trainer.build_runtime(hspec)
    procs = [spawn_join_process(runtime.listen_address, workers=1,
                                platform=CHILD_PLATFORM)
             for _ in range(2)]
    reader = StatsClient(runtime.listen_address)
    docs = []
    try:
        res_h = trainer.finish(runtime, hspec)
        # drain whatever snapshots arrived during the run
        while True:
            doc = reader.wait_stats(timeout=0.5)
            if doc is None:
                break
            docs.append(doc)
    finally:
        codes = []
        for p in procs:
            try:
                codes.append(p.wait(timeout=60))
            except Exception:
                p.kill()
                codes.append("killed")
        reader.close()
    assert codes == [0, 0], codes

    a = res_h.extra["accounting"]
    assert a["applied"] == 12
    _check_reconcile(res_h)
    serving = res_h.extra["serving"]
    assert serving["clients"] == 0          # never a serve client...
    assert serving["stats_clients"] == 1    # ...counted as a reader

    assert docs, "stats reader saw no pushes"
    live = [d for d in docs if "version" in d]
    if live:                                # saw the run mid-flight
        assert live[-1]["mode"] == "sync"
        assert 0 <= live[-1]["applied"] <= 12

    for key in base.last_params:
        assert np.array_equal(np.asarray(base.last_params[key]),
                              np.asarray(trainer.last_params[key])), key


def test_top_formats_waiting_and_live_rows():
    from repro.obs.top import _fmt_line
    line = _fmt_line({"state": "waiting"}, None)
    assert "waiting" in line
    doc = {"t": 1.5, "version": 42, "mode": "hybrid", "applied": 120,
           "dropped": 1, "buffered": 2, "pending_round": 0,
           "updates": 40, "staleness": {"p50": 0.0, "p99": 2.0},
           "queue_depth": 3, "live_workers": 2, "num_workers": 2,
           "serve_clients": 0}
    line = _fmt_line(doc, 99.5)
    assert "42" in line and "99.5" in line and "hybrid" in line


def test_stats_history_ring_backfills_late_attaching_top():
    """A `repro top` that attaches mid-run is not blind: the hub's
    cadence thread feeds a history ring even with zero subscribers, a
    new subscriber receives that ring as a one-shot backfill before its
    first live push (seeding the grads/sec delta), and live pushes stay
    coalesced — a slow reader skips ticks instead of queueing them."""
    import io

    from repro.cluster.hostlink import HostTransport
    from repro.obs.top import StatsClient, top_main

    hub = HostTransport(4, host="127.0.0.1", port=0, num_workers=1,
                        welcome_config={})
    hub.stats_every_s = 0.05
    state = {"n": 0}

    def provider():
        state["n"] += 1
        return {"t": state["n"] * 0.05, "version": state["n"],
                "applied": state["n"] * 10, "dropped": 0, "buffered": 0,
                "pending_round": 0, "queue_depth": 0, "live_workers": 1,
                "fleet_size": 1, "serve_clients": 0, "mode": "async",
                "staleness": {"p50": 0.0, "p99": 0.0}}

    reader = None
    try:
        # installing the provider starts the cadence thread at once —
        # the ring fills with nobody watching
        hub.stats_provider = provider
        deadline = time.monotonic() + 5.0
        while len(hub.stats_history()) < 3:
            assert time.monotonic() < deadline, "history ring never fed"
            time.sleep(0.02)

        # late attach: the backfill arrives before the first live push
        reader = StatsClient(hub.address)
        first = reader.wait_stats(timeout=5.0)
        assert first is not None and "version" in first
        assert reader.backfill, "no history backfill received"
        assert all("version" in c for c in reader.backfill)
        # ring cells are oldest-first and precede the first live push
        versions = [c["version"] for c in reader.backfill]
        assert versions == sorted(versions)
        assert versions[-1] <= first["version"]

        # coalescing: a slow reader skips the ticks it slept through
        time.sleep(0.4)
        latest = reader.wait_stats(timeout=5.0)
        assert latest is not None
        assert latest["version"] > first["version"] + 1

        # and the CLI body seeds its rate delta from the backfill: the
        # very first printed row already carries grads/sec (applied
        # moves 10 per 0.05s of leader clock = 200.0 exactly)
        out = io.StringIO()
        assert top_main(tuple(hub.address), count=1, out=out) == 0
        text = out.getvalue()
        assert "backfilled" in text, text
        assert "200.0" in text, text
    finally:
        if reader is not None:
            reader.close()
        hub.close()


# ------------------------------------------------ perf gate: serve cells

def _serve_report(cells):
    return {"schema": "repro.bench.serve/v1",
            "grid": [{"clients": c,
                      "train": {"grads_per_s": gps},
                      "client_stats": [
                          {"client": i, "staleness": {"p99": p99}}
                          for i, p99 in enumerate(p99s)]}
                     for c, gps, p99s in cells]}


def test_perf_gate_serve_cells(tmp_path):
    from benchmarks import perf_gate

    server = {"grid": [{"fleet": 4, "K": 1,
                        "slab": {"grads_per_s": 100.0}}]}
    server_path = tmp_path / "server.json"
    server_path.write_text(json.dumps(server))
    base_path = tmp_path / "serve_base.json"
    base_path.write_text(json.dumps(_serve_report(
        [(0, 100.0, []), (2, 50.0, [1.0, 1.0])])))

    def gate(fresh_cells):
        fresh_path = tmp_path / "serve_fresh.json"
        fresh_path.write_text(json.dumps(_serve_report(fresh_cells)))
        return perf_gate.main([
            "--fresh", str(server_path),
            "--baseline", str(server_path),
            "--serve-fresh", str(fresh_path),
            "--serve-baseline", str(base_path)])

    # identical report passes
    assert gate([(0, 100.0, []), (2, 50.0, [1.0, 1.0])]) == 0
    # noise within tolerance passes; additive staleness slack honoured
    assert gate([(0, 40.0, []), (2, 20.0, [3.0, 2.0])]) == 0
    # training throughput under serving load regressed
    assert gate([(0, 100.0, []), (2, 10.0, [1.0, 1.0])]) == 1
    # client-observed staleness regressed
    assert gate([(0, 100.0, []), (2, 50.0, [1.0, 50.0])]) == 1
    # a baseline cell missing from the fresh report FAILS, not skips
    assert gate([(0, 100.0, [])]) == 1
    # without serve args the serve plane is not gated
    assert perf_gate.main(["--fresh", str(server_path),
                           "--baseline", str(server_path)]) == 0
