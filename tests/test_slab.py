"""Tests for the slab gradient path (``repro.core.slab``): codec
round-trip properties, numerical parity of the slab aggregation against
the legacy pytree fold (bitwise for the sync mean, allclose for weighted
flushes), the donation contract (published params survive later donated
flushes; snapshots stay valid while flushes continue), and the
one-flush-executable guarantee for any fleet size."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.slab import SlabAggregator, SlabBuffer, slab_codec
from repro.cluster.server import ParameterServer
from repro.cluster.transport import GradientMsg, ParamsMsg
from repro.core.schedule import constant_schedule, step_schedule
from repro.kernels.hybrid_aggregate import TILE_P


def _tree(seed: int, scale: float = 1.0, shapes=None):
    shapes = shapes or {"w1": (20, 64), "b1": (64,), "w2": (64, 10)}
    ks = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return {name: scale * jax.random.normal(k, s)
            for k, (name, s) in zip(ks, sorted(shapes.items()))}


@jax.jit
def _legacy_agg_apply_jit(params, grads, weights, scale):
    wsum = jnp.sum(weights)

    def comb(p, *leaves):
        s = weights[0] * leaves[0]
        for w, leaf in zip(weights[1:], leaves[1:]):
            s = s + w * leaf
        return p - scale * (s / wsum)

    return jax.tree.map(comb, params, *grads)


def legacy_agg_apply(params, grads, weights, scale):
    """The pre-slab server's fused aggregate+apply, verbatim: one
    *jitted* executable per buffer size K, folding the K gradient
    pytrees leaf by leaf, normalized by Σw.  (Jitted like the original —
    eager execution skips XLA's FMA contraction and drifts by 1 ulp.)
    The slab executable must reproduce it bitwise for uniform weights."""
    return _legacy_agg_apply_jit(params, tuple(grads),
                                 jnp.asarray(weights, jnp.float32),
                                 jnp.float32(scale))


# ----------------------------------------------------------------- codec

@settings(max_examples=25, deadline=None)
@given(n_leaves=st.integers(1, 4), seed=st.integers(0, 2 ** 16),
       dim=st.sampled_from([1, 3, 17, 128, 300]),
       ranks=st.sampled_from([(1,), (2,), (1, 2), (3, 1)]))
def test_codec_round_trip_property(n_leaves, seed, dim, ranks):
    """Property: decode(encode(tree)) is bitwise identical for any tree
    of floating leaves, and the slab is tile-aligned with zero padding."""
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i in range(n_leaves):
        key, k = jax.random.split(key)
        shape = tuple(dim + i for _ in range(ranks[i % len(ranks)]))
        tree[f"leaf{i}"] = jax.random.normal(k, shape)
    codec = slab_codec(tree)
    slab = codec.encode(tree)
    assert slab.shape == (codec.padded_size,) and slab.dtype == jnp.float32
    assert codec.padded_size % TILE_P == 0
    assert codec.size == sum(np.prod(s) for s in codec.shapes)
    np.testing.assert_array_equal(
        np.asarray(slab[codec.size:]), 0.0)        # padding is zeros
    back = codec.decode(slab)
    for name in tree:
        got, want = np.asarray(back[name]), np.asarray(tree[name])
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_array_equal(got, want)


def test_codec_bf16_round_trip_exact():
    """bf16 leaves widen to f32 on the slab and narrow back exactly."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 8)
                                   ).astype(jnp.bfloat16)}
    codec = slab_codec(tree)
    back = codec.decode(codec.encode(tree))
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


def test_codec_cached_per_structure():
    """Same structure -> same codec object (and compiled executables);
    different shapes -> a different codec."""
    assert slab_codec(_tree(0)) is slab_codec(_tree(1))
    other = slab_codec({"w": jnp.zeros((4, 4))})
    assert other is not slab_codec(_tree(0))


def test_codec_rejects_integer_leaves():
    with pytest.raises(TypeError, match="floating"):
        slab_codec({"ids": jnp.zeros((3,), jnp.int32)})


def test_codec_layout_offsets():
    """Leaves occupy [offset, offset+size) in flatten order."""
    tree = _tree(3)
    codec = slab_codec(tree)
    slab = np.asarray(codec.encode(tree))
    leaves = jax.tree_util.tree_leaves(tree)
    for leaf, off, n in zip(leaves, codec.offsets, codec.sizes):
        np.testing.assert_array_equal(slab[off:off + n],
                                      np.asarray(leaf).ravel())


# ------------------------------------------------ aggregator vs legacy

def test_slab_flush_bitwise_equals_legacy_sync_fold():
    """Uniform weights (the sync round mean): the slab executable's fold
    must be bitwise identical to the legacy per-leaf fold."""
    params, grads = _tree(0), [_tree(i + 1, 0.01) for i in range(3)]
    codec = slab_codec(params)
    agg = SlabAggregator(codec, params, k_max=5)
    for i, g in enumerate(grads):
        agg.stage(codec.encode(g), i)
    pub = agg.flush_apply(np.ones(3), 0.05)
    want = legacy_agg_apply(params, tuple(grads), np.ones(3), 0.05)
    got = codec.decode(pub)
    for name in params:
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(want[name]), err_msg=name)


@pytest.mark.parametrize("weights", [
    np.array([1.0, 0.9, 0.81, 0.729]),     # staleness decay 0.9
    np.array([0.3, 1.0, 0.3, 0.7]),
])
def test_slab_flush_weighted_allclose_legacy(weights):
    params, grads = _tree(0), [_tree(i + 1, 0.01) for i in range(4)]
    codec = slab_codec(params)
    agg = SlabAggregator(codec, params, k_max=4)
    for i, g in enumerate(grads):
        agg.stage(codec.encode(g), i)
    got = codec.decode(agg.flush_apply(weights, 0.04))
    want = legacy_agg_apply(params, tuple(grads), weights, 0.04)
    for name in params:
        np.testing.assert_allclose(np.asarray(got[name]),
                                   np.asarray(want[name]),
                                   rtol=1e-6, atol=1e-7, err_msg=name)


def test_slab_flush_pallas_interpret_matches_jnp():
    """The Pallas kernel (interpret mode on CPU) and the jnp fallback
    compute the same flush — the TPU/CPU backend matrix is consistent."""
    params, grads = _tree(0), [_tree(i + 1, 0.01) for i in range(3)]
    codec = slab_codec(params)
    outs = []
    for use_pallas in (False, True):
        agg = SlabAggregator(codec, params, k_max=4,
                             use_pallas=use_pallas, interpret=use_pallas)
        for i, g in enumerate(grads):
            agg.stage(codec.encode(g), i)
        outs.append(np.asarray(
            agg.flush_apply(np.array([1.0, 0.9, 0.81]), 0.03)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6, atol=1e-7)


def test_slab_buffer_staleness_weights_clamped():
    """decay^(now - v) with the exponent clamped at 0: a gradient tagged
    with a *future* version (post-restore) is not up-weighted."""
    params = _tree(0)
    agg = SlabAggregator(slab_codec(params), params, k_max=3)
    buf = SlabBuffer(agg, staleness_decay=0.5)
    slab = agg.codec.encode(_tree(1, 0.01))
    for v in (4, 6, 9):                    # staleness 2, 0, -3 at now=6
        buf.add(slab, v)
    np.testing.assert_allclose(buf.weights(6), [0.25, 1.0, 1.0])
    buf.clear()
    assert len(buf) == 0


# ------------------------------------------------------ donation contract

def test_published_params_survive_later_donated_flushes():
    """The flush executable's second output (the published params) must
    never alias the donated buffer: copies handed out at version v stay
    bitwise intact while the server keeps flushing."""
    params = _tree(0)
    codec = slab_codec(params)
    agg = SlabAggregator(codec, params, k_max=2)
    g = codec.encode(_tree(1, 0.01))
    agg.stage(g, 0)
    pub_v1 = agg.flush_apply(np.ones(1), 0.01)
    held = np.asarray(pub_v1).copy()
    for _ in range(5):                      # donations keep recycling
        agg.stage(g, 0)                     # the private buffers
        agg.flush_apply(np.ones(1), 0.01)
    np.testing.assert_array_equal(np.asarray(pub_v1), held)
    # and the params did actually move on
    assert not np.array_equal(np.asarray(agg.params_slab), held)


class _CellTransport:
    """Minimal transport stub: remembers the last published params."""

    def __init__(self):
        self.published = []

    def publish_params(self, msg: ParamsMsg):
        self.published.append(msg)

    def send_gradient(self, msg, timeout=None):   # pragma: no cover
        return True

    def recv_gradient(self, timeout=None):        # pragma: no cover
        return None

    def pending_gradients(self):                  # pragma: no cover
        return 0


def _server(mode="hybrid", num_workers=3, schedule=None, **kw):
    params = _tree(0)
    if mode in ("async", "hybrid") and schedule is None:
        schedule = constant_schedule(num_workers,
                                     1 if mode == "async" else 2)
    return params, ParameterServer(
        params, lr=0.05, mode=mode, transport=_CellTransport(),
        num_workers=num_workers, schedule=schedule, **kw)


def test_snapshot_survives_continued_flushes():
    """Regression for the checkpoint-under-donation hazard: a snapshot
    taken mid-run must be a copy — its values stay bitwise intact while
    later flushes keep donating (and therefore recycling) the server's
    params buffers."""
    params, server = _server(mode="async", num_workers=2)
    codec = server.codec
    grads = [codec.encode(_tree(i + 1, 0.01)) for i in range(4)]
    for i in range(3):
        server.ingest(GradientMsg(0, grads[i], server.version, i))
    version, snap, applied = server.snapshot()
    held = {k: np.asarray(v).copy() for k, v in snap.items()}
    for i in range(40):                 # checkpoint-while-training
        server.ingest(GradientMsg(0, grads[i % 4], server.version, i))
    for k in held:                      # the snapshot did not move
        np.testing.assert_array_equal(np.asarray(snap[k]), held[k])
    # while the live params did
    _, now, _ = server.snapshot()
    assert any(not np.array_equal(held[k], np.asarray(now[k]))
               for k in held)
    assert version == 3 and applied == 3


# ------------------------------------------------- server parity / probe

def _replay_legacy(params, msgs, mode, schedule, lr, flush_mode="sum",
                   staleness_decay=1.0, num_workers=3):
    """Replay an ingest sequence through the pre-slab server semantics
    (pytree buffers + legacy_agg_apply) and return the final params."""
    version, buffer, round_ = 0, [], {}
    p = params
    for msg in msgs:
        if mode == "sync":
            if msg.version != version:
                continue
            round_[msg.worker_id] = msg.grad
            if set(round_) >= set(range(num_workers)):
                wids = sorted(round_)
                grads = [round_[w] for w in wids]
                round_ = {}
                p = legacy_agg_apply(p, tuple(grads),
                                     np.ones(len(grads)), lr)
                version += 1
        else:
            buffer.append((msg.grad, msg.version))
            if len(buffer) >= schedule(version):
                grads = [g for g, _ in buffer]
                stale = np.maximum(0.0, version - np.asarray(
                    [v for _, v in buffer], np.float64))
                weights = staleness_decay ** stale
                k = len(buffer)
                buffer = []
                scale = lr * k if flush_mode == "sum" else lr
                p = legacy_agg_apply(p, tuple(grads), weights, scale)
                version += 1
    return p, version


@pytest.mark.parametrize("mode,flush_mode,decay", [
    ("sync", "sum", 1.0),
    ("async", "sum", 1.0),
    ("hybrid", "sum", 1.0),
    ("hybrid", "mean", 1.0),
    ("hybrid", "sum", 0.9),
    ("hybrid", "mean", 0.9),
])
def test_server_slab_path_matches_legacy_pytree_path(mode, flush_mode,
                                                     decay):
    """Numerical parity of the live slab server against the pre-slab
    pytree path, on an identical deterministic ingest sequence: bitwise
    for the sync round mean, allclose <= 1e-6 for weighted flushes."""
    num_workers = 3
    schedule = None
    if mode == "hybrid":
        schedule = step_schedule(num_workers, 2)   # K anneals 1 -> 3
    elif mode == "async":
        schedule = constant_schedule(num_workers, 1)
    params, server = _server(mode=mode, num_workers=num_workers,
                             schedule=schedule, flush_mode=flush_mode,
                             staleness_decay=decay)
    for w in range(num_workers):
        server.register(w)
    codec = server.codec
    grad_trees = [_tree(100 + i, 0.01) for i in range(12)]

    # deterministic ingest: round-robin workers, each reading the
    # then-current version (so hybrid/async staleness is exercised but
    # reproducible)
    slab_msgs, tree_msgs = [], []
    for i, g in enumerate(grad_trees):
        wid = i % num_workers
        v = server.version
        msg = GradientMsg(wid, codec.encode(g), v, i)
        server.ingest(msg)
        tree_msgs.append(GradientMsg(wid, g, v, i))
        slab_msgs.append(msg)

    want, want_version = _replay_legacy(
        params, tree_msgs, mode, schedule, server.lr,
        flush_mode=flush_mode, staleness_decay=decay,
        num_workers=num_workers)
    assert server.version == want_version > 0
    _, got, _ = server.snapshot()
    for name in params:
        g, w = np.asarray(got[name]), np.asarray(want[name])
        if mode == "sync":
            np.testing.assert_array_equal(g, w, err_msg=name)  # bitwise
        else:
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-7,
                                       err_msg=name)


def test_restore_wipes_nonfinite_staged_gradients():
    """Regression: diverged (inf/nan) gradients sitting in the buffer
    when a restore discards them must not poison later flushes — zero
    masking alone is not enough (0 · inf = nan), so discard wipes.
    The restore rolls K(t) back to 1, so the discarded rows would be
    masked (never overwritten) by the next flush."""
    num_workers = 3
    schedule = step_schedule(num_workers, 1)       # K(v) = 1 + v
    params, server = _server(mode="hybrid", num_workers=num_workers,
                             schedule=schedule)
    codec = server.codec
    g = _tree(2, 0.01)
    for i in range(3):     # advance to version 2 (flushes at K=1, K=2)
        server.ingest(GradientMsg(i, codec.encode(g), server.version, i))
    assert server.version == 2 and len(server.buffer) == 0
    # two diverged gradients buffer at rows 0 and 1, awaiting K=3
    bad = jax.tree.map(lambda x: jnp.full_like(x, jnp.inf), _tree(1))
    for i in range(2):
        server.ingest(GradientMsg(i, codec.encode(bad),
                                  server.version, 3 + i))
    assert len(server.buffer) == 2
    server.restore(params, step=0)          # discards them; K back to 1
    assert server.dropped == 2
    # the next flush stages only row 0 — row 1 (the inf) is masked,
    # so without the wipe it would turn the params to NaN
    server.ingest(GradientMsg(0, codec.encode(g), server.version, 5))
    _, got, _ = server.snapshot()
    want = legacy_agg_apply(params, (g,), np.ones(1), server.lr)
    for name in params:
        assert np.isfinite(np.asarray(got[name])).all(), name
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(want[name]), err_msg=name)


def test_hybrid_schedule_larger_than_fleet_does_not_overflow_staging():
    """Regression: a K(t) schedule built for a larger fleet than the
    actual worker count must not overflow the staging buffer — k_max is
    sized to the schedule's own ceiling, so the buffer keeps filling
    until the demanded K is reached."""
    num_workers = 2
    schedule = step_schedule(5, 1)         # K(t) can demand up to 5
    params, server = _server(mode="hybrid", num_workers=num_workers,
                             schedule=schedule)
    codec = server.codec
    for i in range(20):
        server.ingest(GradientMsg(i % num_workers,
                                  codec.encode(_tree(i, 0.01)),
                                  server.version, i))
    # flush sizes 1,2,3,4,5,5 — every gradient accounted, none clobbered
    assert server.applied == 20 and len(server.buffer) == 0
    assert server.agg.flush_cache_size() == 1


def test_async_flushes_every_gradient_regardless_of_schedule():
    """async is K ≡ 1 by definition: its one-row staging buffer relies
    on the schedule being ignored, whatever K it would demand."""
    params, server = _server(mode="async", num_workers=3,
                             schedule=step_schedule(3, 1))
    codec = server.codec
    for i in range(6):
        server.ingest(GradientMsg(i % 3, codec.encode(_tree(i, 0.01)),
                                  server.version, i))
    assert server.applied == server.version == 6
    assert server.agg.k_max == 1


@pytest.mark.parametrize("num_workers", [1, 3, 5])
def test_exactly_one_flush_executable_any_fleet(num_workers):
    """The jit-cache probe: after serving traffic across every buffer
    size K in 1..fleet, the server holds exactly ONE compiled flush
    executable (the pre-slab server compiled ``num_workers`` of them
    before the clock even started)."""
    schedule = step_schedule(num_workers, 1)       # K grows every update
    params, server = _server(mode="hybrid", num_workers=num_workers,
                             schedule=schedule)
    codec = server.codec
    seen_k = set()
    for i in range(4 * num_workers):
        k_now = schedule(server.version)
        seen_k.add(k_now)
        server.ingest(GradientMsg(i % num_workers,
                                  codec.encode(_tree(i, 0.01)),
                                  server.version, i))
    assert seen_k == set(range(1, num_workers + 1))  # every K exercised
    assert server.agg.flush_cache_size() == 1
    assert server.applied == 4 * num_workers


# ------------------------------------------- slab-resident optimizers

from repro.core.slab import slab_codec as _slab_codec  # noqa: E402
from repro.optim import SlabOptimizer  # noqa: E402

OPTS = [SlabOptimizer("sgd"),
        SlabOptimizer("momentum", beta1=0.9),
        SlabOptimizer("adamw", beta1=0.9, beta2=0.95, weight_decay=0.01)]


def test_sgd_optimizer_flush_bitwise_identical_to_legacy():
    """The hard invariant: optimizer="sgd" IS the historical flush, bit
    for bit — an explicitly-passed sgd SlabOptimizer changes nothing
    against the pre-refactor fused aggregate+apply."""
    num_workers = 3
    params, server = _server(mode="sync", num_workers=num_workers,
                             optimizer=SlabOptimizer("sgd"))
    for w in range(num_workers):
        server.register(w)
    codec = server.codec
    p = params
    for r in range(4):
        grads = [_tree(10 * r + w, 0.01) for w in range(num_workers)]
        for w in range(num_workers):
            server.ingest(GradientMsg(w, codec.encode(grads[w]),
                                      server.version, r))
        p = legacy_agg_apply(p, tuple(grads), np.ones(num_workers),
                             server.lr)
    _, got, _ = server.snapshot()
    for name in params:
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(p[name]), err_msg=name)
    assert server.agg.opt_state_host() is None     # sgd carries no state


@pytest.mark.parametrize("opt", OPTS, ids=lambda o: o.name)
def test_sim_and_cluster_sync_flush_bitwise_identical(opt):
    """The simulator and the cluster server run the SAME fused
    flush+optimizer executable: staging the same sync rounds through a
    simulator-style aggregator (PSTrainer's construction, no server
    warmup) and through a live ParameterServer yields bitwise-identical
    params AND moments, per optimizer."""
    num_workers = 3
    params, server = _server(mode="sync", num_workers=num_workers,
                             optimizer=opt)
    for w in range(num_workers):
        server.register(w)
    # the simulator path: PSTrainer builds its aggregator exactly so
    # (and never warmups — the server's warmup must be a bitwise no-op)
    sim_agg = SlabAggregator(_slab_codec(params), params, num_workers,
                             optimizer=opt)
    for r in range(5):
        grads = [_tree(10 * r + w, 0.01) for w in range(num_workers)]
        for w in range(num_workers):
            server.ingest(GradientMsg(w, server.codec.encode(grads[w]),
                                      server.version, r))
            sim_agg.stage(sim_agg.codec.encode(grads[w]), w)
        sim_agg.flush_apply(np.ones(num_workers), server.lr)
    _, got, _ = server.snapshot()
    want = sim_agg.params_tree()
    for name in params:
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(want[name]),
                                      err_msg=f"{opt.name}:{name}")
    st_server = server.agg.opt_state_host()
    st_sim = sim_agg.opt_state_host()
    if opt.name == "sgd":
        assert st_server is None and st_sim is None
    else:
        assert st_server["count"] == st_sim["count"] == 5
        for mname in opt.moment_names:
            np.testing.assert_array_equal(st_server[mname],
                                          st_sim[mname],
                                          err_msg=f"{opt.name}:{mname}")


@pytest.mark.parametrize("opt", OPTS[1:], ids=lambda o: o.name)
def test_momentum_adamw_exactly_one_fused_executable(opt):
    """The one-executable contract extends to the optimizer flushes:
    after serving every buffer size K in 1..fleet, momentum/adamw hold
    exactly ONE compiled fused flush+update executable."""
    num_workers = 4
    schedule = step_schedule(num_workers, 1)       # K grows every update
    params, server = _server(mode="hybrid", num_workers=num_workers,
                             schedule=schedule, optimizer=opt)
    codec = server.codec
    seen_k = set()
    for i in range(4 * num_workers):
        seen_k.add(schedule(server.version))
        server.ingest(GradientMsg(i % num_workers,
                                  codec.encode(_tree(i, 0.01)),
                                  server.version, i))
    assert seen_k == set(range(1, num_workers + 1))
    assert server.agg.flush_cache_size() == 1
    # conservation: every ingested gradient is applied or still staged
    assert server.applied + len(server.buffer) == 4 * num_workers


def test_moments_stay_f32_under_bf16_slab():
    """The mixed-precision rule: slab_dtype="bf16" halves the staging/
    wire bytes, but the optimizer moments (like the master params) stay
    f32 — second moments in bf16 would collapse small squared
    gradients to zero."""
    opt = SlabOptimizer("adamw", beta1=0.9, beta2=0.95)
    params, server = _server(mode="async", num_workers=2,
                             slab_dtype="bf16", optimizer=opt)
    codec = server.codec
    assert jnp.asarray(server.agg.params_slab).dtype == jnp.bfloat16
    for i in range(4):
        server.ingest(GradientMsg(i % 2, codec.encode(_tree(i, 0.01)),
                                  server.version, i))
    moments = server.agg._moments
    chunks = []
    for name in opt.moment_names:
        m = moments[name]
        chunks += list(m) if isinstance(m, list) else [m]
    assert chunks and all(c.dtype == jnp.float32 for c in chunks)
    st = server.agg.opt_state_host()
    for name in opt.moment_names:
        assert st[name].dtype == np.float32
        assert np.isfinite(st[name]).all()
    assert st["count"] == 4


def test_opt_state_checkpoint_round_trip_resumes_bitwise(tmp_path):
    """Checkpoint mid-run with adamw, restore into a fresh server, and
    continue: the resumed trajectory is bitwise identical to the
    uninterrupted one — moments AND the bias-correction count travel
    with the params."""
    from repro.checkpoint import (load_opt_state, restore_checkpoint,
                                  save_checkpoint)
    opt = SlabOptimizer("adamw", beta1=0.9, beta2=0.95,
                        weight_decay=0.01)
    params, server_a = _server(mode="async", num_workers=2,
                               optimizer=opt)
    codec = server_a.codec
    grads = [codec.encode(_tree(50 + i, 0.01)) for i in range(6)]
    for i in range(3):
        server_a.ingest(GradientMsg(i % 2, grads[i],
                                    server_a.version, i))
    version, snap, _, opt_state = server_a.snapshot_for_checkpoint()
    assert opt_state["count"] == 3
    path = str(tmp_path / f"step_{version}")
    save_checkpoint(path, snap, version, opt_state=opt_state)

    # a fresh server restores params + moments + count from disk
    _, server_b = _server(mode="async", num_workers=2, optimizer=opt)
    r_params, r_step = restore_checkpoint(path, like=params)
    r_opt = load_opt_state(path)
    assert r_opt is not None and r_opt["count"] == 3
    server_b.restore(r_params, r_step, opt_state=r_opt)

    for i in range(3, 6):
        for s in (server_a, server_b):
            s.ingest(GradientMsg(i % 2, grads[i], s.version, i))
    _, got_a, _ = server_a.snapshot()
    _, got_b, _ = server_b.snapshot()
    for name in params:
        np.testing.assert_array_equal(np.asarray(got_a[name]),
                                      np.asarray(got_b[name]),
                                      err_msg=name)
    st_a = server_a.agg.opt_state_host()
    st_b = server_b.agg.opt_state_host()
    assert st_a["count"] == st_b["count"] == 6
    for mname in opt.moment_names:
        np.testing.assert_array_equal(st_a[mname], st_b[mname],
                                      err_msg=mname)


def test_old_checkpoint_without_opt_state_restores_zero_moments(
        tmp_path):
    """Back-compat: a checkpoint written without optimizer state (the
    pre-refactor format, or an sgd run) restores cleanly — moments
    restart from zero, count from 0."""
    from repro.checkpoint import load_opt_state, save_checkpoint
    opt = SlabOptimizer("momentum", beta1=0.9)
    params, server = _server(mode="async", num_workers=2, optimizer=opt)
    codec = server.codec
    for i in range(3):
        server.ingest(GradientMsg(i % 2, codec.encode(_tree(i, 0.01)),
                                  server.version, i))
    path = str(tmp_path / "step_0")
    save_checkpoint(path, params, 0)           # no opt_state (old form)
    assert load_opt_state(path) is None
    server.restore(params, 0, opt_state=load_opt_state(path))
    st = server.agg.opt_state_host()
    assert st["count"] == 0
    assert not np.any(st["mu"])                # zeroed, not stale
