"""Tests for the unified ``repro.api`` experiment layer: spec round-trip,
the schedule mini-language, RunResult shape parity across backends, the
CLI, and replica resharding round-trips."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (ExperimentSpec, RunResult, SimulatorTrainer,
                       SpmdTrainer, parse_schedule, register_schedule, run)
from repro.api.schedules import SCHEDULE_FAMILIES
from repro.core.schedule import ThresholdSchedule, constant_schedule
from repro.core.simulator import WorkerPool

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------- ExperimentSpec

def test_spec_json_round_trip():
    spec = ExperimentSpec(
        arch="cnn-mnist", backend="sim", mode="hybrid",
        schedule="exp:horizon=800,rate=3", seed=7, lr=0.02, batch=64,
        horizon=12.5, sample_every=0.25, flush_mode="mean",
        staleness_decay=0.8, steps=40, seq=64, merge_alpha=0.5,
        pool=WorkerPool(num_workers=13, delay_std=0.75))
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    assert back.pool == spec.pool and isinstance(back.pool, WorkerPool)


def test_spec_save_load_round_trip(tmp_path):
    spec = ExperimentSpec(schedule="cosine:horizon=500")
    path = str(tmp_path / "spec.json")
    spec.save(path)
    assert ExperimentSpec.load(path) == spec


def test_spec_validation():
    with pytest.raises(ValueError, match="backend"):
        ExperimentSpec(backend="tpu")
    with pytest.raises(ValueError, match="mode"):
        ExperimentSpec(mode="semi-sync")
    with pytest.raises(ValueError, match="flush_mode"):
        ExperimentSpec(flush_mode="max")
    with pytest.raises(ValueError, match="schedule"):
        ExperimentSpec(mode="hybrid", schedule=None)
    with pytest.raises(ValueError):      # bad schedule spec caught eagerly
        ExperimentSpec(mode="hybrid", schedule="bogus:1")
    with pytest.raises(ValueError, match="unknown ExperimentSpec fields"):
        ExperimentSpec.from_dict({"archz": "mlp"})
    # sync/async need no schedule
    assert ExperimentSpec(mode="sync", schedule=None).schedule is None


def test_spec_with_revalidates():
    spec = ExperimentSpec(mode="sync", schedule=None)
    with pytest.raises(ValueError):
        spec.with_(mode="hybrid", schedule="nope:1")
    assert spec.with_(mode="hybrid", schedule="step:10").mode == "hybrid"


# ------------------------------------------------------ schedule language

@pytest.mark.parametrize("spec_str", [
    "step:300", "step:step_size=300", "linear:1000",
    "linear:horizon=1000", "cosine:2000", "cosine:horizon=2000",
    "exp:2000", "exp:horizon=2000,rate=5", "exp:2000,rate=0.5",
    "const:4", "const:k=1",
])
def test_parse_schedule_families(spec_str):
    s = parse_schedule(spec_str, num_workers=16)
    assert isinstance(s, ThresholdSchedule)
    assert s.num_workers == 16
    ks = [s(t) for t in range(0, 4000, 37)]
    assert all(1 <= k <= 16 for k in ks)
    assert ks == sorted(ks)               # monotone non-decreasing


def test_parse_schedule_exp_rate_kwarg():
    fast = parse_schedule("exp:horizon=1000,rate=10", 16)
    slow = parse_schedule("exp:horizon=1000,rate=1", 16)
    assert fast(200) >= slow(200)         # higher rate saturates earlier
    assert fast(1000) == slow(1000) == 16


def test_parse_schedule_matches_legacy_factories():
    from repro.core.schedule import step_schedule
    new, old = parse_schedule("step:300", 25), step_schedule(25, 300)
    assert [new(t) for t in range(0, 9000, 100)] == \
           [old(t) for t in range(0, 9000, 100)]


@pytest.mark.parametrize("bad, match", [
    ("", "empty"),
    ("warp:10", "unknown schedule family"),
    ("step:1,2", "too many positional"),
    ("step:300,step_size=5", "duplicate argument"),
    ("step", "bad arguments"),                 # missing required step_size
    ("exp:2000,speed=3", "bad arguments"),     # unknown kwarg
])
def test_parse_schedule_errors(bad, match):
    with pytest.raises(ValueError, match=match):
        parse_schedule(bad, num_workers=8)


def test_register_schedule():
    register_schedule(
        "sqrt2", lambda w, horizon: constant_schedule(w, 2),
        positional=("horizon",), overwrite=True)
    try:
        assert parse_schedule("sqrt2:100", 8)(0) == 2
        with pytest.raises(ValueError, match="already registered"):
            register_schedule("sqrt2", lambda w: None)
    finally:
        SCHEDULE_FAMILIES.pop("sqrt2", None)


# ------------------------------------------------- RunResult + trainers

def _sim_spec(**kw):
    base = dict(arch="mlp", backend="sim", mode="hybrid",
                schedule="step:50", horizon=2.0, sample_every=0.5,
                smoke=True, pool=WorkerPool(num_workers=4,
                                            base_compute=0.05))
    base.update(kw)
    return ExperimentSpec(**base)


def test_run_result_json_round_trip():
    res = run(_sim_spec())
    back = RunResult.from_json(res.to_json())
    assert back == res
    assert back.averaged() == res.averaged()


def test_sim_run_result_shape():
    res = run(_sim_spec())
    assert res.backend == "sim" and res.grid_unit == "virtual_s"
    assert set(res.metrics) == {"train_loss", "test_loss", "test_acc"}
    for series in res.metrics.values():
        assert len(series) == len(res.grid)
    avg = res.averaged()
    assert all(isinstance(v, float) for v in avg.values())
    assert res.num_gradients >= res.num_updates > 0
    assert res.spec["schedule"] == "step:50"


def test_backend_result_parity():
    """All three backends emit the same RunResult shape from the same
    spec fields (grid + aligned metrics + counters + averaged())."""
    sim = run(_sim_spec())
    spmd = run(ExperimentSpec(
        arch="xlstm-350m", backend="spmd", mode="sync", schedule=None,
        steps=2, batch=2, seq=16, lr=1e-3, smoke=True, log_every=1))
    cluster = run(ExperimentSpec(
        arch="mlp", backend="cluster", mode="sync", schedule=None,
        cluster_workers=3, wall_budget_s=1.0, wall_sample_every_s=0.25,
        batch=16, smoke=True))
    assert spmd.backend == "spmd" and spmd.grid_unit == "step"
    assert cluster.backend == "cluster" and cluster.grid_unit == "wall_s"
    for res in (sim, spmd, cluster):
        assert len(res.grid) > 0
        for series in res.metrics.values():
            assert len(series) == len(res.grid)
        assert set(res.to_dict()) == set(sim.to_dict())
        avg = res.averaged()
        assert set(avg) == set(res.metrics)
        assert all(np.isfinite(v) for v in avg.values())
    assert spmd.num_updates == 2
    # one gradient per replica per step, counted exactly by the driver
    assert spmd.num_gradients == sum(
        h["replicas"] for h in spmd.extra["history"])


def test_mismatched_metric_grid_rejected():
    with pytest.raises(ValueError, match="grid"):
        RunResult(backend="sim", mode="sync", schedule=None,
                  grid_unit="virtual_s", grid=(0.0, 1.0),
                  metrics={"loss": (1.0,)})


def test_simulator_trainer_accuracy_fn_threaded():
    """The workload's accuracy_fn reaches PSTrainer via the constructor
    (not post-construction mutation): sim results have nonzero acc."""
    res = SimulatorTrainer().run(_sim_spec(mode="async", horizon=3.0))
    assert max(res.series("test_acc")) > 0.0


def test_unknown_workload_and_backend():
    with pytest.raises(ValueError, match="unknown sim workload"):
        SimulatorTrainer().run(_sim_spec(arch="resnet"))
    from repro.api import get_trainer
    with pytest.raises(ValueError, match="unknown backend"):
        get_trainer("mpi")


# ------------------------------------------------------ reshard replicas

def test_reshard_replicas_round_trips():
    import jax
    from repro.core.spmd_hybrid import reshard_replicas

    rng = np.random.default_rng(0)
    p4 = {"w": jax.numpy.asarray(rng.normal(size=(4, 3, 2)),
                                 dtype=jax.numpy.float32)}
    # identity
    assert reshard_replicas(p4, 4) is p4
    # down (average pairs) then up (broadcast copies)
    p2 = reshard_replicas(p4, 2)
    assert p2["w"].shape == (2, 3, 2)
    np.testing.assert_allclose(
        np.asarray(p2["w"][0]), np.asarray(p4["w"][:2]).mean(0), rtol=1e-6)
    p4b = reshard_replicas(p2, 4)
    assert p4b["w"].shape == (4, 3, 2)
    np.testing.assert_allclose(np.asarray(p4b["w"][0]),
                               np.asarray(p4b["w"][1]), rtol=0)
    # up then down returns the original values exactly
    p8 = reshard_replicas(p4, 8)
    back = reshard_replicas(p8, 4)
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(p4["w"]),
                               rtol=1e-6)


# ----------------------------------------------------------------- CLI

def test_cli_simulate_smoke(tmp_path):
    out = str(tmp_path / "res.json")
    spec_out = str(tmp_path / "spec.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    p = subprocess.run(
        [sys.executable, "-m", "repro", "simulate", "--smoke",
         "--out", out, "--save-spec", spec_out],
        capture_output=True, text=True, timeout=420, env=env, cwd=REPO)
    assert p.returncode == 0, p.stderr[-2000:]
    res = RunResult.from_json(open(out).read())
    assert res.backend == "sim" and res.averaged()
    assert json.loads(p.stdout)["averaged"].keys() == res.averaged().keys()
    # the emitted spec re-runs to an identical result (reproducibility)
    again = run(ExperimentSpec.load(spec_out))
    assert again.metrics == res.metrics


def test_cli_deprecated_shims_still_work():
    """Old entry points keep working (with DeprecationWarning)."""
    import warnings
    from repro.core.schedule import SCHEDULES
    from repro.core.simulator import PSTrainer  # noqa: F401 (import path)
    from repro.launch.train import train        # noqa: F401 (import path)

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        fam = SCHEDULES["step"]
        assert fam(8, 10)(25) == 3
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
