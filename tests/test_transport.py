"""Transport conformance suite.

One parametrized battery run against every :class:`repro.cluster.
transport.Transport` implementation — ``InProcTransport``,
``SocketTransport`` (TCP and Unix-domain), ``ProcTransport``, and the
multi-host ``HostTransport`` — pinning the semantics the cluster
runtime relies on: per-worker FIFO gradient delivery with bitwise
payload integrity, end-to-end backpressure on a full channel (with
exact conservation through it), the ``fetch_params(min_version=...)``
sync barrier, the version-goes-*backwards* broadcast a checkpoint
restore produces, and the uniform timeout contract (``None`` blocks,
``<= 0`` polls).

The socket transports are exercised hub + worker-endpoint in one
process here (the frames still cross a real socket); the end-to-end
multi-process runs live in ``tests/test_mpcluster.py`` and the
multi-host (leader + joined process groups) runs in
``tests/test_hostlink.py``.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.hostlink import HostTransport
from repro.cluster.mptransport import ProcTransport, SocketTransport
from repro.cluster.server import ParameterServer
from repro.cluster.transport import (GradientMsg, InProcTransport,
                                     ParamsMsg)
from repro.core.schedule import constant_schedule

KINDS = ["inproc", "socket-tcp", "socket-unix", "proc", "host"]


def make_pair(kind: str, cap: int):
    """(server_side, worker_endpoint, close_fn) for one transport kind.

    For ``inproc`` both sides are the same object; for the socket
    transports the worker endpoint is a real client connected to the
    hub's address."""
    if kind == "inproc":
        t = InProcTransport(grad_capacity=cap)
        return t, t, t.close
    if kind == "proc":
        hub = ProcTransport(cap, family="unix")
    elif kind == "host":
        hub = HostTransport(cap, host="127.0.0.1", port=0,
                            num_workers=4, welcome_config={})
    else:
        hub = SocketTransport(
            cap, family="tcp" if kind == "socket-tcp" else "unix")
    client = hub.connect(0)

    def close():
        client.close()
        hub.close()
    return hub, client, close


def drain_all(server, client, got=0, deadline_s: float = 10.0):
    """Drain the gradient channel to empty *after* flushing + closing
    the worker endpoint — the only state in which counts are exact.
    Flush and drain must interleave: a backpressured sender can only
    finish its accepted frames if the server keeps making room."""
    deadline = time.monotonic() + deadline_s
    if client is not server:
        while not client.flush(0.05):
            while server.recv_gradient(timeout=0) is not None:
                got += 1
            assert time.monotonic() < deadline, "endpoint failed to flush"
        client.close()
    while True:
        while server.recv_gradient(timeout=0) is not None:
            got += 1
        if server.quiesce(timeout=0.1):
            break
        assert time.monotonic() < deadline, "transport failed to quiesce"
    while server.recv_gradient(timeout=0) is not None:
        got += 1
    assert server.pending_gradients() == 0
    return got


@pytest.mark.parametrize("kind", KINDS)
def test_fifo_order_and_bitwise_payload(kind):
    server, client, close = make_pair(kind, cap=16)
    try:
        rng = np.random.default_rng(0)
        sent = [rng.normal(size=64).astype(np.float32) for _ in range(5)]
        for i, g in enumerate(sent):
            assert client.send_gradient(GradientMsg(0, g, 7, i + 1),
                                        timeout=5.0)
        for i, g in enumerate(sent):
            msg = server.recv_gradient(timeout=5.0)
            assert msg is not None
            assert (msg.worker_id, msg.version, msg.seq) == (0, 7, i + 1)
            # f32 slabs must round-trip bitwise — the cross-process
            # parity guarantee starts here
            assert np.asarray(msg.grad).tobytes() == g.tobytes()
        assert server.recv_gradient(timeout=0) is None
    finally:
        close()


@pytest.mark.parametrize("kind", KINDS)
def test_backpressure_blocks_sender_and_conserves(kind):
    """A full bounded channel must (eventually) refuse a timed send —
    for sockets that means queue + kernel buffers + outbound queue all
    filled up, i.e. physical end-to-end backpressure — and every
    gradient accepted before that must still be delivered exactly
    once."""
    server, client, close = make_pair(kind, cap=2)
    try:
        big = np.zeros(1 << 18, np.float32)         # 1 MiB frames
        sent_ok, refused = 0, False
        for i in range(64):
            if client.send_gradient(GradientMsg(0, big, 0, sent_ok + 1),
                                    timeout=0.05):
                sent_ok += 1
            else:
                refused = True
                break
        assert refused, f"64 x 1MiB sends never hit backpressure ({kind})"
        assert drain_all(server, client) == sent_ok
    finally:
        close()


@pytest.mark.parametrize("kind", KINDS)
def test_fetch_params_min_version_barrier(kind):
    server, client, close = make_pair(kind, cap=4)
    try:
        assert client.fetch_params(timeout=0.05) is None  # nothing yet
        server.publish_params(ParamsMsg(1, np.full(8, 1.0, np.float32)))
        msg = client.fetch_params(min_version=1, timeout=5.0)
        assert msg is not None and msg.version == 1
        assert np.asarray(msg.params).tobytes() \
            == np.full(8, 1.0, np.float32).tobytes()
        # the barrier: v2 is not there yet
        assert client.fetch_params(min_version=2, timeout=0.1) is None
        t = threading.Timer(0.25, server.publish_params,
                            (ParamsMsg(2, np.full(8, 2.0, np.float32)),))
        t.start()
        try:
            msg = client.fetch_params(min_version=2, timeout=5.0)
            assert msg is not None and msg.version == 2
        finally:
            t.join()
    finally:
        close()


@pytest.mark.parametrize("kind", KINDS)
def test_version_goes_backwards_on_restore(kind):
    """A checkpoint restore publishes an OLDER version; the broadcast
    must overwrite unconditionally (not keep the max) so workers can
    resync to the restored round."""
    server, client, close = make_pair(kind, cap=4)
    try:
        server.publish_params(ParamsMsg(5, np.full(4, 5.0, np.float32)))
        assert client.fetch_params(min_version=5, timeout=5.0).version == 5
        server.publish_params(ParamsMsg(2, np.full(4, 2.0, np.float32)))
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            cur = client.fetch_params(timeout=0.05)
            if cur is not None and cur.version == 2:
                break
        assert cur.version == 2, cur
        assert np.asarray(cur.params).tobytes() \
            == np.full(4, 2.0, np.float32).tobytes()
    finally:
        close()


@pytest.mark.parametrize("kind", KINDS)
def test_timeout_contract(kind):
    """``timeout <= 0`` polls (never blocks); ``None`` blocks until the
    call can complete.  (send_gradient(None) blocking on a full channel
    is covered by the worker retry loop + backpressure test.)"""
    server, client, close = make_pair(kind, cap=2)
    try:
        t0 = time.monotonic()
        assert server.recv_gradient(timeout=0) is None
        assert client.fetch_params(timeout=0) is None
        assert time.monotonic() - t0 < 0.5      # polls, no waiting

        out = []
        th = threading.Thread(
            target=lambda: out.append(server.recv_gradient()),  # None
            daemon=True)
        th.start()
        th.join(0.3)
        assert th.is_alive(), "recv_gradient(timeout=None) must block"
        assert client.send_gradient(
            GradientMsg(0, np.ones(4, np.float32), 0, 1), timeout=5.0)
        th.join(5.0)
        assert not th.is_alive() and out[0].seq == 1
    finally:
        close()


# ---------------------------------------------------- membership churn
#
# Server-level conformance for elastic fleets: the sync barrier must
# degrade to the *live* membership when a worker dies mid-round, growth
# mid-round/mid-buffer must preserve what is already staged, and the
# conservation ledger must stay exact through all of it.  Policy only —
# the wire-level churn (leases, grace windows, auth) lives in
# ``tests/test_hostlink.py``.

def _churn_server(mode, num_workers, schedule=None):
    params = {"b": jnp.zeros((4,), jnp.float32),
              "w": jnp.arange(8, dtype=jnp.float32)}
    transport = InProcTransport(grad_capacity=16)
    server = ParameterServer(params, lr=0.05, mode=mode,
                             transport=transport,
                             num_workers=num_workers, schedule=schedule)
    return server, transport


def _grad(server, fill):
    return server.codec.encode(
        {"b": jnp.full((4,), fill, jnp.float32),
         "w": jnp.full((8,), 2.0 * fill, jnp.float32)})


def test_sync_round_completes_after_mid_round_worker_death():
    """A sync round blocked on a worker that dies mid-round must
    complete with the survivors' gradients the moment the death is
    known — degrading the barrier to live membership, never
    deadlocking — and account every gradient it saw."""
    server, t = _churn_server("sync", 3)
    try:
        for w in range(3):
            server.register(w)
        server.ingest(GradientMsg(0, _grad(server, 0.1), 0, 1))
        server.ingest(GradientMsg(1, _grad(server, 0.2), 0, 1))
        assert server.version == 0          # barrier waits on worker 2
        server.deregister(2)                # mid-round death
        assert server.version == 1          # completed with the living
        acct = server.accounting()
        assert acct["applied"] == 2 and acct["pending_round"] == 0
        assert acct["dropped"] == 0
    finally:
        t.close()


def test_sync_grow_mid_round_bitwise_and_ledger_exact():
    """Grow / shrink / re-lease, sync policy: a fleet seeded at 2 that
    grows to 4 *mid-round* must produce bitwise the same parameters as
    a fleet of 4 from the start (the staging resize preserves what the
    newcomers then complete), a post-shrink stale replay from the
    re-leased id is dropped and accounted, and the ledger is exact
    across the whole churn."""
    fixed, tA = _churn_server("sync", 4)
    grown, tB = _churn_server("sync", 2)
    try:
        g = [_grad(fixed, 0.1 * (w + 1)) for w in range(4)]
        for w in range(4):
            fixed.register(w)
        for w in range(2):
            grown.register(w)
        # part of the round arrives before the fleet grows (one short
        # of the seed barrier, so the round is still open)
        grown.ingest(GradientMsg(0, g[0], 0, 1))
        grown.grow_fleet(4)                 # elastic admission
        grown.register(2)
        grown.register(3)
        assert grown.version == 0           # barrier now spans 4 ids
        for w in range(1, 4):
            grown.ingest(GradientMsg(w, g[w], 0, 1))
        for w in range(4):
            fixed.ingest(GradientMsg(w, g[w], 0, 1))
        assert fixed.version == 1 and grown.version == 1
        assert np.asarray(grown.agg.params_slab).tobytes() \
            == np.asarray(fixed.agg.params_slab).tobytes()

        # shrink: worker 3 dies before contributing to round v1; its
        # re-leased successor first replays a stale v0 gradient (the
        # predecessor's in-flight frame) — dropped, never applied
        grown.deregister(3)
        grown.ingest(GradientMsg(3, g[3], 0, 2))        # stale replay
        grown.register(3)
        for w in range(4):
            grown.ingest(GradientMsg(w, g[w], 1, 2))
        assert grown.version == 2
        acct = grown.accounting()
        ingested = 4 + 1 + 4
        assert ingested == (acct["applied"] + acct["dropped"]
                            + acct["buffered"] + acct["pending_round"])
        assert acct["applied"] == 8 and acct["dropped"] == 1
    finally:
        tA.close()
        tB.close()


def test_hybrid_grow_mid_buffer_preserves_staged_rows():
    """Hybrid policy: gradients staged *before* a mid-buffer grow (the
    buffer below K, rows already written into staging) must survive the
    resize — the flush after growth is bitwise identical to a fleet
    that was large from the start, and the ledger stays exact."""
    fixed, tA = _churn_server("hybrid", 4, constant_schedule(4, 3))
    grown, tB = _churn_server("hybrid", 2, constant_schedule(2, 2))
    try:
        g = [_grad(fixed, 0.3 * (w + 1)) for w in range(3)]
        # one gradient staged, buffer below K — then the fleet grows
        # and the re-derived schedule raises K to 3
        grown.ingest(GradientMsg(0, g[0], 0, 1))
        assert grown.version == 0 and len(grown.buffer) == 1
        grown.grow_fleet(4, constant_schedule(4, 3))
        grown.ingest(GradientMsg(1, g[1], 0, 1))
        grown.ingest(GradientMsg(2, g[2], 0, 1))
        for w in range(3):
            fixed.ingest(GradientMsg(w, g[w], 0, 1))
        assert fixed.version == 1 and grown.version == 1
        assert np.asarray(grown.agg.params_slab).tobytes() \
            == np.asarray(fixed.agg.params_slab).tobytes()
        acct = grown.accounting()
        assert 3 == (acct["applied"] + acct["dropped"]
                     + acct["buffered"] + acct["pending_round"])
        assert acct["applied"] == 3 and acct["buffered"] == 0
    finally:
        tA.close()
        tB.close()


def test_socket_broadcast_reaches_every_worker():
    """publish_params is a broadcast: N connected workers each see the
    latest version (and late joiners get the current params on
    connect)."""
    hub = SocketTransport(4, family="tcp")
    clients = []
    try:
        hub.publish_params(ParamsMsg(3, np.arange(6, dtype=np.float32)))
        clients = [hub.connect(w) for w in range(3)]
        for c in clients:
            msg = c.fetch_params(min_version=3, timeout=5.0)
            assert msg is not None and msg.version == 3
        assert hub.wait_for_workers(3, timeout=5.0)
        assert hub.live_workers() == {0, 1, 2}
    finally:
        for c in clients:
            c.close()
        hub.close()
