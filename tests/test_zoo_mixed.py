"""Mixed-precision slabs + model-zoo cluster workloads.

Four layers, matching the refactor they gate:

  * **dtype-aware codec** — property tests over mixed f32/bf16/f16
    pytrees (decode restores the original per-leaf dtypes; an f32
    round trip is bitwise for any <=32-bit floating input), the f32
    codec staying byte-identical to the historical default, and the
    rejection errors still naming the offending leaf path;
  * **P-sharded staging** — the sharded aggregator's flush is bitwise
    identical to the unsharded one (chunking changes the layout, never
    the arithmetic);
  * **wire negotiation** — HELLO sizes (bare v1 frame for f32 peers,
    one trailing dtype byte otherwise), bf16 slab payloads at half the
    f32 byte count round-tripping bitwise, unknown dtype codes
    rejected with a readable reason, and a real socket run where bf16
    negotiation halves the telemetry wire counters per gradient;
  * **zoo workloads** — registry-built configs scale to tile-aligned
    widths, and a >=1M-parameter ``zoo:transformer`` trains end to end
    on ``backend=cluster`` over the proc AND host transports with the
    conservation ledger exact, sync f32 runs bitwise-reproducible, and
    bf16 cutting per-gradient wire bytes ~2x.
"""
import socket
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.api import ExperimentSpec, run
from repro.cluster import mptransport as mpt
from repro.cluster.hostlink import spawn_join_process
from repro.cluster.mptransport import SocketTransport
from repro.cluster.trainer import ClusterTrainer
from repro.core.slab import (SlabAggregator, resolve_slab_dtype,
                             shard_chunks, slab_codec)
from repro.kernels.hybrid_aggregate import TILE_P
from repro.models.zoo import ZOO_TIERS, num_params, zoo_config

CHILD_PLATFORM = None if jax.default_backend() == "cpu" else "cpu"


def _poll(predicate, timeout_s: float = 5.0, what: str = "condition"):
    deadline = time.monotonic() + timeout_s
    while not predicate():
        assert time.monotonic() < deadline, f"timed out waiting: {what}"
        time.sleep(0.02)


def _check_conservation(res):
    a = res.extra["accounting"]
    assert a["computed"] == (a["applied"] + a["dropped"] + a["buffered"]
                             + a["pending_round"] + a["in_flight"]), a
    assert res.num_gradients == a["applied"]
    return a


# ------------------------------------------------- dtype-aware codec

_FLOATS = [jnp.float32, jnp.bfloat16, jnp.float16]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n_leaves=st.integers(1, 4),
       slab_bf16=st.booleans())
def test_codec_mixed_dtype_round_trip_property(seed, n_leaves,
                                               slab_bf16):
    """Property: for any mixed f32/bf16/f16 tree, decode restores the
    original per-leaf dtypes and shapes; with an f32 slab the round
    trip is bitwise (every <=32-bit float widens losslessly), and
    leaves already in the slab dtype are bitwise under either slab."""
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i in range(n_leaves):
        key, k = jax.random.split(key)
        dt = _FLOATS[(seed + i) % len(_FLOATS)]
        tree[f"leaf{i}"] = jax.random.normal(
            k, (3 + i, 5)).astype(dt)
    name = "bf16" if slab_bf16 else "f32"
    codec = slab_codec(tree, name)
    slab = codec.encode(tree)
    assert slab.dtype == resolve_slab_dtype(name)
    assert slab.shape == (codec.padded_size,)
    back = codec.decode(slab)
    for leaf_name, want in tree.items():
        got = back[leaf_name]
        assert got.dtype == want.dtype and got.shape == want.shape
        exact = (name == "f32") or want.dtype == jnp.bfloat16
        if exact:
            np.testing.assert_array_equal(
                np.asarray(got, np.float32), np.asarray(want, np.float32))
        else:
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=1e-2, atol=1e-2)


def test_f32_codec_is_the_historical_default_byte_for_byte():
    """`slab_codec(tree, "f32")` IS `slab_codec(tree)` — same cached
    object, same compiled executables — and its slab bytes are the
    historical layout exactly."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (33, 17)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (17,))}
    default = slab_codec(tree)
    explicit = slab_codec(tree, "f32")
    assert default is explicit
    slab = np.asarray(default.encode(tree))
    flat = np.concatenate([np.asarray(v).ravel()
                           for v in jax.tree_util.tree_leaves(tree)])
    want = np.pad(flat, (0, default.padded_size - default.size))
    assert slab.tobytes() == want.astype("<f4").tobytes()


def test_codec_errors_name_the_offending_path():
    with pytest.raises(TypeError, match=r"ids"):
        slab_codec({"layer0": {"ids": jnp.zeros((3,), jnp.int32),
                               "w": jnp.zeros((3,))}})
    # >32-bit floats are rejected too (they would quantize silently);
    # a raw numpy leaf keeps float64 without enabling jax x64
    with pytest.raises(TypeError, match=r"32-bit.*at \['wd'\]"):
        slab_codec({"wd": np.zeros((3,), np.float64)})


def test_bf16_slab_halves_bytes_and_master_stays_f32():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(2), (64, 64))}
    f32 = slab_codec(tree, "f32")
    bf16 = slab_codec(tree, "bf16")
    assert f32 is not bf16
    assert bf16.encode(tree).dtype == jnp.bfloat16
    assert (np.asarray(bf16.encode(tree)).nbytes * 2
            == np.asarray(f32.encode(tree)).nbytes)
    # the aggregator's master form never narrows
    assert bf16.encode_master(tree).dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(bf16.encode_master(tree)),
                                  np.asarray(f32.encode(tree)))


# ------------------------------------------------- P-sharded staging

def test_shard_chunks_tile_aligned_and_exhaustive():
    padded = 7 * TILE_P
    for shards in (1, 2, 3, 7, 11):
        chunks = shard_chunks(padded, shards)
        assert sum(chunks) == padded
        assert all(c % TILE_P == 0 for c in chunks)
        assert len(chunks) == min(shards, 7)


@pytest.mark.parametrize("dtype_name", ["f32", "bf16"])
def test_sharded_flush_bitwise_equals_unsharded(dtype_name):
    """Chunking the (K, P) staging along P must not change a single
    bit of the flushed params: the reduction is elementwise over P, so
    the per-element fold order is identical in every chunk."""
    shapes = {"w1": (64, 300), "b1": (300,), "w2": (300, 64)}
    ks = jax.random.split(jax.random.PRNGKey(7), len(shapes) * 4)
    params = {n: jax.random.normal(k, s)
              for k, (n, s) in zip(ks, sorted(shapes.items()))}
    grads = [{n: 0.01 * jax.random.normal(ks[3 + i * 3 + j], s)
              for j, (n, s) in enumerate(sorted(shapes.items()))}
             for i in range(3)]
    codec = slab_codec(params, dtype_name)
    weights = np.asarray([1.0, 0.7, 0.4], np.float32)
    outs = {}
    for shards in (1, 2):
        agg = SlabAggregator(codec, params, k_max=3, shards=shards)
        assert agg.shards == shards
        for slot, g in enumerate(grads):
            agg.stage(codec.encode(g), slot)
        agg.flush_apply(weights, 0.1)
        outs[shards] = np.asarray(agg.params_slab)
    assert outs[1].tobytes() == outs[2].tobytes()


# ------------------------------------------------- wire negotiation

def test_hello_frame_sizes_pin_v1_for_f32():
    """An f32 peer's HELLO is the pinned v1 frame bit-for-bit; only a
    non-f32 peer appends the single dtype byte."""
    f32 = mpt._hello_frame(3, 1)
    assert f32 == mpt._hello_frame(3, 1, "f32")
    assert len(f32) == mpt._HDR.size + mpt._HELLO.size
    bf16 = mpt._hello_frame(3, 1, "bf16")
    assert len(bf16) == mpt._HDR.size + mpt._HELLO_DT.size
    assert bf16[-1] == mpt._DT_BF16
    # the common prefix (magic, proto, id, generation) is unchanged
    assert bf16[mpt._HDR.size:mpt._HDR.size + mpt._HELLO.size] \
        == f32[mpt._HDR.size:]


def test_bf16_slab_payload_half_bytes_round_trips_bitwise():
    rng = np.random.default_rng(0)
    slab = jnp.asarray(rng.standard_normal(4096),
                       jnp.float32).astype(jnp.bfloat16)
    payload = mpt._slab_to_bytes(slab, "bf16")
    assert len(payload) == 2 * slab.size
    assert len(mpt._slab_to_bytes(slab.astype(jnp.float32), "f32")) \
        == 4 * slab.size
    back = mpt._slab_from_payload(payload, 0, "bf16")
    assert back.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back, np.float32),
                                  np.asarray(slab, np.float32))


def test_unknown_hello_dtype_code_rejected():
    """A HELLO' carrying a dtype code this build does not know is
    rejected whole — never admitted as a garbled f32 worker."""
    hub = SocketTransport(4, family="tcp")
    try:
        peer = socket.create_connection(tuple(hub.address), timeout=5.0)
        peer.sendall(
            mpt._HDR.pack(mpt._F_HELLO, mpt._HELLO_DT.size)
            + mpt._HELLO_DT.pack(mpt._MAGIC, mpt._PROTO_VERSION,
                                 0, 0, 7))
        _poll(lambda: hub.rejected_peers == 1,
              what="unknown dtype code rejected")
        assert hub.live_workers() == set()
        peer.close()
    finally:
        hub.close()


def test_socket_bf16_negotiation_halves_wire_bytes_per_gradient():
    """The same budgeted run over the socket transport, once at f32
    and once at bf16: the telemetry wire counters per computed
    gradient must come out ~2x smaller at bf16 — the negotiated slab
    payload dominates the frame."""
    per_grad = {}
    for name in ("f32", "bf16"):
        spec = ExperimentSpec(
            arch="mlp", backend="cluster", mode="async", smoke=True,
            transport="socket", cluster_workers=2, wall_budget_s=20.0,
            wall_sample_every_s=5.0, batch=16, max_gradients=16,
            slab_dtype=name)
        res = run(spec)
        a = _check_conservation(res)
        counters = res.extra["telemetry"]["counters"]
        assert counters["wire.tx_bytes"] > 0
        per_grad[name] = counters["wire.rx_bytes"] / a["computed"]
    ratio = per_grad["f32"] / per_grad["bf16"]
    assert 1.8 < ratio < 2.2, per_grad


# --------------------------------------------------- zoo workloads

def test_zoo_config_scaling_is_tile_friendly():
    for kind in ZOO_TIERS:
        for scale in (0.125, 0.25, 0.5):
            cfg = zoo_config(kind, scale)
            assert cfg.d_model % 64 == 0
            assert cfg.vocab_size % 64 == 0
            assert cfg.num_groups >= 1
            if cfg.num_heads:
                assert cfg.head_dim * cfg.num_heads == cfg.d_model
                if cfg.num_kv_heads:
                    assert cfg.num_heads % cfg.num_kv_heads == 0
    # scale 1.0 reproduces the published tier widths
    full = zoo_config("xlstm", 1.0)
    base = ZOO_TIERS["xlstm"]()
    assert (full.d_model, full.num_groups) == (base.d_model,
                                               base.num_groups)
    with pytest.raises(ValueError, match="zoo:"):
        zoo_config("cobol-net", 0.25)


def test_zoo_transformer_meets_the_million_parameter_floor():
    from repro.models import model as M
    cfg = zoo_config("transformer", 0.25)
    p = num_params(M.init_params(jax.random.PRNGKey(0), cfg))
    assert p >= 1_000_000, p


def test_zoo_transformer_proc_e2e_exact_ledger():
    """A >=1M-parameter registry transformer trains end to end over
    the proc transport — every worker its own OS process rebuilding
    the zoo workload from spec JSON — with the ledger exact and real
    wire traffic in the telemetry."""
    spec = ExperimentSpec(
        arch="zoo:transformer", backend="cluster", mode="async",
        smoke=True, zoo_scale=0.25, transport="proc",
        cluster_workers=2, wall_budget_s=90.0,
        wall_sample_every_s=30.0, batch=4, max_gradients=6)
    res = run(spec)
    a = _check_conservation(res)
    assert a["applied"] > 0
    counters = res.extra["telemetry"]["counters"]
    assert counters["wire.tx_bytes"] > 0
    assert counters["wire.rx_bytes"] > 0
    losses = res.metrics["train_loss"]
    assert losses and all(np.isfinite(x) for x in losses)


def test_zoo_transformer_host_e2e_bf16_halves_wire():
    """The same >=1M-parameter transformer over the host transport —
    a leader plus two separately launched `repro join` process groups
    — negotiated down to bf16: ledger exact, and the uplink bytes per
    computed gradient sit at the 2-byte/element slab, not the 4-byte
    f32 one."""
    from repro.models import model as M
    spec = ExperimentSpec(
        arch="zoo:transformer", backend="cluster", mode="async",
        smoke=True, zoo_scale=0.25, slab_dtype="bf16",
        transport="host", listen="127.0.0.1:0", cluster_workers=2,
        wall_budget_s=120.0, wall_sample_every_s=30.0, batch=4,
        max_gradients=6)
    trainer = ClusterTrainer()
    runtime = trainer.build_runtime(spec)
    procs = [spawn_join_process(runtime.listen_address, workers=1,
                                platform=CHILD_PLATFORM)
             for _ in range(2)]
    try:
        res = trainer.finish(runtime, spec)
    finally:
        codes = []
        for p in procs:
            try:
                codes.append(p.wait(timeout=90))
            except Exception:
                p.kill()
                codes.append("killed")
    assert codes == [0, 0], codes
    a = _check_conservation(res)
    assert a["applied"] > 0
    counters = res.extra["telemetry"]["counters"]
    cfg = zoo_config("transformer", 0.25)
    p_count = num_params(M.init_params(jax.random.PRNGKey(0), cfg))
    f32_slab_bytes = 4 * p_count
    rx_per_grad = counters["wire.rx_bytes"] / a["computed"]
    assert rx_per_grad < 0.75 * f32_slab_bytes, \
        (rx_per_grad, f32_slab_bytes)


def test_zoo_sync_f32_bitwise_reproducible():
    """Two identical sync f32 zoo runs produce bit-identical final
    parameters — the mixed-precision refactor leaves the historical
    f32 path untouched down to the last bit."""
    finals = []
    for _ in range(2):
        trainer = ClusterTrainer()
        res = trainer.run(ExperimentSpec(
            arch="zoo:transformer", backend="cluster", mode="sync",
            smoke=True, zoo_scale=0.125, transport="inproc",
            cluster_workers=2, wall_budget_s=60.0,
            wall_sample_every_s=20.0, batch=4, max_gradients=8))
        a = _check_conservation(res)
        assert a["applied"] == 8
        finals.append(trainer.last_params)
    flat0 = jax.tree_util.tree_leaves(finals[0])
    flat1 = jax.tree_util.tree_leaves(finals[1])
    assert len(flat0) == len(flat1)
    for x, y in zip(flat0, flat1):
        assert np.array_equal(np.asarray(x), np.asarray(y))
