"""Tests for ``repro.checkpoint.ckpt``: save/restore round-trips
(pytree structure, dtypes — including bf16's npz upcast/downcast — and
values), ``latest_step`` discovery, and the restore-into-a-running-
cluster path (K(t) resumes from the restored step, not step 0)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, FaultPlan, parse_schedule
from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.cluster.trainer import ClusterTrainer


def _tree():
    return {
        "dense": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                  "b": jnp.ones((4,), jnp.float32)},
        "embed": jnp.asarray([[1, 2], [3, 4]], jnp.int32),
        "scale": jnp.asarray([0.5, -2.0], jnp.bfloat16),
        "stack": [jnp.zeros((2, 2), jnp.float32),
                  jnp.full((3,), 7, jnp.float32)],
    }


def test_ckpt_round_trip_structure_and_dtypes(tmp_path):
    tree = _tree()
    path = str(tmp_path / "step_5")
    save_checkpoint(path, tree, step=5, extra={"note": "hi"})
    like = jax.tree.map(jnp.zeros_like, tree)
    back, step = restore_checkpoint(path, like)
    assert step == 5
    assert jax.tree_util.tree_structure(back) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype        # bf16 restored as bf16
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_ckpt_restore_shape_mismatch_caught(tmp_path):
    path = str(tmp_path / "step_0")
    save_checkpoint(path, {"w": jnp.zeros((2, 2))}, step=0)
    with pytest.raises(AssertionError):
        restore_checkpoint(path, {"w": jnp.zeros((3, 3))})


def test_latest_step(tmp_path):
    d = str(tmp_path)
    assert latest_step(d) is None
    for s in (3, 11, 7):
        save_checkpoint(os.path.join(d, f"step_{s}"),
                        {"w": jnp.zeros(2)}, step=s)
    assert latest_step(d) == 11
    assert latest_step(str(tmp_path / "missing")) is None


def test_cluster_resume_continues_mid_schedule(tmp_path):
    """Restoring a checkpoint into a cluster run resumes the K(t)
    schedule from the restored step: the server's version starts at the
    checkpoint step, so the threshold picks up mid-anneal instead of
    re-opening at K=1."""
    d = str(tmp_path)
    spec = ExperimentSpec(
        arch="mlp", backend="cluster", mode="hybrid", schedule="step:10",
        cluster_workers=3, wall_budget_s=1.5, wall_sample_every_s=0.5,
        batch=16, faults=FaultPlan(checkpoint_every_s=0.4))
    first = ClusterTrainer(ckpt_dir=d).run(spec)
    step = latest_step(d)
    assert step is not None and step > 10, \
        f"first run too short to cross a schedule step ({step})"
    assert any(e["event"] == "checkpoint" for e in first.extra["events"])

    resumed = ClusterTrainer(
        resume_from=os.path.join(d, f"step_{step}")).run(
            spec.with_(faults=FaultPlan(), wall_budget_s=1.0))
    assert resumed.extra["start_version"] == step
    # mid-schedule: the threshold at the restored step is already > 1
    schedule = parse_schedule(spec.schedule, spec.cluster_workers)
    assert schedule(step) > 1
    # and the run continued from there (fresh updates counted from the
    # restored version, not from 0)
    assert resumed.num_updates > 0
    a = resumed.extra["accounting"]
    assert a["updates"] == resumed.num_updates
