"""Per-architecture smoke tests: reduced same-family variants run one
forward + one SGD train step on CPU; output shapes and finiteness are
asserted.  Decode-capable archs also run one cached decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import (ARCH_NAMES, get_config, smoke_batch,
                                    smoke_variant)
from repro.models import model as M
from repro.optim import sgd


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch, rng):
    cfg = smoke_variant(get_config(arch))
    cfg.validate()
    params = M.init_params(rng, cfg)
    batch = smoke_batch(cfg)
    opt = sgd(0.1)
    opt_state = opt.init(params)

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg), has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss, metrics

    logits, aux = M.forward(params, batch, cfg)
    B = batch.get("tokens", batch.get("features")).shape[0]
    S = 32  # smoke seq (vision: image+text tokens sum to this)
    assert logits.shape == (B, S, cfg.vocab_size), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    new_params, opt_state, loss, _ = train_step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss))
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert delta > 0.0

    loss2 = M.loss_fn(new_params, batch, cfg)[0]
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if get_config(a).has_decode])
def test_smoke_decode_step(arch, rng):
    cfg = smoke_variant(get_config(arch))
    params = M.init_params(rng, cfg)
    B, max_seq = 2, 32
    cache = M.init_cache(cfg, B, max_seq)
    tokens = jnp.ones((B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda c, t, i: M.decode_step(params, c, t, i, cfg)
    )(cache, tokens, jnp.int32(5))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_exact_assigned_configs():
    """The full configs must match the assignment table exactly."""
    c = get_config("qwen1.5-110b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (80, 8192, 64, 8, 49152, 152064)
    assert c.attn_bias
    c = get_config("qwen2.5-32b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (64, 5120, 40, 8, 27648, 152064)
    c = get_config("llama4-scout-17b-a16e")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.vocab_size) == (48, 5120, 40, 8, 202048)
    assert c.num_experts == 16 and c.num_experts_per_tok == 1
    c = get_config("deepseek-v2-lite-16b")
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size) \
        == (27, 2048, 16, 102400)
    assert c.kv_lora_rank == 512 and c.num_experts == 64 \
        and c.num_experts_per_tok == 6
    c = get_config("hubert-xlarge")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) \
        == (48, 1280, 16, 5120, 504)
    assert c.encoder_only
    c = get_config("phi-3-vision-4.2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) \
        == (32, 3072, 32, 8192, 32064)
    c = get_config("h2o-danube-1.8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (24, 2560, 32, 8, 6912, 32000)
    assert c.sliding_window == 4096
    c = get_config("jamba-v0.1-52b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4096, 32, 8, 14336, 65536)
    assert c.num_experts == 16 and c.num_experts_per_tok == 2
    mixers = [m for m, _ in c.block_pattern]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
    c = get_config("phi4-mini-3.8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 24, 8, 8192, 200064)
    c = get_config("xlstm-350m")
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size) \
        == (24, 1024, 4, 50304)


def test_applicability_matrix():
    from repro.configs.registry import applicable_pairs
    pairs = applicable_pairs()
    assert len(pairs) == 40
    n_ok = sum(1 for *_, ok, _ in pairs if ok)
    assert n_ok == 33  # 7 principled skips (DESIGN.md)
    skipped = {(a, s) for a, s, ok, _ in pairs if not ok}
    assert ("hubert-xlarge", "decode_32k") in skipped
    assert ("hubert-xlarge", "long_500k") in skipped
    assert ("qwen1.5-110b", "long_500k") in skipped
    assert ("jamba-v0.1-52b", "long_500k") not in skipped
    assert ("h2o-danube-1.8b", "long_500k") not in skipped
    assert ("llama4-scout-17b-a16e", "long_500k") not in skipped
    assert ("xlstm-350m", "long_500k") not in skipped
