"""Model-level correctness: row-block attention equivalence, decode ≡
forward for every cache family, MoE routing invariants, frontends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention as A
from repro.models import model as M
from repro.models.config import (ATTN, ATTN_GLOBAL, MAMBA, MLA, MLP, MLSTM,
                                 MOE, NONE, SLSTM, ModelConfig)


def _cfg(**kw):
    base = dict(name="t", arch_type="dense", d_model=64, vocab_size=128,
                block_pattern=((ATTN, MLP),), num_groups=2, num_heads=4,
                num_kv_heads=2, head_dim=16, d_ff=128, dtype="float32",
                remat="none")
    base.update(kw)
    return ModelConfig(**base)


# ------------------------------------------------------------- attention

@pytest.mark.parametrize("variant", ["causal", "swa", "chunked"])
def test_rowblock_equals_naive(variant):
    cfg = _cfg(sliding_window=256 if variant == "swa" else None,
               attn_chunk=256 if variant == "chunked" else None)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S = 1, 1024
    q = jax.random.normal(ks[0], (B, S, 4, 16))
    k = jax.random.normal(ks[1], (B, S, 2, 16))
    v = jax.random.normal(ks[2], (B, S, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    blocked = A.rowblock_attention(q, k, v, pos, cfg, q_block=128)
    naive = A.rowblock_attention(q, k, v, pos, cfg, q_block=S)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), qb=st.sampled_from([64, 128, 256]))
def test_rowblock_block_size_invariance(seed, qb):
    cfg = _cfg()
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 512, 4, 16))
    k = jax.random.normal(ks[1], (1, 512, 2, 16))
    v = jax.random.normal(ks[2], (1, 512, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(512), (1, 512))
    a = A.rowblock_attention(q, k, v, pos, cfg, q_block=qb)
    b = A.rowblock_attention(q, k, v, pos, cfg, q_block=512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# ------------------------------------------------- decode ≡ full forward

DECODE_CONFIGS = {
    "dense_gqa": _cfg(),
    "dense_bias": _cfg(attn_bias=True),
    "swa_ring": _cfg(sliding_window=8),
    "chunked": _cfg(attn_chunk=8),
    "mla": _cfg(block_pattern=((MLA, MLP),), num_kv_heads=4,
                kv_lora_rank=32, rope_head_dim=8),
    "mamba": _cfg(block_pattern=((MAMBA, MLP),), ssm_chunk=8,
                  arch_type="ssm"),
    "xlstm": _cfg(block_pattern=((MLSTM, NONE), (SLSTM, NONE)),
                  num_kv_heads=4, arch_type="ssm"),
    "moe": _cfg(block_pattern=((ATTN, MOE),), num_experts=4,
                num_experts_per_tok=2, moe_d_ff=64, num_shared_experts=1,
                moe_capacity_factor=4.0, arch_type="moe"),
    "tied": _cfg(tie_embeddings=True),
}


@pytest.mark.parametrize("name", list(DECODE_CONFIGS))
def test_decode_matches_forward(name):
    cfg = DECODE_CONFIGS[name]
    S, B = 24, 2
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = M.forward(params, {"tokens": toks}, cfg)
    cache = M.init_cache(cfg, B, S)
    step = jax.jit(lambda c, t, i: M.decode_step(params, c, t, i, cfg))
    outs = []
    for i in range(S):
        lg, cache = step(cache, toks[:, i:i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=1e-3)


# ------------------------------------------------------------------- MoE

def test_moe_router_aux_balanced_lower():
    """Aux loss is minimised (≈1·E/E = 1) under perfectly uniform routing."""
    from repro.models.moe import init_moe, moe_forward
    cfg = _cfg(block_pattern=((ATTN, MOE),), num_experts=4,
               num_experts_per_tok=1, moe_d_ff=32)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64))
    _, aux = moe_forward(params, x, cfg)
    assert float(aux) >= 1.0 - 1e-3     # E·Σ f·p ≥ 1 (Cauchy-Schwarz)


def test_moe_capacity_drops_are_bounded():
    """With a generous capacity factor no token mass is dropped: output
    equals a full dense-expert mixture computed by brute force."""
    from repro.models.moe import init_moe, moe_forward
    cfg = _cfg(block_pattern=((ATTN, MOE),), num_experts=2,
               num_experts_per_tok=2, moe_d_ff=32,
               moe_capacity_factor=8.0, moe_group_size=32)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 64))
    y, _ = moe_forward(params, x, cfg)

    # brute force: every token through every expert, weighted by router
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    w = jax.nn.softmax(logits, -1)   # top-2 of 2 experts = all, renormed = w
    ep = params["experts"]
    h = jax.nn.silu(jnp.einsum("bsd,edf->besf", x, ep["w_gate"])) \
        * jnp.einsum("bsd,edf->besf", x, ep["w_up"])
    ye = jnp.einsum("besf,efd->besd", h, ep["w_down"])
    want = jnp.einsum("bse,besd->bsd", w, ye)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100))
def test_moe_gate_weights_sum_to_one(seed):
    from repro.models.moe import init_moe, moe_forward
    cfg = _cfg(block_pattern=((ATTN, MOE),), num_experts=8,
               num_experts_per_tok=2, moe_d_ff=16,
               moe_capacity_factor=8.0)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 16, 64))
    y, aux = moe_forward(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(aux) > 0


# -------------------------------------------------------------- frontends

def test_vision_frontend_prefix_and_loss_region():
    cfg = _cfg(frontend="vision", frontend_dim=24, num_image_tokens=4,
               arch_type="vlm")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S_text = 2, 12
    batch = {"tokens": jnp.ones((B, S_text), jnp.int32),
             "image_embeds": jnp.ones((B, 4, 24)),
             "labels": jnp.ones((B, S_text), jnp.int32)}
    logits, _ = M.forward(params, batch, cfg)
    assert logits.shape == (B, 4 + S_text, cfg.vocab_size)
    loss, metrics = M.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    # changing image embeds changes text logits (fusion is real)
    batch2 = dict(batch, image_embeds=2.0 * batch["image_embeds"])
    logits2, _ = M.forward(params, batch2, cfg)
    assert not np.allclose(np.asarray(logits[:, 4:]),
                           np.asarray(logits2[:, 4:]))


def test_audio_frontend_masked_loss():
    cfg = _cfg(frontend="audio", frontend_dim=24, encoder_only=True,
               causal=False, arch_type="audio", vocab_size=32)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    feats = jax.random.normal(jax.random.PRNGKey(1), (B, S, 24))
    labels = jnp.ones((B, S), jnp.int32)
    m1 = jnp.zeros((B, S)).at[:, :4].set(1.0)
    l1, _ = M.loss_fn(params, {"features": feats, "labels": labels,
                               "loss_mask": m1}, cfg)
    m2 = jnp.ones((B, S))
    l2, _ = M.loss_fn(params, {"features": feats, "labels": labels,
                               "loss_mask": m2}, cfg)
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    assert abs(float(l1) - float(l2)) > 1e-6   # mask matters


def test_encoder_bidirectional_attention():
    """Encoder (non-causal) output at position 0 must depend on later
    positions."""
    cfg = _cfg(frontend="audio", frontend_dim=24, encoder_only=True,
               causal=False, arch_type="audio", vocab_size=32)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    feats = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 24))
    out1, _ = M.forward(params, {"features": feats}, cfg)
    feats2 = feats.at[:, -1].set(99.0)
    out2, _ = M.forward(params, {"features": feats2}, cfg)
    assert not np.allclose(np.asarray(out1[:, 0]), np.asarray(out2[:, 0]))
