"""Multi-host slab transport tests (``spec.transport = "host"``).

Three layers:

  * **pinned wire format** — slab payloads are little-endian ``<f4`` on
    encode AND decode (a byteswapped input round-trips to the same
    values; the wire bytes are LE regardless of the input's order), the
    HELLO handshake carries magic + protocol version, and malformed /
    mismatched / oversized peers are rejected with a readable, logged
    error instead of being misparsed as workers;
  * **addressing + leader discovery** — explicit ``--listen`` ports
    (with SO_REUSEADDR fast restart), JOIN/WELCOME worker-id leases
    with generation fencing, and the spec travelling over the wire;
  * **end to end** — a leader plus two *separately launched*
    ``python -m repro join`` process groups (distinct interpreters,
    distinct spec-JSON rebuilds, TCP the only link) is bitwise
    identical to ``inproc`` under a sync gradient budget, and joined
    workers exit cleanly (EOF, no strand) when the leader dies.
"""
import logging
import socket
import struct
import threading
import time

import numpy as np
import pytest

import jax

from repro.api import ExperimentSpec
from repro.cluster import mptransport as mpt
from repro.cluster.hostlink import (HostTransport, negotiate_join,
                                    parse_hostport, spawn_join_process)
from repro.cluster.mptransport import (SocketTransport,
                                       SocketWorkerClient,
                                       WireProtocolError)
from repro.cluster.trainer import ClusterTrainer
from repro.cluster.transport import GradientMsg, ParamsMsg

# joined/spawned worker process groups must not fight the parent for an
# exclusive accelerator (same rule as the proc transport's children)
CHILD_PLATFORM = None if jax.default_backend() == "cpu" else "cpu"


def _poll(predicate, timeout_s: float = 5.0, what: str = "condition"):
    deadline = time.monotonic() + timeout_s
    while not predicate():
        assert time.monotonic() < deadline, f"timed out waiting: {what}"
        time.sleep(0.02)


# ------------------------------------------------------------ addressing

def test_parse_hostport():
    assert parse_hostport("10.0.0.7:5555") == ("10.0.0.7", 5555)
    assert parse_hostport(":0") == ("127.0.0.1", 0)
    assert parse_hostport("7781") == ("127.0.0.1", 7781)
    with pytest.raises(ValueError, match="HOST:PORT"):
        parse_hostport("nonsense:port")
    with pytest.raises(ValueError, match="port"):
        parse_hostport("h:70000")


def test_tcp_explicit_port_resolved_and_fast_restart():
    """An explicit port binds that port (0 still means "pick"), the
    resolved address is exposed, and SO_REUSEADDR lets a fast restart
    rebind the same port while old connections sit in TIME_WAIT."""
    t1 = SocketTransport(2, family="tcp", port=0)
    host, port = tuple(t1.address)
    assert port != 0
    # leave a connection behind so the close puts the server side in
    # TIME_WAIT — the state a non-REUSEADDR rebind trips over
    c1 = t1.connect(0)
    assert t1.wait_for_workers(1, timeout=5.0)
    c1.close()
    t1.close()
    t2 = SocketTransport(2, family="tcp", port=port)    # immediate rebind
    try:
        assert tuple(t2.address) == (host, port)
        c2 = t2.connect(1)
        assert t2.wait_for_workers(1, timeout=5.0)
        c2.close()
    finally:
        t2.close()


def test_spec_host_transport_round_trip_and_listen_validation():
    spec = ExperimentSpec(transport="host", listen="0.0.0.0:5555",
                          backend="cluster")
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    with pytest.raises(ValueError, match="listen"):
        ExperimentSpec(transport="host", listen="not-an-address:x")


# ------------------------------------------------- pinned slab byte order

def test_slab_payload_is_little_endian_on_the_wire():
    """Encode pins ``<f4``: a byteswapped (big-endian) input produces
    the exact same wire bytes as the native little-endian one."""
    vals = np.linspace(-3.0, 7.0, 16, dtype=np.float32)
    swapped = vals.astype(">f4")            # same values, swapped bytes
    goff = mpt._HDR.size + mpt._GRAD.size
    for arr in (vals, swapped):
        frame = mpt._grad_frame(GradientMsg(3, arr, 7, 1))
        assert frame[goff:] == vals.astype("<f4").tobytes()
    poff = mpt._HDR.size + mpt._PARAMS.size
    for arr in (vals, swapped):
        frame = mpt._params_frame(ParamsMsg(5, arr, epoch=2))
        assert frame[poff:] == vals.astype("<f4").tobytes()


def test_byteswapped_payload_roundtrips_over_socket():
    """The regression the multi-host boundary demands: a gradient
    handed over as a byteswapped buffer arrives value-identical and in
    the *native* dtype (decode is explicit ``<f4``, normalized)."""
    hub = SocketTransport(4, family="tcp")
    client = hub.connect(0)
    try:
        vals = np.linspace(-1.0, 1.0, 32, dtype=np.float32)
        assert client.send_gradient(
            GradientMsg(0, vals.astype(">f4"), 1, 1), timeout=5.0)
        msg = hub.recv_gradient(timeout=5.0)
        assert msg is not None
        got = np.asarray(msg.grad)
        assert got.dtype == np.float32 and got.dtype.isnative
        assert got.tobytes() == vals.tobytes()      # bitwise, post-pin
        # and the broadcast direction
        hub.publish_params(ParamsMsg(1, vals.astype(">f4")))
        pmsg = client.fetch_params(min_version=1, timeout=5.0)
        assert pmsg is not None
        pgot = np.asarray(pmsg.params)
        assert pgot.dtype == np.float32 and pgot.dtype.isnative
        assert pgot.tobytes() == vals.tobytes()
    finally:
        client.close()
        hub.close()


# ------------------------------------------------ handshake gatekeeping

def test_garbage_connection_rejected_without_joining_barrier():
    """A stray TCP client (here: speaking HTTP) must be turned away —
    logged and counted — without crashing the hub, entering the fleet
    barrier, or wedging a reader on a garbage frame length."""
    hub = SocketTransport(4, family="tcp")
    try:
        stray = socket.create_connection(tuple(hub.address), timeout=5.0)
        stray.sendall(b"GET / HTTP/1.1\r\nHost: example\r\n\r\n")
        _poll(lambda: hub.rejected_peers == 1, what="stray rejected")
        assert hub.live_workers() == set()
        assert not hub.wait_for_workers(1, timeout=0.2)
        # the stray sees the connection die (EOF or RST, possibly after
        # a REJECT frame it cannot parse) — never a hang
        stray.settimeout(5.0)
        try:
            while stray.recv(65536):
                pass
        except OSError:
            pass        # RST: the hub closed with unread bytes pending
        stray.close()
        # the hub still serves legitimate peers afterwards
        client = hub.connect(0)
        assert hub.wait_for_workers(1, timeout=5.0)
        client.close()
    finally:
        hub.close()


def test_hello_version_mismatch_rejected_with_readable_error(caplog):
    """Right magic, wrong protocol version: the peer gets a REJECT
    frame with a human-readable reason, the hub logs it, and the
    connection never becomes a worker."""
    hub = SocketTransport(4, family="tcp")
    try:
        peer = socket.create_connection(tuple(hub.address), timeout=5.0)
        bad = (mpt._HDR.pack(mpt._F_HELLO, mpt._HELLO.size)
               + mpt._HELLO.pack(mpt._MAGIC, 99, 0, 0))
        with caplog.at_level(logging.WARNING):
            peer.sendall(bad)
            _poll(lambda: hub.rejected_peers == 1, what="peer rejected")
        assert "version mismatch" in caplog.text and "v99" in caplog.text
        peer.settimeout(5.0)
        hdr = peer.recv(mpt._HDR.size, socket.MSG_WAITALL)
        ftype, n = mpt._HDR.unpack(hdr)
        assert ftype == mpt._F_REJECT
        payload = peer.recv(n, socket.MSG_WAITALL)
        reason = payload[mpt._CTRL.size:].decode()
        assert "version mismatch" in reason and "v99" in reason
        peer.close()
        assert hub.live_workers() == set()
    finally:
        hub.close()


def test_bad_magic_and_oversized_frame_rejected():
    hub = SocketTransport(4, family="tcp")
    try:
        # wrong magic in an otherwise well-formed HELLO
        p1 = socket.create_connection(tuple(hub.address), timeout=5.0)
        p1.sendall(mpt._HDR.pack(mpt._F_HELLO, mpt._HELLO.size)
                   + mpt._HELLO.pack(0xDEADBEEF, mpt._PROTO_VERSION,
                                     0, 0))
        _poll(lambda: hub.rejected_peers == 1, what="bad magic rejected")
        p1.close()
        # an authenticated peer that loses frame sync (absurd length)
        # is cut off before the reader commits to the garbage read
        p2 = socket.create_connection(tuple(hub.address), timeout=5.0)
        p2.sendall(mpt._hello_frame(1, 0))
        _poll(lambda: 1 in hub.live_workers(), what="worker 1 admitted")
        p2.sendall(mpt._HDR.pack(mpt._F_GRAD, mpt._MAX_FRAME + 1))
        _poll(lambda: hub.rejected_peers == 2, what="oversize rejected")
        _poll(lambda: hub.live_workers() == set(),
              what="worker 1 deregistered")
        p2.close()
        # a GRAD whose slab is not whole f4 elements is rejected with a
        # readable error too — never an unhandled reader crash
        p3 = socket.create_connection(tuple(hub.address), timeout=5.0)
        p3.sendall(mpt._hello_frame(2, 0))
        _poll(lambda: 2 in hub.live_workers(), what="worker 2 admitted")
        p3.sendall(mpt._HDR.pack(mpt._F_GRAD, mpt._GRAD.size + 3)
                   + b"\x00" * (mpt._GRAD.size + 3))
        _poll(lambda: hub.rejected_peers == 3,
              what="ragged GRAD rejected")
        p3.close()
    finally:
        hub.close()


def test_silent_peer_receives_no_params_broadcast():
    """A connection that never authenticates must not receive the
    model: the params broadcast is gated on a valid HELLO, so a silent
    stray peer gets nothing while real workers still get every
    publish."""
    hub = SocketTransport(4, family="tcp")
    silent = None
    try:
        silent = socket.create_connection(tuple(hub.address),
                                          timeout=5.0)
        time.sleep(0.3)     # writer thread is up; peer stays silent
        hub.publish_params(ParamsMsg(1, np.ones(64, np.float32)))
        client = hub.connect(0)
        msg = client.fetch_params(min_version=1, timeout=5.0)
        assert msg is not None and msg.version == 1   # workers: yes
        silent.settimeout(1.0)
        try:
            got = silent.recv(4096)
        except socket.timeout:
            got = b""
        assert got == b"", "stray peer received broadcast bytes"
        client.close()
    finally:
        if silent is not None:
            silent.close()
        hub.close()


def test_out_of_range_hello_rejected():
    """A direct HELLO naming a worker id outside the fleet must not be
    admitted — it would satisfy the fleet-ready barrier while its data
    shard does not exist."""
    hub = HostTransport(4, host="127.0.0.1", port=0, num_workers=2,
                        welcome_config={})
    try:
        stray = SocketWorkerClient(tuple(hub.address), 7, generation=0,
                                   family="tcp")
        assert stray.closed.wait(5.0)
        assert "out of range" in (stray.reject_reason or "")
        stray.close()
        assert hub.live_workers() == set()
    finally:
        hub.close()


def test_rehello_rejected_and_no_ghost_registration():
    """One connection identifies itself exactly once: a second HELLO
    (e.g. under a different worker id) is a protocol violation.  The
    misbehaving connection is dropped whole, so the barrier never keeps
    a ghost worker id that no connection backs."""
    hub = SocketTransport(4, family="tcp")
    gone = []
    hub.on_worker_gone = lambda wid, gen: gone.append(wid)
    try:
        peer = socket.create_connection(tuple(hub.address), timeout=5.0)
        peer.sendall(mpt._hello_frame(0, 0))
        _poll(lambda: 0 in hub.live_workers(), what="worker 0 admitted")
        peer.sendall(mpt._hello_frame(1, 0))       # re-HELLO, new id
        _poll(lambda: hub.rejected_peers == 1, what="re-HELLO rejected")
        _poll(lambda: hub.live_workers() == set(),
              what="no ghost worker left behind")
        assert gone == [0]      # the original id was deregistered
        peer.close()
    finally:
        hub.close()


def test_client_surfaces_reject_reason():
    """A fenced/rejected worker endpoint closes with the hub's readable
    reason on ``reject_reason`` instead of spinning."""
    hub = HostTransport(4, host="127.0.0.1", port=0, num_workers=2,
                        welcome_config={})
    live = hub.connect(1)
    try:
        assert hub.wait_for_workers(1, timeout=5.0)
        dup = hub.connect(1)        # same worker id, same generation
        assert dup.closed.wait(5.0)
        assert "live connection" in (dup.reject_reason or "")
        dup.close()
    finally:
        live.close()
        hub.close()


# --------------------------------------------------- leases and fencing

def test_join_lease_negotiation_and_generation_fencing():
    hub = HostTransport(4, host="127.0.0.1", port=0, num_workers=2,
                        welcome_config={"spec": {"arch": "mlp"}})
    addr = tuple(hub.address)
    socks = []
    try:
        s0, cfg0 = negotiate_join(addr)
        socks.append(s0)
        assert (cfg0["worker_id"], cfg0["generation"]) == (0, 0)
        assert cfg0["num_workers"] == 2
        assert cfg0["spec"] == {"arch": "mlp"}      # the wire contract

        # the lease window is protected: worker 0 is leased but still
        # "compiling" (no HELLO yet) — a direct HELLO for its id at the
        # current generation must not steal the shard from under it
        impostor = SocketWorkerClient(addr, 0, generation=0,
                                      family="tcp")
        assert impostor.closed.wait(5.0)
        assert "live connection" in (impostor.reject_reason or "")
        impostor.close()
        s1, cfg1 = negotiate_join(addr)
        socks.append(s1)
        assert (cfg1["worker_id"], cfg1["generation"]) == (1, 0)
        # lease contention is retried within connect_timeout (it can
        # resolve as the fleet churns), so expecting the failure needs
        # a short deadline; an out-of-range id fails immediately
        with pytest.raises(WireProtocolError, match="full"):
            negotiate_join(addr, connect_timeout=0.5)
        with pytest.raises(WireProtocolError, match="already joined"):
            negotiate_join(addr, worker_id=1, connect_timeout=0.5)
        t0 = time.monotonic()
        with pytest.raises(WireProtocolError, match="out of range"):
            negotiate_join(addr, worker_id=5, connect_timeout=30.0)
        assert time.monotonic() - t0 < 5.0      # permanent: no retry

        # a rejoining host resumes its shard (same worker id), fenced
        # by a bumped generation — not a duplicate.  The rejoin may
        # race the hub reaping the dead predecessor's connection;
        # negotiate_join retries that transient rejection itself
        s1.close()
        s1b, cfg1b = negotiate_join(addr, worker_id=1,
                                    connect_timeout=10.0)
        socks.append(s1b)
        assert (cfg1b["worker_id"], cfg1b["generation"]) == (1, 1)

        # generation fencing: even with NO live connection holding the
        # id (the lease record outlives the connection), a HELLO from
        # the superseded generation-0 peer is turned away
        s1b.close()
        deadline = time.monotonic() + 5.0
        while True:
            stale = SocketWorkerClient(addr, 1, generation=0,
                                       family="tcp")
            assert stale.closed.wait(5.0)
            reason = stale.reject_reason or ""
            stale.close()
            if "generation fence" in reason:
                break
            # the hub may not have reaped s1b's connection yet, in
            # which case the (also correct) duplicate rejection fires
            assert "live connection" in reason, reason
            assert time.monotonic() < deadline
            time.sleep(0.05)
    finally:
        for s in socks:
            s.close()
        hub.close()


# ------------------------------------------- elastic admission + auth

def test_elastic_admission_beyond_seed_fleet():
    """With ``max_workers`` above the seed, auto JOINs keep receiving
    fresh ids past ``num_workers`` — and every WELCOME names the
    *ceiling* as the shard space, so data sharding is identical for
    the host that joined first and the host admitted last."""
    hub = HostTransport(4, host="127.0.0.1", port=0, num_workers=2,
                        max_workers=4, welcome_config={})
    addr = tuple(hub.address)
    socks = []
    try:
        for expect in range(4):
            s, cfg = negotiate_join(addr)
            socks.append(s)
            assert (cfg["worker_id"], cfg["generation"]) == (expect, 0)
            # the shard space is the admission ceiling, not the seed
            assert cfg["num_workers"] == 4
        with pytest.raises(WireProtocolError, match="full"):
            negotiate_join(addr, connect_timeout=0.5)
    finally:
        for s in socks:
            s.close()
        hub.close()


def test_auto_join_blocked_by_grace_window_then_relessed():
    """An auto JOIN must not be handed a recently-departed worker id —
    its previous holder may be mid-reconnect — until the re-lease grace
    window expires; after expiry the id is re-leased with a bumped
    generation (fencing out the departed holder's stale frames)."""
    hub = HostTransport(4, host="127.0.0.1", port=0, num_workers=1,
                        welcome_config={}, lease_grace_s=0.5)
    addr = tuple(hub.address)
    try:
        s0, cfg0 = negotiate_join(addr)
        assert (cfg0["worker_id"], cfg0["generation"]) == (0, 0)
        s0.close()
        _poll(lambda: 0 in hub._departed, what="departure recorded")
        # inside the window: the only free id is grace-protected
        with pytest.raises(WireProtocolError, match="grace"):
            negotiate_join(addr, connect_timeout=0.3)
        # the BUSY rejection is retried past expiry: same id, new
        # generation — never a brand-new shard
        s1, cfg1 = negotiate_join(addr, connect_timeout=10.0)
        assert (cfg1["worker_id"], cfg1["generation"]) == (0, 1)
        s1.close()
    finally:
        hub.close()


def test_join_secret_challenge_and_rejections():
    """Authenticated JOIN, all four corners: a secretless joiner gets a
    readable client-side error, a wrong secret gets the leader's
    readable REJECT without ever taking a lease or a barrier seat, the
    right secret is admitted (generation 0 — the failures consumed
    nothing), and a direct HELLO cannot sidestep the challenge."""
    hub = HostTransport(4, host="127.0.0.1", port=0, num_workers=2,
                        welcome_config={"spec": {"arch": "mlp"}},
                        join_secret="open-sesame")
    addr = tuple(hub.address)
    try:
        with pytest.raises(WireProtocolError, match="authenticated"):
            negotiate_join(addr, connect_timeout=5.0)
        with pytest.raises(WireProtocolError,
                           match="authentication failed"):
            negotiate_join(addr, secret="wrong", connect_timeout=5.0)
        assert hub.live_workers() == set()      # never entered the barrier
        s, cfg = negotiate_join(addr, secret="open-sesame")
        try:
            # generation 0: the rejected attempts held no lease
            assert (cfg["worker_id"], cfg["generation"]) == (0, 0)
            assert cfg["spec"] == {"arch": "mlp"}
        finally:
            s.close()
        # a bare HELLO is not a way around the challenge
        stray = SocketWorkerClient(addr, 1, generation=0, family="tcp")
        assert stray.closed.wait(5.0)
        assert "authenticated JOIN" in (stray.reject_reason or "")
        stray.close()
        assert 1 not in hub.live_workers()
    finally:
        hub.close()


# ---------------------------------------------------------- end to end

def _host_spec(**kw):
    base = dict(arch="mlp", backend="cluster", mode="sync",
                schedule=None, cluster_workers=2, wall_budget_s=30.0,
                wall_sample_every_s=10.0, batch=16, smoke=True,
                max_gradients=12)
    base.update(kw)
    return ExperimentSpec(**base)


def _check_conservation(res):
    a = res.extra["accounting"]
    assert a["computed"] == (a["applied"] + a["dropped"] + a["buffered"]
                             + a["pending_round"] + a["in_flight"]), a
    assert res.num_gradients == a["applied"]
    return a


def test_two_host_groups_bitwise_identical_to_inproc():
    """The acceptance scenario: the same sync spec under a gradient
    budget, run once with in-process threads and once as a leader plus
    TWO separately-launched `repro join` process groups (each rebuilds
    the workload from spec JSON fetched over TCP).  Final parameters
    must be bitwise identical — the pinned ``<f4`` wire format, leased
    worker-id shards, and worker-id-ordered sync rounds leave no other
    outcome.

    Two read-only serve clients subscribe to the host run while it
    trains: they must receive pushes, never claim a barrier seat, and
    — the serving-plane acceptance bar — leave the training outcome
    bitwise untouched."""
    from repro.serve.client import ServeClient
    finals = {}
    trainer = ClusterTrainer()
    res = trainer.run(_host_spec(transport="inproc"))
    a = _check_conservation(res)
    assert a["applied"] == 12 and res.num_updates == 6
    finals["inproc"] = trainer.last_params

    spec = _host_spec(transport="host", listen="127.0.0.1:0")
    trainer2 = ClusterTrainer()
    runtime = trainer2.build_runtime(spec)
    assert runtime.listen_address[1] != 0       # resolved, advertisable
    procs = [spawn_join_process(runtime.listen_address, workers=1,
                                platform=CHILD_PLATFORM)
             for _ in range(2)]
    serve_clients = [ServeClient(runtime.listen_address)
                     for _ in range(2)]
    try:
        res_h = trainer2.finish(runtime, spec)
    finally:
        codes = []
        for p in procs:
            try:
                codes.append(p.wait(timeout=60))
            except Exception:
                p.kill()
                codes.append("killed")
        for c in serve_clients:
            c.close()
    assert codes == [0, 0], codes
    a = _check_conservation(res_h)
    assert a["applied"] == 12 and res_h.num_updates == 6
    finals["host"] = trainer2.last_params

    # the serving plane saw the run but never entered it
    serving = res_h.extra["serving"]
    assert serving["clients"] == 2, serving
    for c in serve_clients:
        seen = list(c.versions_seen)
        assert seen and seen == sorted(seen), seen

    # resolved address is exposed on the result
    assert res_h.extra["listen"].startswith("127.0.0.1:")
    listening = [e for e in res_h.extra["events"]
                 if e["event"] == "listening"]
    assert listening and listening[0]["expected_workers"] == 2

    for key in finals["inproc"]:
        assert np.array_equal(np.asarray(finals["inproc"][key]),
                              np.asarray(finals["host"][key])), key


def test_elastic_e2e_admit_kill_release_and_exact_ledger():
    """The elasticity acceptance scenario, end to end over TCP: a
    2-worker run admits a third joiner mid-run (the fleet grows beyond
    the seed), survives a SIGKILLed worker whose shard is then
    re-leased to a fresh process at a bumped generation, and still
    finishes with an exact conservation ledger."""
    spec = _host_spec(transport="host", listen="127.0.0.1:0",
                      mode="async", cluster_workers=2, max_workers=3,
                      max_gradients=None, wall_budget_s=120.0)
    trainer = ClusterTrainer()
    runtime = trainer.build_runtime(spec)
    addr = runtime.listen_address

    def _applied():
        server = getattr(runtime, "server", None)
        return server.applied if server is not None else 0

    box = {}
    th = threading.Thread(
        target=lambda: box.update(res=trainer.finish(runtime, spec)),
        daemon=True)
    j0 = spawn_join_process(addr, worker_id=0, platform=CHILD_PLATFORM)
    j1 = spawn_join_process(addr, worker_id=1, platform=CHILD_PLATFORM)
    th.start()
    j2 = j3 = None
    try:
        _poll(lambda: runtime.transport.live_workers() >= {0, 1},
              timeout_s=180.0, what="seed fleet assembled")
        _poll(lambda: _applied() > 0, timeout_s=60.0,
              what="seed fleet training")

        # online admission: a third host joins the live run
        j2 = spawn_join_process(addr, platform=CHILD_PLATFORM)
        _poll(lambda: 2 in runtime.transport.live_workers(),
              timeout_s=180.0, what="worker 2 admitted mid-run")
        # the hub admits the HELLO a beat before the runtime's
        # ready-callback grows the fleet — poll, don't assert
        _poll(lambda: runtime.fleet_size == 3, timeout_s=30.0,
              what="fleet grew to 3")
        mark = _applied()
        _poll(lambda: _applied() > mark, timeout_s=60.0,
              what="grown fleet training")

        # departure: SIGKILL a seed worker (no goodbye, no flush)...
        j1.kill()
        _poll(lambda: 1 not in runtime.transport.live_workers(),
              timeout_s=60.0, what="killed worker reaped")
        # ...and re-lease its shard to a fresh process (the explicit id
        # skips the grace window; the generation bump fences the ghost)
        j3 = spawn_join_process(addr, worker_id=1,
                                platform=CHILD_PLATFORM)
        _poll(lambda: 1 in runtime.transport.live_workers(),
              timeout_s=180.0, what="shard re-leased")
        mark = _applied()
        _poll(lambda: _applied() > mark, timeout_s=60.0,
              what="re-leased fleet training")
        runtime.server.done.set()           # end the run
        th.join(120.0)
        assert not th.is_alive(), "runtime never finished"
    finally:
        codes = {}
        for name, p in (("j0", j0), ("j2", j2), ("j3", j3)):
            if p is None:
                continue
            try:
                codes[name] = p.wait(timeout=60)
            except Exception:
                p.kill()
                codes[name] = "stranded"
        if j1.poll() is None:
            j1.kill()
        j1.wait(timeout=30)
    assert codes == {"j0": 0, "j2": 0, "j3": 0}, codes
    assert j1.returncode == -9              # SIGKILL, by design

    res = box["res"]
    a = _check_conservation(res)
    assert a["applied"] > 0
    # the per-worker ledger covers every member that ever existed —
    # including the one admitted beyond the seed fleet
    assert set(a["computed_per_worker"]) == {"0", "1", "2"}

    events = res.extra["events"]
    grow = [e for e in events if e["event"] == "fleet_grow"]
    assert grow and grow[0]["to_workers"] == 3, grow
    joins = [e for e in events if e["event"] == "member_join"]
    assert any(e["worker"] == 2 for e in joins), joins
    # the re-leased shard came back under a bumped generation
    assert any(e["worker"] == 1 and e["generation"] >= 1
               for e in joins), joins
    assert any(e["event"] == "member_gone" and e["worker"] == 1
               for e in events)


def test_kill_the_leader_joined_worker_exits_cleanly():
    """When the leader dies, a joined worker must see EOF and exit 0 —
    not hang in ``recv`` or strand in the send retry loop."""
    from repro.api.trainers import SIM_WORKLOADS
    from repro.core.slab import slab_codec

    spec = _host_spec(mode="async", cluster_workers=1,
                      max_gradients=None)
    hub = HostTransport(8, host="127.0.0.1", port=0, num_workers=1,
                        welcome_config={"spec": spec.to_dict()})
    proc = spawn_join_process(hub.address, workers=1,
                              platform=CHILD_PLATFORM)
    try:
        assert hub.wait_for_workers(1, timeout=180.0), \
            "joined worker never connected"
        # put the worker mid-training-loop: publish real params so it
        # is actively fetching, computing, and sending when the leader
        # vanishes
        _, init_params, _, _ = SIM_WORKLOADS[spec.arch](spec)
        slab = np.asarray(slab_codec(init_params).encode(init_params))
        hub.publish_params(ParamsMsg(0, slab))
        _poll(lambda: hub.pending_gradients() > 0
              or sum(hub.received_counts().values()) > 0,
              timeout_s=60.0, what="worker training")
        hub.close()                             # the leader dies
        assert proc.wait(timeout=30) == 0       # EOF -> clean exit
    finally:
        if proc.poll() is None:
            proc.kill()
        hub.close()
