"""End-to-end tests for the multi-process cluster transports
(``spec.transport = "socket" | "proc"``).

The expensive scenarios live here (each ``proc`` run spawns real
worker processes that import JAX and compile before connecting —
seconds per fleet), separate from the thread-mode cluster tests in
``tests/test_cluster.py``:

  * the acceptance scenario — a 2-process hybrid run completes with the
    conservation ledger holding exactly, survives one SIGKILL+respawn
    fault, and reports torn frames instead of corrupting accounting;
  * cross-process bitwise parity — the same sync spec under a gradient
    budget produces bit-identical final parameters on ``inproc`` and
    ``proc`` (slab frames round-trip f32 bitwise; per-worker data
    streams and worker-id-ordered rounds are deterministic);
  * the ``socket`` transport (threads over TCP slab frames) as a drop-in
    on the normal runtime, checkpoint restore propagation included.
"""
import numpy as np
import pytest

from repro.api import ExperimentSpec, FaultPlan, run
from repro.cluster.trainer import ClusterTrainer


def _spec(**kw):
    base = dict(arch="mlp", backend="cluster", mode="hybrid",
                schedule="step:40", cluster_workers=2, wall_budget_s=1.5,
                wall_sample_every_s=0.5, batch=16, smoke=True)
    base.update(kw)
    return ExperimentSpec(**base)


def _check_conservation(res):
    a = res.extra["accounting"]
    assert a["computed"] == (a["applied"] + a["dropped"] + a["buffered"]
                             + a["pending_round"] + a["in_flight"]), a
    assert res.num_gradients == a["applied"]
    assert a["computed"] == sum(a["computed_per_worker"].values())
    return a


# ---------------------------------------------------------------- spec

def test_spec_transport_field_round_trip():
    spec = _spec(transport="proc")
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    with pytest.raises(ValueError, match="transport"):
        _spec(transport="carrier-pigeon")


def test_proc_runtime_requires_spec_dict():
    """ClusterRuntime can't spawn worker processes without the spec the
    children rebuild the workload from — fail at construction, not as a
    hung fleet."""
    from repro.cluster.runtime import ClusterRuntime
    with pytest.raises(ValueError, match="spec_dict"):
        ClusterRuntime(lambda p, x, y: 0.0, None, (None,) * 4,
                       mode="async", transport_kind="proc")


# ------------------------------------------------- socket (threads/TCP)

def test_socket_transport_run_completes_with_exact_ledger():
    res = run(_spec(transport="socket"))
    assert res.backend == "cluster" and res.grid_unit == "wall_s"
    a = _check_conservation(res)
    assert a["applied"] > 0 and res.num_updates > 0


def test_socket_transport_sync_restore_resyncs(tmp_path):
    """A mid-run checkpoint restore rolls the version backwards *over
    the socket broadcast*; sync workers must resync and accounting must
    stay exact — the cross-address-space version of the in-proc restore
    test."""
    spec = _spec(mode="sync", schedule=None, transport="socket",
                 wall_budget_s=2.0,
                 faults=FaultPlan(checkpoint_every_s=0.4,
                                  restore_at_s=1.0))
    res = ClusterTrainer(ckpt_dir=str(tmp_path)).run(spec)
    a = _check_conservation(res)
    kinds = [e["event"] for e in res.extra["events"]]
    assert "restore" in kinds and "checkpoint" in kinds
    assert a["applied"] > 0


# ------------------------------------------------------ proc (processes)

def test_proc_acceptance_kill_respawn_exact_ledger():
    """The acceptance scenario: a 2-process hybrid run completes, one
    worker is SIGKILLed mid-run and respawned (fresh process, fresh JAX
    runtime, fresh stream generation), and the conservation ledger
    holds to the gradient — a frame torn by the SIGKILL is discarded
    and reported, never miscounted."""
    res = run(_spec(transport="proc", wall_budget_s=10.0,
                    wall_sample_every_s=2.0,
                    faults=FaultPlan(kill=((1, 1.0),),
                                     respawn_after_s=0.5)))
    a = _check_conservation(res)
    assert res.num_gradients == a["applied"] > 0
    kinds = [e["event"] for e in res.extra["events"]]
    assert kinds.count("kill") == 1 and kinds.count("respawn") == 1
    # SIGKILL was physical (the event records it) and both generations
    # of worker 1 talked to the server
    kill_ev = next(e for e in res.extra["events"] if e["event"] == "kill")
    assert kill_ev["sigkill"] is True
    assert a["computed_per_worker"]["1"] > 0
    assert a["torn_frames"] >= 0          # present, and never negative


def test_proc_sync_kill_respawn_barrier_keeps_moving():
    """Sync + proc + SIGKILL/respawn: the barrier must keep completing
    rounds with the survivors while the respawned child is still
    importing JAX — membership is driven by the connection (register
    on HELLO, deregister on connection death), not by the spawn, so a
    worker that cannot yet contribute never blocks a round."""
    res = run(_spec(mode="sync", schedule=None, transport="proc",
                    wall_budget_s=8.0, wall_sample_every_s=2.0,
                    faults=FaultPlan(kill=((1, 1.0),),
                                     respawn_after_s=0.5)))
    a = _check_conservation(res)
    kinds = [e["event"] for e in res.extra["events"]]
    assert kinds.count("kill") == 1 and kinds.count("respawn") == 1
    assert a["applied"] > 0 and res.num_updates > 0


def test_proc_sync_mid_run_restore_resyncs_across_processes(tmp_path):
    """Checkpoints round-trip across the process boundary: the server
    (parent) snapshots and restores mid-run; the rolled-back version +
    bumped restore epoch cross the socket to the worker processes,
    which resync to the restored round instead of stalling the barrier;
    accounting stays exact."""
    spec = _spec(mode="sync", schedule=None, transport="proc",
                 wall_budget_s=5.0, wall_sample_every_s=1.0,
                 faults=FaultPlan(checkpoint_every_s=0.5,
                                  restore_at_s=1.5))
    res = ClusterTrainer(ckpt_dir=str(tmp_path)).run(spec)
    a = _check_conservation(res)
    events = res.extra["events"]
    kinds = [e["event"] for e in events]
    assert "checkpoint" in kinds and "restore" in kinds
    restore_t = next(e["t"] for e in events if e["event"] == "restore")
    assert restore_t < res.extra["serve_wall_s"]
    assert a["applied"] > 0 and res.num_updates > 0


def test_proc_bitwise_parity_with_inproc():
    """Same sync spec + gradient budget, run once with worker threads
    and once with worker processes: final parameters must be bitwise
    identical.  This is the guarantee that moving workers out of the
    address space changed the physics (GIL, staleness, death) and
    nothing else — slab frames carry f32 bitwise, rounds aggregate in
    worker-id order, shards are deterministic."""
    base = dict(mode="sync", schedule=None, wall_budget_s=30.0,
                wall_sample_every_s=10.0, max_gradients=12)
    finals = {}
    for transport in ("inproc", "proc"):
        trainer = ClusterTrainer()
        res = trainer.run(_spec(transport=transport, **base))
        a = _check_conservation(res)
        assert a["applied"] == 12 and res.num_updates == 6
        finals[transport] = trainer.last_params
    for key in finals["inproc"]:
        assert np.array_equal(np.asarray(finals["inproc"][key]),
                              np.asarray(finals["proc"][key])), key


def test_proc_adamw_sigkill_restore_exact_ledger_no_nan_moments(tmp_path):
    """The slab-resident optimizer under the full fault gauntlet: an
    adamw run over real worker processes takes a SIGKILL+respawn,
    checkpoints on a cadence (moment slabs + update count riding the
    npz), and restores mid-run.  The conservation ledger must hold to
    the gradient, the moments must come out finite (a restore that
    resurrected stale or torn moment state would NaN within a few
    flushes), and the optimizer's update count must both persist in the
    checkpoint and keep advancing after the restore."""
    from repro.checkpoint import latest_step, load_opt_state

    spec = _spec(transport="proc", optimizer="adamw", beta1=0.9,
                 beta2=0.95, weight_decay=0.01,
                 wall_budget_s=8.0, wall_sample_every_s=2.0,
                 faults=FaultPlan(kill=((1, 1.0),), respawn_after_s=0.5,
                                  checkpoint_every_s=0.5,
                                  restore_at_s=2.0))
    trainer = ClusterTrainer(ckpt_dir=str(tmp_path))
    runtime = trainer.build_runtime(spec)
    res = trainer.finish(runtime, spec)
    a = _check_conservation(res)
    kinds = [e["event"] for e in res.extra["events"]]
    assert "checkpoint" in kinds and "restore" in kinds
    assert kinds.count("kill") == 1 and kinds.count("respawn") == 1
    assert a["applied"] > 0 and res.num_updates > 0
    # the live server's moments after the whole gauntlet: finite, f32,
    # and the count matches the updates actually applied since restore
    st = runtime.server.snapshot_opt_state()
    assert st is not None
    for name in ("mu", "nu"):
        assert st[name].dtype == np.float32
        assert np.isfinite(st[name]).all(), name
    assert st["count"] > 0
    # the on-disk checkpoints carry the optimizer state too
    step = latest_step(str(tmp_path))
    assert step is not None
    on_disk = load_opt_state(str(tmp_path / f"step_{step}"))
    assert on_disk is not None and on_disk["count"] > 0
    assert np.isfinite(on_disk["mu"]).all()
    assert np.isfinite(on_disk["nu"]).all()
    # the telemetry seam: one optimizer step per fused flush, exactly
    tel = res.extra["telemetry"]
    assert tel["counters"]["optimizer_steps"] == a["updates"]
    assert tel["histograms"]["opt_update_s"]["count"] == a["updates"]
