"""Property tests for the SPMD hybrid phase machinery (pure host logic)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.schedule import step_schedule, linear_schedule
from repro.core.spmd_hybrid import (HybridPhase, build_phases,
                                    min_group_size)


@settings(max_examples=30, deadline=None)
@given(step=st.integers(1, 500), horizon=st.integers(1, 3000),
       axis=st.sampled_from([2, 4, 8, 16, 32]))
def test_build_phases_invariants(step, horizon, axis):
    sched = step_schedule(axis, step)
    phases = build_phases(sched, horizon, axis)
    assert phases[0].t_start == 0
    sizes = [p.group_size for p in phases]
    starts = [p.t_start for p in phases]
    assert sizes == sorted(sizes)                 # monotone anneal
    assert starts == sorted(starts)
    for p in phases:
        assert axis % p.group_size == 0           # g divides the axis
        assert p.num_replicas * p.group_size == axis
        assert 1 <= p.group_size <= axis


@settings(max_examples=20, deadline=None)
@given(axis=st.sampled_from([4, 8, 16]), horizon=st.integers(10, 500))
def test_build_phases_reaches_sync(axis, horizon):
    """A linear schedule over its own horizon must end fully synchronous."""
    sched = linear_schedule(axis, horizon)
    phases = build_phases(sched, horizon + 1, axis)
    assert phases[-1].group_size == axis
    assert phases[-1].num_replicas == 1


@settings(max_examples=20, deadline=None)
@given(g_min=st.sampled_from([1, 2, 4, 8]))
def test_build_phases_respects_g_min(g_min):
    sched = step_schedule(16, 10)
    phases = build_phases(sched, 200, 16, g_min=g_min)
    assert all(p.group_size >= g_min for p in phases)


def test_min_group_size_law():
    """Replica memory law: per-chip state = (params+opt)/(g·model)."""
    gib = 2 ** 30
    # 100B params bf16 + fp32 mu/nu = 10 bytes/param = 1.0 TB state
    param_b = 100e9 * 2
    opt_b = 100e9 * 8
    g = min_group_size(int(param_b), int(opt_b), model_axis=16,
                       hbm_per_chip=16 * gib, act_budget_frac=0.5)
    # needs 1e12/(g·16) <= 8 GiB -> g >= 7.3 -> 8
    assert g == 8
    # a 350M model fits at g=1
    g_small = min_group_size(int(0.35e9 * 2), int(0.35e9 * 8),
                             model_axis=16, hbm_per_chip=16 * gib)
    assert g_small == 1


def test_reshard_replicas_merge_down_averages():
    import jax
    import jax.numpy as jnp
    from repro.core.spmd_hybrid import reshard_replicas
    p = {"w": jnp.arange(8.0).reshape(4, 2)}     # 4 replicas of shape (2,)
    out = reshard_replicas(p, 2)
    np.testing.assert_allclose(
        np.asarray(out["w"]),
        np.asarray([[1.0, 2.0], [5.0, 6.0]]))    # mean of consecutive pairs
