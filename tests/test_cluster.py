"""Tests for the wall-clock cluster backend (``repro.cluster``):
transport semantics, FaultPlan validation + spec round-trip, all three
policies on the runtime, fault injection (stragglers, kill/respawn),
exact gradient accounting (conservation + determinism guards), and the
CLI surface.

Budgets are deliberately small (a second or two per run): the point is
exercising real concurrency and exact accounting, not convergence.
"""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.api import ExperimentSpec, FaultPlan, RunResult, run
from repro.cluster.faults import parse_fault_pairs
from repro.cluster.trainer import ClusterTrainer
from repro.cluster.transport import GradientMsg, InProcTransport, ParamsMsg

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cluster_spec(**kw):
    base = dict(arch="mlp", backend="cluster", mode="hybrid",
                schedule="step:40", cluster_workers=3, wall_budget_s=1.2,
                wall_sample_every_s=0.4, batch=16, smoke=True)
    base.update(kw)
    return ExperimentSpec(**base)


def _check_conservation(res):
    """The conservation ledger holds EXACTLY — computed == applied +
    dropped + buffered + pending_round + in_flight, to the gradient —
    and num_gradients is the server's applied counter.  The runtime
    guarantees exactness by snapshotting only after the transport has
    quiesced (no approximate mid-run qsize() feeds the ledger)."""
    a = res.extra["accounting"]
    assert a["computed"] == (a["applied"] + a["dropped"] + a["buffered"]
                             + a["pending_round"] + a["in_flight"]), a
    assert res.num_gradients == a["applied"]
    assert a["computed"] == sum(a["computed_per_worker"].values())
    return a


# ------------------------------------------------------------ FaultPlan

def test_fault_plan_validation():
    plan = FaultPlan(stragglers=((0, 0.1),), kill=((1, 2.0),),
                     respawn_after_s=0.5)
    assert plan.straggle_s(0) == 0.1 and plan.straggle_s(2) == 0.0
    assert plan.kill_events() == [(2.0, 1)]
    assert not plan.empty and FaultPlan().empty
    # JSON gives lists of lists; construction coerces back to tuples
    assert FaultPlan(stragglers=[[0, 0.1]]) == FaultPlan(
        stragglers=((0, 0.1),))
    with pytest.raises(ValueError, match="stragglers"):
        FaultPlan(stragglers=((-1, 0.1),))
    with pytest.raises(ValueError, match="respawn_after_s"):
        FaultPlan(respawn_after_s=-1.0)


def test_parse_fault_pairs():
    assert parse_fault_pairs("0:0.2, 3:0.5") == ((0, 0.2), (3, 0.5))
    with pytest.raises(ValueError, match="WORKER:SECONDS"):
        parse_fault_pairs("3")
    with pytest.raises(ValueError):
        parse_fault_pairs("a:b")


def test_cluster_spec_json_round_trip():
    spec = _cluster_spec(
        max_gradients=100,
        faults=FaultPlan(stragglers=((0, 0.05),), kill=((1, 0.5),),
                         respawn_after_s=0.25, checkpoint_every_s=0.5))
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    assert isinstance(back.faults, FaultPlan)
    assert back.faults.kill == ((1, 0.5),)
    with pytest.raises(ValueError, match="cluster_workers"):
        _cluster_spec(cluster_workers=0)
    with pytest.raises(ValueError, match="max_gradients"):
        _cluster_spec(max_gradients=-1)


# ------------------------------------------------------------ transport

def test_inproc_transport_semantics():
    t = InProcTransport(grad_capacity=2)
    assert t.fetch_params(timeout=0) is None          # nothing published
    t.publish_params(ParamsMsg(3, {"w": 1}))
    assert t.fetch_params(min_version=2, timeout=0).version == 3
    assert t.fetch_params(min_version=4, timeout=0.01) is None  # barrier
    assert t.send_gradient(GradientMsg(0, "g0", 3, 1))
    assert t.send_gradient(GradientMsg(1, "g1", 3, 1))
    assert not t.send_gradient(GradientMsg(2, "g2", 3, 1),
                               timeout=0.01)          # backpressure
    assert t.pending_gradients() == 2
    assert t.recv_gradient(timeout=0).worker_id == 0  # FIFO
    assert t.recv_gradient(timeout=0).worker_id == 1
    assert t.recv_gradient(timeout=0) is None


def test_inproc_timeout_none_blocks_both_sides():
    """The timeout contract is uniform: ``None`` means block on BOTH
    sides (recv_gradient(None) used to mean get_nowait — the opposite
    of the send side), ``<= 0`` polls."""
    t = InProcTransport(grad_capacity=1)
    # send side: None blocks until the queue has room
    assert t.send_gradient(GradientMsg(0, "g0", 0, 1))
    done = []
    th = threading.Thread(
        target=lambda: done.append(
            t.send_gradient(GradientMsg(0, "g1", 0, 2))),  # timeout=None
        daemon=True)
    th.start()
    th.join(0.2)
    assert th.is_alive(), "send_gradient(timeout=None) must block"
    assert t.recv_gradient(timeout=0).seq == 1     # make room
    th.join(2.0)
    assert not th.is_alive() and done == [True]
    # recv side: None blocks until a gradient arrives
    out = []
    th = threading.Thread(target=lambda: out.append(t.recv_gradient()),
                          daemon=True)
    th.start()
    th.join(0.2)
    assert not th.is_alive() and out[0].seq == 2   # g1 was waiting
    th = threading.Thread(target=lambda: out.append(t.recv_gradient()),
                          daemon=True)
    th.start()
    th.join(0.2)
    assert th.is_alive(), "recv_gradient(timeout=None) must block"
    t.send_gradient(GradientMsg(0, "g2", 0, 3))
    th.join(2.0)
    assert not th.is_alive() and out[1].seq == 3
    # <= 0 always polls
    assert t.recv_gradient(timeout=0) is None
    assert t.recv_gradient(timeout=-1) is None


def test_server_death_never_strands_workers(monkeypatch):
    """Regression (worker hang on server death): if the server dies
    mid-run, the runtime must still propagate shutdown to every worker
    stop event — a worker blocked in the bounded-send retry loop would
    otherwise spin forever."""
    from repro.cluster.server import ParameterServer

    def boom(self, msg):
        raise RuntimeError("server died mid-ingest")

    monkeypatch.setattr(ParameterServer, "ingest", boom)
    with pytest.raises(RuntimeError, match="server died mid-ingest"):
        run(_cluster_spec(wall_budget_s=5.0))
    deadline = time.time() + 5.0
    while time.time() < deadline:
        alive = [t.name for t in threading.enumerate()
                 if t.name.startswith("worker-") and t.is_alive()]
        if not alive:
            break
        time.sleep(0.05)
    assert not alive, f"workers outlived the dead server: {alive}"


# ------------------------------------------------- the three policies

@pytest.mark.parametrize("mode,schedule", [
    ("async", None), ("sync", None), ("hybrid", "step:40"),
])
def test_cluster_policies_produce_wall_clock_runresult(mode, schedule):
    res = run(_cluster_spec(mode=mode, schedule=schedule))
    assert res.backend == "cluster" and res.grid_unit == "wall_s"
    assert set(res.metrics) == {"train_loss", "test_loss", "test_acc"}
    assert len(res.grid) >= 2            # wall-clock metric grid
    assert res.grid == tuple(sorted(res.grid))
    for series in res.metrics.values():
        assert len(series) == len(res.grid)
    assert res.num_updates > 0 and res.num_gradients > 0
    avg = res.averaged()
    assert set(avg) == set(res.metrics)
    assert all(np.isfinite(v) for v in avg.values())
    assert res.schedule == (schedule if mode == "hybrid" else None)
    _check_conservation(res)
    # a cluster RunResult round-trips like any other
    assert RunResult.from_json(res.to_json()) == res


def test_cluster_hybrid_more_grads_than_updates():
    """Once K(t) > 1 the hybrid folds several gradients per update."""
    res = run(_cluster_spec(schedule="step:10"))
    assert res.num_gradients > res.num_updates > 0
    _check_conservation(res)


def test_unknown_cluster_workload():
    with pytest.raises(ValueError, match="unknown cluster workload"):
        ClusterTrainer().run(_cluster_spec(arch="resnet"))


# ------------------------------------------------------ fault injection

def test_cluster_straggler_slows_one_worker():
    res = run(_cluster_spec(
        mode="async", schedule=None,
        faults=FaultPlan(stragglers=((0, 0.2),))))
    a = _check_conservation(res)
    per = a["computed_per_worker"]
    straggler, healthy = per["0"], max(per["1"], per["2"])
    assert straggler < healthy / 3, per


def test_cluster_hybrid_kill_and_respawn_completes():
    """The acceptance scenario: a hybrid run whose FaultPlan kills and
    respawns a worker completes, and the reported num_gradients exactly
    matches the server's applied-gradient counter."""
    res = run(_cluster_spec(
        wall_budget_s=2.2,
        faults=FaultPlan(kill=((1, 0.7),), respawn_after_s=0.3)))
    a = _check_conservation(res)
    assert res.num_gradients == a["applied"] > 0
    kinds = [e["event"] for e in res.extra["events"]]
    assert kinds.count("kill") == 1 and kinds.count("respawn") == 1
    # the respawned generation contributed again after the kill
    assert a["computed_per_worker"]["1"] > 0


def test_cluster_sync_mid_run_restore_keeps_accounting(tmp_path):
    """A mid-run restore rolls the server's version *backwards*; sync
    workers must resync to the restored round (not stall on the old
    one), and every gradient — including round entries discarded by the
    restore and duplicate re-contributions — stays accounted."""
    spec = _cluster_spec(
        mode="sync", schedule=None, wall_budget_s=2.0,
        faults=FaultPlan(checkpoint_every_s=0.4, restore_at_s=1.0))
    res = ClusterTrainer(ckpt_dir=str(tmp_path)).run(spec)
    a = _check_conservation(res)
    kinds = [e["event"] for e in res.extra["events"]]
    assert "restore" in kinds and "checkpoint" in kinds
    restore_t = next(e["t"] for e in res.extra["events"]
                     if e["event"] == "restore")
    assert restore_t < res.wall_s       # training continued after it
    assert a["applied"] > 0


def test_cluster_fault_worker_ids_validated():
    """A plan naming workers outside the fleet is a configuration
    error, not a phantom worker that breaks the sync barrier."""
    with pytest.raises(ValueError, match="worker ids"):
        run(_cluster_spec(faults=FaultPlan(kill=((7, 0.5),))))
    with pytest.raises(ValueError, match="worker ids"):
        run(_cluster_spec(faults=FaultPlan(stragglers=((3, 0.1),))))


def test_cluster_overlapping_kills_fire_on_time():
    """A pending respawn must not postpone later kill events: kills and
    respawns interleave on one wall-clock timeline."""
    res = run(_cluster_spec(
        wall_budget_s=2.0,
        faults=FaultPlan(kill=((0, 0.4), (1, 0.6)),
                         respawn_after_s=0.5)))
    _check_conservation(res)
    events = [(e["event"], e.get("worker")) for e in res.extra["events"]]
    assert events == [("kill", 0), ("kill", 1),
                      ("respawn", 0), ("respawn", 1)], events


def test_cluster_checkpoint_plan_requires_ckpt_dir():
    """The runtime refuses a checkpointing plan without a directory (a
    silent no-op would lose the checkpoints the plan promised); the
    trainer layer instead provisions a temp directory, so a
    checkpointing spec stays runnable from its JSON alone."""
    from repro.cluster.runtime import ClusterRuntime
    with pytest.raises(ValueError, match="ckpt_dir"):
        ClusterRuntime(lambda p, x, y: 0.0, None, (None,) * 4,
                       mode="async",
                       faults=FaultPlan(checkpoint_every_s=0.5))
    res = run(_cluster_spec(faults=FaultPlan(checkpoint_every_s=0.4)))
    kinds = [e["event"] for e in res.extra["events"]]
    assert "ckpt_dir_provisioned" in kinds and "checkpoint" in kinds
    _check_conservation(res)


def test_cluster_sync_survives_worker_kill_without_respawn():
    """Killing a worker mid-run must not deadlock the sync barrier: the
    dead worker is deregistered and rounds continue with the rest."""
    res = run(_cluster_spec(
        mode="sync", schedule=None, wall_budget_s=1.6,
        faults=FaultPlan(kill=((2, 0.4),))))
    _check_conservation(res)
    events = res.extra["events"]
    assert [e["event"] for e in events] == ["kill"]
    assert res.num_updates > 0


# ------------------------------------------------- determinism guards

def test_cluster_async_accounting_deterministic():
    """Two async runs with the same seed reach identical gradient-count
    accounting under a gradient budget, even though apply order (and
    per-worker interleaving) differs between runs."""
    spec = _cluster_spec(mode="async", schedule=None, max_gradients=40,
                         wall_budget_s=30.0)
    first, second = run(spec), run(spec)
    for res in (first, second):
        a = _check_conservation(res)
        assert res.num_gradients == 40 == a["applied"]
    assert first.num_gradients == second.num_gradients
    assert first.num_updates == second.num_updates


def test_cluster_sync_bitwise_reproducible():
    """The sync policy is bitwise reproducible: per-worker batch streams
    are deterministic, rounds aggregate in worker-id order, and the
    gradient budget pins the round count."""
    spec = _cluster_spec(mode="sync", schedule=None, max_gradients=30,
                         wall_budget_s=30.0)
    finals = []
    for _ in range(2):
        trainer = ClusterTrainer()
        res = trainer.run(spec)
        assert res.num_updates == 10      # 10 rounds of 3 workers
        finals.append(trainer.last_params)
    for key in finals[0]:
        assert np.array_equal(np.asarray(finals[0][key]),
                              np.asarray(finals[1][key])), key


# ----------------------------------------------------------------- CLI

def test_cli_cluster_run_with_faults(tmp_path):
    out = str(tmp_path / "res.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    p = subprocess.run(
        [sys.executable, "-m", "repro", "run", "--backend", "cluster",
         "--arch", "mlp", "--cluster-workers", "3", "--wall-budget", "1.5",
         "--wall-sample-every", "0.5", "--mode", "hybrid",
         "--schedule", "step:40", "--straggler", "0:0.1", "--quiet",
         "--out", out],
        capture_output=True, text=True, timeout=420, env=env, cwd=REPO)
    assert p.returncode == 0, p.stderr[-2000:]
    res = RunResult.from_json(open(out).read())
    assert res.backend == "cluster" and res.grid_unit == "wall_s"
    assert res.spec["faults"]["stragglers"] == [[0, 0.1]]
    _check_conservation(res)
    summary = json.loads(p.stdout)
    assert summary["num_gradients"] == res.num_gradients


def test_cli_bench_resolves_from_any_cwd(tmp_path):
    """`python -m repro bench` no longer requires the repo root CWD."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    p = subprocess.run(
        [sys.executable, "-m", "repro", "bench", "--help"],
        capture_output=True, text=True, timeout=120, env=env,
        cwd=str(tmp_path))
    assert p.returncode == 0, p.stderr[-2000:]
    assert "usage" in p.stdout.lower()
