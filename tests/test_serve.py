"""Serving-plane conformance tests (``repro.serve`` + SERVE/PING wire).

Four layers:

  * **admission** — SERVE peers are admitted read-only by the host hub
    only: a plain hub turns them away with a readable reason,
    version-mismatched serve clients are rejected and counted, and a
    serve client that tries to send a GRAD frame is rejected before it
    can touch the ledger.  Serve connections never appear in
    ``live_workers`` or the fleet barrier;
  * **publication** — params pushes are version-monotonic per client
    (no restores in play), ``serve_every`` down-samples the stream, and
    a stalled serve client (connected, never reading) cannot block
    ``publish_params`` or a worker's delivery — the slow reader costs
    exactly one wedged per-connection writer;
  * **liveness** — the leader PINGs on ``heartbeat_s``; workers and
    serve clients detect a *hung* (not just dead) leader via the
    no-frames watchdog and report a readable ``stall_reason``, while a
    healthy heartbeat keeps an otherwise-idle client alive.  A dead
    leader (closed hub) strands nobody;
  * **end to end** — a training leader serves two separately-launched
    ``python -m repro infer`` processes while joined workers train.
"""
import json
import socket
import struct
import threading
import time

import numpy as np
import pytest

import jax

from repro.api import ExperimentSpec
from repro.cluster import mptransport as mpt
from repro.cluster.hostlink import (HostTransport, negotiate_serve,
                                    spawn_join_process)
from repro.cluster.mptransport import (SocketTransport,
                                       SocketWorkerClient,
                                       WireProtocolError)
from repro.cluster.trainer import ClusterTrainer
from repro.cluster.transport import GradientMsg, ParamsMsg
from repro.serve.client import ServeClient, spawn_infer_process

CHILD_PLATFORM = None if jax.default_backend() == "cpu" else "cpu"


def _poll(predicate, timeout_s: float = 5.0, what: str = "condition"):
    deadline = time.monotonic() + timeout_s
    while not predicate():
        assert time.monotonic() < deadline, f"timed out waiting: {what}"
        time.sleep(0.02)


def _host_hub(**kw):
    kw.setdefault("num_workers", 1)
    kw.setdefault("welcome_config", {"spec": {"arch": "mlp"}})
    return HostTransport(8, host="127.0.0.1", port=0, **kw)


# ------------------------------------------------------------- admission


def test_serve_rejected_on_non_host_hub():
    hub = SocketTransport(family="tcp")
    try:
        with pytest.raises(WireProtocolError,
                           match="not a host transport"):
            negotiate_serve(hub.address, connect_timeout=5.0)
        _poll(lambda: hub.rejected_peers == 1, what="rejected count")
        assert hub.live_workers() == set()
    finally:
        hub.close()


def test_version_mismatched_serve_peer_rejected():
    hub = _host_hub()
    try:
        s = socket.create_connection(tuple(hub.address), timeout=5.0)
        bad = (mpt._HDR.pack(mpt._F_SERVE, mpt._CTRL.size)
               + mpt._CTRL.pack(mpt._MAGIC, 99))
        s.sendall(bad)
        # the hub answers with a readable REJECT frame, then closes
        hdr = s.recv(mpt._HDR.size, socket.MSG_WAITALL)
        ftype, n = mpt._HDR.unpack(hdr)
        assert ftype == mpt._F_REJECT
        payload = s.recv(n, socket.MSG_WAITALL)
        reason = payload[mpt._CTRL.size:].decode("utf-8")
        assert "version mismatch" in reason
        _poll(lambda: hub.rejected_peers == 1, what="rejected count")
        assert hub.serve_stats()["clients"] == 0
        s.close()
    finally:
        hub.close()


def test_serve_client_never_enters_membership():
    hub = _host_hub()
    try:
        client = ServeClient(hub.address)
        assert client.welcome["role"] == "serve"
        assert client.welcome["spec"] == {"arch": "mlp"}
        # not a worker anywhere: no barrier seat, no ledger row
        assert hub.live_workers() == set()
        assert hub.connected_workers() == {}
        assert not hub.wait_for_workers(1, timeout=0.3)
        assert hub.received_counts() == {}
        assert hub.serve_stats()["clients"] == 1
        client.close()
    finally:
        hub.close()


def test_serve_client_sending_grad_is_rejected():
    hub = _host_hub()
    try:
        client = ServeClient(hub.address)
        grad = GradientMsg(0, np.zeros(4, np.float32), 0, 0)
        client.sock.sendall(mpt._grad_frame(grad))
        _poll(lambda: hub.rejected_peers == 1, what="rejected count")
        assert client.closed.wait(5.0)
        assert "read-only" in (client.reject_reason or "")
        # nothing reached the gradient queue or the ledger
        assert hub.recv_gradient(timeout=0) is None
        assert hub.received_counts() == {}
        client.close()
    finally:
        hub.close()


# ----------------------------------------------------------- publication


def test_params_pushes_version_monotonic_per_client():
    hub = _host_hub()
    try:
        client = ServeClient(hub.address)
        for v in range(6):
            hub.publish_params(ParamsMsg(v, np.full(
                16, float(v), np.float32)))
            time.sleep(0.03)
        msg = client.wait_params(min_version=5, timeout=5.0)
        assert msg is not None and msg.version == 5
        assert msg.params[0] == 5.0
        seen = list(client.versions_seen)
        assert seen == sorted(seen), seen       # monotonic, no re-push
        assert len(seen) == len(set(seen)), seen
        stats = hub.serve_stats()["per_client"][0]
        assert stats["last_version"] == 5
        assert stats["pushes"] == len(seen)
        client.close()
    finally:
        hub.close()


def test_serve_every_downsamples_the_push_stream():
    hub = _host_hub(serve_every=3)
    try:
        client = ServeClient(hub.address)
        assert client.welcome["serve_every"] == 3
        for v in range(8):
            hub.publish_params(ParamsMsg(v, np.full(
                8, float(v), np.float32)))
            time.sleep(0.05)
        msg = client.wait_params(min_version=6, timeout=5.0)
        assert msg is not None and msg.version == 6
        assert all(v % 3 == 0 for v in client.versions_seen), \
            client.versions_seen
        stats = hub.serve_stats()["per_client"][0]
        assert stats["skipped_pushes"] >= 1
        client.close()
    finally:
        hub.close()


def test_stalled_serve_client_never_blocks_publish_or_workers():
    """A serve client that connects and then never reads again: the
    coalescing writer wedges against its full socket buffer, but
    ``publish_params`` stays O(1) and a real worker keeps receiving
    fresh versions."""
    hub = _host_hub()
    try:
        s = socket.create_connection(tuple(hub.address), timeout=5.0)
        s.sendall(mpt._serve_frame())
        hdr = s.recv(mpt._HDR.size, socket.MSG_WAITALL)
        _, n = mpt._HDR.unpack(hdr)
        s.recv(n, socket.MSG_WAITALL)           # WELCOME — last read ever
        _poll(lambda: hub.serve_stats()["clients"] == 1,
              what="serve admission")

        worker = hub.connect(0)
        _poll(lambda: hub.live_workers() == {0}, what="worker hello")

        slab = np.arange(256 * 1024, dtype=np.float32)   # 1 MiB frames
        t0 = time.monotonic()
        for v in range(30):
            hub.publish_params(ParamsMsg(v, slab + v))
        publish_s = time.monotonic() - t0
        assert publish_s < 2.0, f"publish_params stalled: {publish_s:.2f}s"

        msg = worker.fetch_params(min_version=29, timeout=10.0)
        assert msg is not None and msg.version == 29
        assert msg.params[1] == 30.0
        worker.close()
        s.close()
    finally:
        hub.close()                             # must not hang either


# -------------------------------------------------------------- liveness


def test_worker_watchdog_detects_hung_leader():
    """A leader that accepts and then goes silent (process alive, event
    loop wedged — no EOF to detect): the worker's no-frames watchdog
    must close the connection with a readable reason."""
    ls = socket.socket()
    ls.bind(("127.0.0.1", 0))
    ls.listen(1)
    held = []
    threading.Thread(target=lambda: held.append(ls.accept()),
                     daemon=True).start()
    client = SocketWorkerClient(ls.getsockname(), 0, family="tcp",
                                heartbeat_timeout_s=1.0)
    try:
        assert client.closed.wait(6.0), "watchdog never fired"
        assert client.stall_reason is not None
        assert "hung" in client.stall_reason
    finally:
        client.close()
        ls.close()


def test_serve_watchdog_detects_hung_leader():
    hub = _host_hub(heartbeat_s=0.0)            # silent leader
    try:
        client = ServeClient(hub.address, heartbeat_timeout_s=1.0)
        assert client.closed.wait(6.0), "watchdog never fired"
        assert client.stall_reason is not None
        assert "hung" in client.stall_reason
    finally:
        hub.close()


def test_heartbeat_keeps_idle_client_alive():
    """Same watchdog, but a healthy leader PINGing on a short cadence:
    no params ever published, yet the client must stay connected —
    PINGs are proof of life."""
    hub = _host_hub(heartbeat_s=0.2)
    try:
        client = ServeClient(hub.address, heartbeat_timeout_s=1.0)
        assert not client.closed.wait(2.5), \
            f"client died despite heartbeats: {client.stall_reason}"
        assert client.stall_reason is None
        client.close()
    finally:
        hub.close()


def test_serve_handshake_skips_ping_frames():
    """A PING racing the SERVE handshake (short cadence leaders) must
    be skipped by the negotiator, not misparsed as the WELCOME."""
    ls = socket.socket()
    ls.bind(("127.0.0.1", 0))
    ls.listen(1)

    def leader():
        conn, _ = ls.accept()
        conn.recv(mpt._HDR.size + mpt._CTRL.size, socket.MSG_WAITALL)
        conn.sendall(mpt._ping_frame()
                     + mpt._welcome_frame({"serve_id": 7, "spec": None,
                                           "heartbeat_s": 0.0}))
        time.sleep(1.0)
        conn.close()

    t = threading.Thread(target=leader, daemon=True)
    t.start()
    sock, cfg = negotiate_serve(ls.getsockname(), connect_timeout=5.0)
    assert cfg["serve_id"] == 7
    sock.close()
    ls.close()


def test_dead_leader_strands_no_serve_client():
    hub = _host_hub()
    client = ServeClient(hub.address)
    assert hub.serve_stats()["clients"] == 1
    hub.close()
    assert client.closed.wait(5.0), "client stranded after leader death"
    assert client.stall_reason is None          # EOF, not a hang
    client.close()


# ------------------------------------------------------------ inference


def test_greedy_generate_decode_step_is_cached():
    from repro.launch import serve as launch_serve
    from repro.serve.workload import lm_tiny_config

    cfg = lm_tiny_config()
    f1 = launch_serve._decode_step_fn(cfg)
    f2 = launch_serve._decode_step_fn(cfg)
    assert f1 is f2                             # one executable per cfg
    # params are an argument, not a baked-in constant: two different
    # params pytrees generate through the same cached callable
    import repro.models.model as M
    p1 = M.init_params(jax.random.PRNGKey(0), cfg)
    p2 = M.init_params(jax.random.PRNGKey(1), cfg)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 4)).astype(np.int32)
    o1 = launch_serve.greedy_generate(cfg, p1, prompts, 4)
    o2 = launch_serve.greedy_generate(cfg, p2, prompts, 4)
    assert o1.shape == o2.shape == (2, 8)
    assert np.array_equal(o1[:, :4], prompts)


def test_probe_adapter_decodes_pushed_slab():
    from repro.api.trainers import SIM_WORKLOADS
    from repro.core.slab import slab_codec
    from repro.serve.workload import build_infer_adapter

    spec = ExperimentSpec(arch="mlp", smoke=True)
    _, params, _, _ = SIM_WORKLOADS["mlp"](spec)
    adapter = build_infer_adapter(spec)
    slab = slab_codec(params).encode(params)
    decoded = adapter.decode(np.asarray(slab))
    out = adapter.run(decoded, 0)
    assert np.isfinite(out["probe_loss"])


# ----------------------------------------------------------- end to end


def _host_spec(**kw):
    base = dict(arch="mlp", backend="cluster", mode="async",
                schedule=None, cluster_workers=1, wall_budget_s=25.0,
                wall_sample_every_s=10.0, batch=16, smoke=True,
                transport="host", listen="127.0.0.1:0")
    base.update(kw)
    return ExperimentSpec(**base)


def test_leader_serves_two_infer_clients_while_training():
    """The acceptance scenario: a training leader with ``--listen``
    concurrently serves two separately-launched ``repro infer``
    processes (each rebuilds the inference workload from the wire
    spec) while a joined worker trains.  Both clients must exit 0 and
    the run report must account for both."""
    spec = _host_spec()
    trainer = ClusterTrainer()
    runtime = trainer.build_runtime(spec)
    runtime.proc_ready_timeout_s = 120.0
    addr = runtime.listen_address
    join = spawn_join_process(addr, workers=1, platform=CHILD_PLATFORM)
    clients = [spawn_infer_process(addr, requests=2,
                                   platform=CHILD_PLATFORM)
               for _ in range(2)]
    try:
        res = trainer.finish(runtime, spec)
    finally:
        codes = []
        for p in (join, *clients):
            try:
                codes.append(p.wait(timeout=90))
            except Exception:
                p.kill()
                codes.append("killed")
    assert codes == [0, 0, 0], codes
    serving = res.extra["serving"]
    assert serving["clients"] == 2
    for c in serving["per_client"]:
        assert c["pushes"] >= 1, serving
    assert [e for e in res.extra["events"]
            if e["event"] == "serve_client"]
    assert res.num_gradients > 0
