"""Substrate tests: optimizers, data pipeline, checkpointing, sharding
rules, HLO cost analyzer."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data.synthetic import (cifar10_like, mnist_like,
                                  random_classification, token_stream)
from repro.optim import adamw, momentum, sgd
from repro.optim.optimizers import clip_by_global_norm, cosine_warmup


# -------------------------------------------------------------- optimizers

def _quad_problem():
    """f(w) = 0.5 * ||w - target||^2 — gradient w - target."""
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    grad_fn = jax.grad(lambda p: 0.5 * jnp.sum((p["w"] - target) ** 2))
    return params, grad_fn, target


@pytest.mark.parametrize("opt,steps,tol", [
    (sgd(0.5), 40, 1e-4),
    (momentum(0.2, 0.9), 200, 3e-3),
    (adamw(0.3), 300, 2e-2),
])
def test_optimizers_converge_quadratic(opt, steps, tol):
    params, grad_fn, target = _quad_problem()
    state = opt.init(params)
    for _ in range(steps):
        g = grad_fn(params)
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=tol)


def test_sgd_exact_step():
    opt = sgd(0.1)
    p = {"w": jnp.ones(2)}
    s = opt.init(p)
    upd, _ = opt.update({"w": jnp.full(2, 3.0)}, s, p)
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.3, rtol=1e-6)


def test_clip_by_global_norm():
    opt = clip_by_global_norm(sgd(1.0), max_norm=1.0)
    p = {"w": jnp.zeros(4)}
    s = opt.init(p)
    g = {"w": jnp.full(4, 10.0)}          # norm 20
    upd, _ = opt.update(g, s, p)
    norm = float(jnp.linalg.norm(upd["w"]))
    assert abs(norm - 1.0) < 1e-5


def test_cosine_warmup_schedule():
    f = cosine_warmup(warmup=10, total=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-6
    assert float(f(jnp.int32(100))) <= 0.11
    vals = [float(f(jnp.int32(t))) for t in range(10, 101, 10)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))


# ---------------------------------------------------------------- datasets

def test_synthetic_shapes_and_determinism():
    x1, y1, xt, yt = mnist_like(seed=3, n_train=100, n_test=20)
    x2, y2, _, _ = mnist_like(seed=3, n_train=100, n_test=20)
    assert x1.shape == (100, 28, 28, 1) and xt.shape == (20, 28, 28, 1)
    np.testing.assert_array_equal(x1, x2)
    x, y, *_ = cifar10_like(seed=0, n_train=50, n_test=10)
    assert x.shape == (50, 32, 32, 3)
    assert set(np.unique(y)) <= set(range(10))


def test_random_classification_split():
    x_tr, y_tr, x_te, y_te = random_classification(seed=1, n=1000)
    assert x_tr.shape == (800, 20) and x_te.shape == (200, 20)
    # learnable: a linear probe beats chance easily
    from repro.models.cnn import init_mlp_clf, mlp_clf_forward, nll_loss
    params = init_mlp_clf(jax.random.PRNGKey(0))
    grad = jax.jit(jax.grad(lambda p: nll_loss(mlp_clf_forward(p, x_tr),
                                               y_tr)))
    for _ in range(60):
        g = grad(params)
        params = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    acc = np.mean(np.argmax(np.asarray(mlp_clf_forward(params, x_te)), -1)
                  == y_te)
    assert acc > 0.5


def test_token_stream_batches():
    it = token_stream(seed=0, vocab_size=97, batch=4, seq=16)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 16) and b1["labels"].shape == (4, 16)
    assert b1["tokens"].max() < 97
    # labels are next-token shifted
    it2 = token_stream(seed=0, vocab_size=97, batch=4, seq=16)
    b2 = next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


# ------------------------------------------------------------- checkpoints

def test_checkpoint_roundtrip():
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nested": {"b": jnp.ones(4, jnp.bfloat16)},
              "tup": (jnp.zeros(2), jnp.full(3, 7.0))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "step_5")
        save_checkpoint(path, params, step=5, extra={"arch": "t"})
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            params)
        restored, step = restore_checkpoint(path, like)
        assert step == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_latest_step():
    from repro.checkpoint import latest_step
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        save_checkpoint(os.path.join(d, "step_3"), {"w": jnp.zeros(1)}, 3)
        save_checkpoint(os.path.join(d, "step_11"), {"w": jnp.zeros(1)}, 11)
        assert latest_step(d) == 11


# ------------------------------------------------------------ HLO analyzer

def test_hlo_cost_scan_scaling():
    from repro.launch.hlo_cost import analyze_hlo_text

    def probe(n):
        def f(x):
            y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=n)
            return y
        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        return analyze_hlo_text(comp.as_text()).flops

    f1, f4 = probe(1), probe(4)
    assert abs(f4 / f1 - 4.0) < 0.1
    assert abs(f1 - 2 * 64 ** 3) / (2 * 64 ** 3) < 0.05


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([32, 64, 128]), k=st.sampled_from([32, 256]),
       n=st.sampled_from([16, 64]))
def test_hlo_cost_matmul_property(m, k, n):
    from repro.launch.hlo_cost import analyze_hlo_text
    comp = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
    flops = analyze_hlo_text(comp.as_text()).flops
    assert flops == pytest.approx(2 * m * k * n, rel=0.01)


# ------------------------------------------------------ partition sanitize

def test_sanitize_sharding_drops_nondivisible():
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.parallel.partition import sanitize_sharding
    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = NamedSharding(mesh, P("data", "model"))
    out = sanitize_sharding(sh, (3, 5))   # 3 % 1 == 0 ok with size-1 axes
    assert out.spec == P("data", "model")


def test_param_logical_tree_all_archs():
    """Every leaf of every full config resolves to a valid logical tuple."""
    from repro.configs.registry import ARCH_NAMES, get_config
    from repro.models import model as M
    from repro.parallel.partition import param_logical_tree
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        sds = jax.eval_shape(
            lambda cfg=cfg: M.init_params(jax.random.PRNGKey(0), cfg))
        logical = param_logical_tree(sds)
        flat_p = jax.tree.leaves(sds)
        flat_l = jax.tree.leaves(
            logical, is_leaf=lambda v: isinstance(v, tuple) and all(
                isinstance(e, (str, type(None))) for e in v))
        assert len(flat_p) == len(flat_l)
        for p, names in zip(flat_p, flat_l):
            assert len(names) == p.ndim, (arch, p.shape, names)
