"""Tests for the paper's technique: threshold schedules, gradient buffer,
and the parameter-server simulator's limit equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buffer import GradientBuffer, aggregate_flush
from repro.core.schedule import (constant_schedule, cosine_schedule,
                                 exponential_schedule, group_size_phases,
                                 linear_schedule, step_schedule)
from repro.core.simulator import PSTrainer, WorkerPool
from repro.data.synthetic import random_classification
from repro.models.cnn import init_mlp_clf, mlp_clf_forward, nll_loss


# ------------------------------------------------------------- schedules

@settings(max_examples=30, deadline=None)
@given(workers=st.integers(2, 64), kind=st.sampled_from(
    ["step", "linear", "cosine", "exp"]), horizon=st.integers(10, 2000),
    t=st.integers(0, 5000))
def test_schedule_monotone_and_bounded(workers, kind, horizon, t):
    from repro.api import parse_schedule
    arg = 50 if kind == "step" else horizon
    s = parse_schedule(f"{kind}:{arg}", workers)
    k_t, k_next = s(t), s(t + 1)
    assert 1 <= k_t <= workers
    assert k_next >= k_t          # monotone non-decreasing


def test_step_schedule_matches_paper():
    """Paper: lr=0.01, step size 300 = 3/lr; K grows by 1 every 300."""
    s = step_schedule(25, 300)
    assert s(0) == 1 and s(299) == 1 and s(300) == 2 and s(599) == 2
    assert s(300 * 24) == 25 and s(10 ** 6) == 25   # clamped at W


def test_schedule_phases():
    s = step_schedule(4, 10)
    assert s.phases(40) == [(0, 1), (10, 2), (20, 3), (30, 4)]
    g = group_size_phases(s, 40, axis_size=16)
    sizes = [x[1] for x in g]
    assert sizes == sorted(sizes)
    assert all(16 % x == 0 for x in sizes)
    assert sizes[-1] == 16


# ---------------------------------------------------------------- buffer

def _tree(seed, shape=(7,)):
    return {"w": jax.random.normal(jax.random.PRNGKey(seed), shape)}


def test_buffer_flush_mean():
    buf = GradientBuffer()
    trees = [_tree(i) for i in range(4)]
    for i, t in enumerate(trees):
        buf.add(t, version=0)
    agg, n = buf.flush(current_version=0)
    assert n == 4 and len(buf) == 0
    want = jnp.mean(jnp.stack([t["w"] for t in trees]), 0)
    np.testing.assert_allclose(np.asarray(agg["w"]), np.asarray(want),
                               rtol=1e-6)


def test_buffer_staleness_weighting():
    buf = GradientBuffer(staleness_decay=0.5)
    buf.add(_tree(0), version=0)   # staleness 2 -> weight 0.25
    buf.add(_tree(1), version=2)   # staleness 0 -> weight 1.0
    agg, _ = buf.flush(current_version=2)
    w = np.array([0.25, 1.0])
    w = w / w.sum()
    want = w[0] * _tree(0)["w"] + w[1] * _tree(1)["w"]
    np.testing.assert_allclose(np.asarray(agg["w"]), np.asarray(want),
                               rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 10), seed=st.integers(0, 999))
def test_buffer_conservation(k, seed):
    """Property: uniform flush × K == sum of gradients (conservation)."""
    buf = GradientBuffer()
    trees = [_tree(seed + i) for i in range(k)]
    for t in trees:
        buf.add(t, version=3)
    agg, n = buf.flush(current_version=3)
    total = sum(np.asarray(t["w"]) for t in trees)
    np.testing.assert_allclose(n * np.asarray(agg["w"]), total, rtol=1e-5,
                               atol=1e-6)


# ------------------------------------------------------------- simulator

@pytest.fixture(scope="module")
def sim_setup():
    data = random_classification(seed=0, n=2000)
    params = init_mlp_clf(jax.random.PRNGKey(0))
    loss = lambda p, x, y: nll_loss(mlp_clf_forward(p, x), y)
    pool = WorkerPool(num_workers=5, base_compute=0.05)
    return loss, params, data, pool


def _run(sim_setup, mode, schedule=None, seed=0):
    loss, params, data, pool = sim_setup
    tr = PSTrainer(loss, params, data, lr=0.01, batch_size=16, pool=pool,
                   seed=seed)
    return tr.simulate(mode, horizon=3.0, schedule=schedule)


def test_hybrid_k1_equals_async(sim_setup):
    """K(t) ≡ 1 must reproduce the async algorithm exactly."""
    r_async = _run(sim_setup, "async")
    r_hyb = _run(sim_setup, "hybrid", schedule=constant_schedule(5, 1))
    np.testing.assert_allclose(r_hyb.train_loss, r_async.train_loss,
                               rtol=1e-6)
    assert r_hyb.num_updates == r_async.num_updates


def test_hybrid_kw_matches_sync_update_count(sim_setup):
    """K(t) ≡ W: every flush aggregates W gradients (sync semantics —
    event timing differs because hybrid workers never idle, which is
    exactly the paper's throughput argument)."""
    r = _run(sim_setup, "hybrid", schedule=constant_schedule(5, 5))
    assert r.num_gradients >= 5 * r.num_updates


def test_sync_slower_than_async(sim_setup):
    """The paper's premise: sync applies far fewer updates per unit time."""
    r_sync = _run(sim_setup, "sync")
    r_async = _run(sim_setup, "async")
    assert r_sync.num_updates < r_async.num_updates / 2


def test_all_modes_learn(sim_setup):
    for mode, sched in [("async", None), ("sync", None),
                        ("hybrid", step_schedule(5, 100))]:
        r = _run(sim_setup, mode, schedule=sched)
        assert r.train_loss[-1] < r.train_loss[0], mode


def test_simulator_deterministic(sim_setup):
    r1 = _run(sim_setup, "hybrid", schedule=step_schedule(5, 50), seed=7)
    r2 = _run(sim_setup, "hybrid", schedule=step_schedule(5, 50), seed=7)
    np.testing.assert_array_equal(r1.train_loss, r2.train_loss)
    assert r1.num_updates == r2.num_updates
